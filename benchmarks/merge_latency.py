"""Merge-algorithm latency at scale (beyond-paper §Perf for the control
plane): faithful bijection matching vs. Merkle signature index.

The paper's merge checks ancestor-graph equivalence pairwise; the
signature index makes submit O(V+E). This benchmark grows the running
set to N dataflows and reports per-submit latency for both strategies —
the number that decides whether the manager can sit on a 1000-node
cluster's critical path.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import ReuseManager
from repro.core.graph import Dataflow, Task


def _library(n_dags: int, seed: int = 0) -> List[Dataflow]:
    """n_dags chains over G groups with nested shared prefixes.

    Prefix task types come from a *small common vocabulary* (parse,
    clean, kalman, …) with identical configs across groups — the
    realistic IoT case where every dataflow starts with the same
    preprocessing ops. Abstractly identical tasks with different source
    ancestry are what make the faithful bijection check expensive: every
    candidate demands an ancestor-graph comparison, while the signature
    index stays O(1) per task.
    """
    rng = np.random.default_rng(seed)
    groups = max(n_dags // 6, 1)
    dags = []
    for i in range(n_dags):
        g = int(rng.integers(groups))
        depth = int(rng.integers(8, 16))
        suffix = int(rng.integers(2, 10))
        name = f"d{i:04d}"
        df = Dataflow(name)
        prev = df.add_task(Task.make(f"{name}/src", f"src{g}", "SOURCE")).id
        for k in range(depth):
            # same ⟨type, config⟩ at depth k in EVERY group
            t = df.add_task(Task.make(f"{name}/p{k}", f"pre{k % 8}", {"stage": k}))
            df.add_stream(prev, t.id)
            prev = t.id
        for k in range(suffix):
            t = df.add_task(Task.make(f"{name}/s{k}", f"u{int(rng.integers(40))}", {}))
            df.add_stream(prev, t.id)
            prev = t.id
        snk = df.add_task(Task.make(f"{name}/sink", "store", "SINK"))
        df.add_stream(prev, snk.id)
        dags.append(df)
    return dags


def main(out_dir: str = "results/benchmarks") -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    out: Dict[str, Dict] = {}
    for n in (50, 100, 200):
        dags = _library(n, seed=4)
        rows = {}
        for strategy in ("faithful", "signature"):
            mgr = ReuseManager(strategy=strategy)
            lat = []
            for df in dags:
                t0 = time.perf_counter()
                mgr.submit(df.copy())
                lat.append(time.perf_counter() - t0)
            rows[strategy] = {
                "mean_ms": round(1e3 * float(np.mean(lat)), 3),
                "p99_ms": round(1e3 * float(np.quantile(lat, 0.99)), 3),
                "last10_mean_ms": round(1e3 * float(np.mean(lat[-10:])), 3),
            }
        speedup = rows["faithful"]["last10_mean_ms"] / max(
            rows["signature"]["last10_mean_ms"], 1e-9
        )
        out[str(n)] = {**rows, "speedup_at_n": round(speedup, 1)}
        print(
            f"N={n:4d}: faithful {rows['faithful']['last10_mean_ms']:.2f} ms/submit "
            f"vs signature {rows['signature']['last10_mean_ms']:.2f} ms "
            f"(×{speedup:.1f} at steady state)"
        )
    with open(os.path.join(out_dir, "merge_latency.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
