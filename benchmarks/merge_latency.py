"""Merge-algorithm latency at scale (beyond-paper §Perf for the control
plane), through the `repro.api` facade.

Part 1 — faithful bijection matching vs. Merkle signature index. The
paper's merge checks ancestor-graph equivalence pairwise; the signature
index makes submit O(V+E). This grows the running set to N dataflows and
reports per-submit latency for both strategies — the number that decides
whether the manager can sit on a 1000-node cluster's critical path.

Part 2 — batched vs sequential submission. Under multi-tenant arrival
churn (RIoTBench's 21 dataflows, OPMW's synthetic portals), N overlapping
arrivals used to pay N independent merges; ``submit_many`` plans the batch
together: one signature pass per DAG, cross-submission dedup inside the
batch, and one merged-DAG rebuild per overlapping group. Reported:
per-DAG submit cost sequential vs batched, on an overlapping batch and on
a disjoint batch (where batching must not be slower).

Part 3 — data-plane task→segment resolution. Every boundary-stream
``forward`` signal and every ``sink_state`` read resolves a task id to
its owning segment; the old Executor scanned all segments linearly, the
ExecutionBackend base keeps an O(1) reverse index. Measured over a
dry-run session holding the OPMW workload (dozens of segments): ns per
lookup via the maintained index vs the equivalent linear scan.

Part 4 — concurrent vs sync stepping. A multi-segment deployment
(independent kalman chains → one dependency wave) on the sharded backend,
stepped as the one-thread launch-order sweep vs the dependency-aware
ready-queue dispatch; reports wall-clock per step and the speedup. Also
runs the calibrated dry-run makespan model on the same deployment: with
``step_mode="concurrent"`` the predicted step latency is the wave *max*,
not the wave *sum* — the dry-run answer to "what would this deployment
gain from concurrency" without a single jit compile.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.api import Dataflow, ReuseSession, flow

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def _library(n_dags: int, seed: int = 0, groups: int | None = None) -> List[Dataflow]:
    """n_dags chains over G groups with nested shared prefixes.

    Prefix task types come from a *small common vocabulary* (parse,
    clean, kalman, …) with identical configs across groups — the
    realistic IoT case where every dataflow starts with the same
    preprocessing ops. Abstractly identical tasks with different source
    ancestry are what make the faithful bijection check expensive: every
    candidate demands an ancestor-graph comparison, while the signature
    index stays O(1) per task.
    """
    rng = np.random.default_rng(seed)
    if groups is None:
        groups = max(n_dags // 6, 1)
    dags = []
    for i in range(n_dags):
        g = int(rng.integers(groups))
        depth = int(rng.integers(8, 16))
        suffix = int(rng.integers(2, 10))
        b = flow(f"d{i:04d}").source(f"src{g}")
        for k in range(depth):
            # same ⟨type, config⟩ at depth k in EVERY group
            b.then(f"pre{k % 8}", stage=k)
        for k in range(suffix):
            b.then(f"u{int(rng.integers(40))}")
        dags.append(b.sink("store").build())
    return dags


def _disjoint_library(n_dags: int, seed: int = 0) -> List[Dataflow]:
    """One source type per DAG — zero overlap, the batching worst case."""
    rng = np.random.default_rng(seed)
    dags = []
    for i in range(n_dags):
        b = flow(f"x{i:04d}").source(f"only{i}")
        for k in range(int(rng.integers(6, 12))):
            b.then(f"pre{k % 8}", stage=k)
        dags.append(b.sink("store").build())
    return dags


def bench_strategies(out: Dict[str, Dict]) -> None:
    for n in (50, 100, 200):
        dags = _library(n, seed=4)
        rows = {}
        for strategy in ("faithful", "signature"):
            session = ReuseSession(strategy=strategy)
            lat = []
            for df in dags:
                t0 = time.perf_counter()
                session.submit(df.copy())
                lat.append(time.perf_counter() - t0)
            rows[strategy] = {
                "mean_ms": round(1e3 * float(np.mean(lat)), 3),
                "p99_ms": round(1e3 * float(np.quantile(lat, 0.99)), 3),
                "last10_mean_ms": round(1e3 * float(np.mean(lat[-10:])), 3),
            }
        speedup = rows["faithful"]["last10_mean_ms"] / max(
            rows["signature"]["last10_mean_ms"], 1e-9
        )
        out[str(n)] = {**rows, "speedup_at_n": round(speedup, 1)}
        print(
            f"N={n:4d}: faithful {rows['faithful']['last10_mean_ms']:.2f} ms/submit "
            f"vs signature {rows['signature']['last10_mean_ms']:.2f} ms "
            f"(×{speedup:.1f} at steady state)"
        )


def _time_sequential(dags: List[Dataflow]) -> float:
    session = ReuseSession(strategy="signature")
    copies = [df.copy() for df in dags]  # copy outside the clock, like batched
    t0 = time.perf_counter()
    for df in copies:
        session.submit(df)
    return time.perf_counter() - t0


def _time_batched(dags: List[Dataflow]) -> float:
    session = ReuseSession(strategy="signature")
    batch = [df.copy() for df in dags]
    t0 = time.perf_counter()
    session.submit_many(batch)
    return time.perf_counter() - t0


def bench_batched(out: Dict[str, Dict], repeats: int = 5) -> None:
    cases = {
        # heavy cross-arrival overlap: few groups, deep shared prefixes
        "overlapping": _library(200, seed=7, groups=4),
        # no overlap at all: batching must not cost anything
        "disjoint": _disjoint_library(200, seed=7),
    }
    for label, dags in cases.items():
        seq = min(_time_sequential(dags) for _ in range(repeats))
        bat = min(_time_batched(dags) for _ in range(repeats))
        speedup = seq / max(bat, 1e-9)
        out[f"batch_{label}"] = {
            "n_dags": len(dags),
            "sequential_ms_per_dag": round(1e3 * seq / len(dags), 3),
            "batched_ms_per_dag": round(1e3 * bat / len(dags), 3),
            "batch_speedup": round(speedup, 2),
        }
        print(
            f"{label:12s}: sequential {1e3 * seq / len(dags):.3f} ms/DAG "
            f"vs submit_many {1e3 * bat / len(dags):.3f} ms/DAG "
            f"(×{speedup:.2f})"
        )


def bench_owner_lookup(out: Dict[str, Dict], repeats: int = 5) -> None:
    """O(1) reverse index vs the old linear scan, on a real deployed set."""
    from repro.workloads import opmw_workload

    session = ReuseSession(strategy="signature", execute=True, backend="dryrun")
    for df in opmw_workload():
        session.submit(df)
    backend = session._system.backend
    task_ids = [tid for seg in backend.segments.values() for tid in seg.spec.task_ids]

    def owner_linear(task_id: str):
        # the pre-redesign Executor._owner: scan every segment's task list
        for name, seg in backend.segments.items():
            if task_id in seg.spec.task_ids:
                return name
        return None

    def time_lookups(fn) -> float:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for tid in task_ids:
                fn(tid)
            best = min(best, time.perf_counter() - t0)
        return best

    # sanity: both resolvers agree before timing
    assert all(backend._owner(t) == owner_linear(t) for t in task_ids)
    indexed = time_lookups(backend._owner)
    linear = time_lookups(owner_linear)
    out["owner_lookup"] = {
        "segments": len(backend.segments),
        "deployed_tasks": len(task_ids),
        "indexed_ns_per_lookup": round(1e9 * indexed / len(task_ids), 1),
        "linear_ns_per_lookup": round(1e9 * linear / len(task_ids), 1),
        "index_speedup": round(linear / max(indexed, 1e-12), 1),
    }
    print(
        f"owner lookup : indexed {out['owner_lookup']['indexed_ns_per_lookup']:.0f} ns "
        f"vs linear {out['owner_lookup']['linear_ns_per_lookup']:.0f} ns over "
        f"{out['owner_lookup']['segments']} segments "
        f"(×{out['owner_lookup']['index_speedup']:.1f})"
    )


def _concurrency_workload(n_chains: int = 8, depth: int = 4) -> List[Dataflow]:
    """Independent compute-heavy chains: one segment each, one dependency
    wave — the best case for overlap (kalman is a lax.scan over the batch,
    so each segment is real single-stream work, not a fused elementwise op).
    """
    dags = []
    for i in range(n_chains):
        b = flow(f"cc{i}").source(f"sensor{i}")
        for k in range(depth):
            b.then("kalman", q=0.1 + i, stage=k)
        dags.append(b.sink("store").build())
    return dags


def bench_concurrent_step(
    out: Dict[str, Dict],
    n_chains: int = 8,
    steps: int = 20,
    base_batch: int = 8192,  # enough XLA work per segment to dwarf dispatch
    max_workers: int = 0,
) -> None:
    """Sync sweep vs dependency-aware concurrent dispatch on the sharded
    backend, plus the calibrated dry-run makespan model of the same set."""
    import jax

    # One dispatch thread per device: more threads than devices only adds
    # GIL contention (devices are the parallelism, threads just unblock it).
    max_workers = max_workers or len(jax.devices())

    dags = _concurrency_workload(n_chains)
    sessions = {}
    for mode in ("sync", "concurrent"):
        s = ReuseSession(
            strategy="signature", execute=True, backend="sharded",
            base_batch=base_batch, step_mode=mode, max_workers=max_workers,
        )
        for df in dags:
            s.submit(df.copy())
        s.run(2)  # compile + warm outside the clock
        s._system.backend.reports.clear()  # keep compile outliers out of calibration
        sessions[mode] = s

    walls = {}
    for mode, s in sessions.items():
        # min of 3 timed windows: the container's CPU scheduling jitter
        # lands in some windows; the min is the honest per-mode floor
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            s.run(steps)
            best = min(best, (time.perf_counter() - t0) / steps)
        walls[mode] = best
    speedup = walls["sync"] / max(walls["concurrent"], 1e-12)

    # digests must be identical across modes (determinism contract)
    assert all(
        sessions["sync"].sink_digests(df.name) == sessions["concurrent"].sink_digests(df.name)
        for df in dags
    ), "concurrent stepping changed sink digests"
    for s in sessions.values():
        s.close()

    # dry-run makespan model, calibrated from the sync session's reports
    from repro.ops.costs import fit_latency_model

    model = fit_latency_model(sessions["sync"]._system.backend.latency_samples())
    dry = {}
    for mode in ("sync", "concurrent"):
        s = ReuseSession(
            strategy="signature", execute=True, backend="dryrun",
            base_batch=base_batch, step_mode=mode,
        )
        s._system.backend.calibrate(model)
        for df in dags:
            s.submit(df.copy())
        dry[mode] = s.step().makespan_ms

    out["concurrent_step"] = {
        "backend": "sharded",
        "segments": n_chains,
        "devices": len(jax.devices()),
        "max_workers": max_workers,
        "base_batch": base_batch,
        "steps": steps,
        "sync_ms_per_step": round(1e3 * walls["sync"], 2),
        "concurrent_ms_per_step": round(1e3 * walls["concurrent"], 2),
        "concurrent_speedup": round(speedup, 2),
        "dryrun_makespan_sync_ms": round(dry["sync"], 2),
        "dryrun_makespan_concurrent_ms": round(dry["concurrent"], 2),
        "dryrun_makespan_ratio": round(dry["sync"] / max(dry["concurrent"], 1e-12), 2),
    }
    print(
        f"concurrent   : sync {out['concurrent_step']['sync_ms_per_step']:.1f} ms/step "
        f"vs concurrent {out['concurrent_step']['concurrent_ms_per_step']:.1f} ms/step "
        f"(×{speedup:.2f} on {len(jax.devices())} devices / {max_workers} workers); "
        f"dryrun makespan {dry['sync']:.1f} → {dry['concurrent']:.1f} ms "
        f"(wave-max model ×{out['concurrent_step']['dryrun_makespan_ratio']:.2f})"
    )


PARTS = {
    "strategies": bench_strategies,
    "batched": bench_batched,
    "owner_lookup": bench_owner_lookup,
    "concurrent_step": bench_concurrent_step,
}


def main(out_dir: str = "results/benchmarks", parts: List[str] | None = None) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    out: Dict[str, Dict] = {}
    for name in parts or list(PARTS):
        PARTS[name](out)
    path = os.path.join(out_dir, "merge_latency.json")
    if parts:  # partial run: merge into the stored record instead of clobbering
        if os.path.exists(path):
            with open(path) as f:
                stored = json.load(f)
            stored.update(out)
            out = stored
    with open(path, "w") as f:
        json.dump(stamp(out), f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--parts",
        help=f"comma list of {sorted(PARTS)} (default: all)",
    )
    ap.add_argument("--out-dir", default="results/benchmarks")
    args = ap.parse_args()
    # Give the sharded backend a multi-device pool to overlap, but never
    # more devices than cores: forcing 4 XLA devices onto 2 cores just
    # oversubscribes them (must be set before jax imports).
    _n_dev = max(2, min(4, os.cpu_count() or 2))
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n_dev}"
    )
    main(out_dir=args.out_dir, parts=args.parts.split(",") if args.parts else None)
