"""Run every benchmark; one section per paper table/figure + the
beyond-paper benches. Results land in results/benchmarks/*.json and the
console summary below is the EXPERIMENTS.md source of truth.

  fig2/3/4   workload_traces   paper Figs. 2, 3, 4 (6 traces, Default vs Reuse)
  latency    merge_latency     faithful vs signature submit latency
  defrag     defrag_benefit    paper future-work, implemented (real data plane)
  serving    serving_reuse     paper technique over multi-tenant LM pipelines
  roofline   roofline_bench    40-cell dry-run aggregation + hillclimb picks
  hotpath    hotpath_bench     zero-copy fetch / chain batching / segment fusion
  optimizer  fusion_optimizer_bench  wave-aware fusion planner / compile cache
  obs        obs_overhead_bench     telemetry-plane overhead on the jit hot path
"""
from __future__ import annotations

import sys
import time


def main() -> int:
    import json

    from benchmarks import (
        defrag_benefit,
        fusion_optimizer_bench,
        hotpath_bench,
        merge_latency,
        obs_overhead_bench,
        roofline_bench,
        serving_reuse,
        workload_traces,
    )
    from benchmarks._host import host_metadata

    t0 = time.time()
    print(f"host: {json.dumps(host_metadata(), sort_keys=True)}")
    print("=== fig 2/3/4: running tasks / cores / reuse histogram ===")
    workload_traces.main()
    print("\n=== merge latency (faithful vs signature) ===")
    merge_latency.main()
    print("\n=== defragmentation benefit (real data plane) ===")
    defrag_benefit.main()
    print("\n=== multi-tenant LM reuse-serving ===")
    serving_reuse.main()
    print("\n=== roofline aggregation (dry-run records) ===")
    roofline_bench.main()
    print("\n=== hot path: zero-copy fetch / chain batching / fusion ===")
    hotpath_rc = hotpath_bench.main([])
    print("\n=== fusion optimizer: wave-aware planner / compile cache ===")
    optimizer_rc = fusion_optimizer_bench.main([])
    print("\n=== telemetry plane: obs overhead on the jit hot path ===")
    obs_rc = obs_overhead_bench.main([])
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return hotpath_rc or optimizer_rc or obs_rc


if __name__ == "__main__":
    sys.exit(main())
