"""Telemetry overhead — the obs plane must be invisible on the jit hot
path (PR 10 acceptance numbers, written to BENCH_pr10.json).

Three planes over the same submitted workload (OPMW pool on the
inprocess backend, ``execute=True`` so every step runs the jit-compiled
segment functions):

  * **off**     — ``configure_obs(metrics=False, trace=False)``: null
    registry, tracer disarmed. The honest baseline.
  * **default** — metrics registry live, tracing off. This is the
    out-of-the-box configuration; the acceptance bar applies here.
  * **traced**  — metrics + span recording at the default sample stride,
    the worst case anyone can switch on without touching knobs.

The bar: *default* overhead < 3% of *off* ms/step. Timing interleaves
the planes round-robin (one window each, repeated) so drift/thermal
noise hits all three equally, and takes the best window per plane.
*traced* overhead is recorded informationally (no bar — span recording
is opt-in).

Any missed bar exits 2 (the CI contract); ``--smoke`` shrinks the step
counts for the CI job while keeping the bar armed.

Usage:
    PYTHONPATH=src python benchmarks/obs_overhead_bench.py \
        [--steps 60] [--windows 7] [--smoke] \
        [--out results/benchmarks/BENCH_pr10.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp

PLANES = ("off", "default", "traced")


def _make_session(plane: str):
    from repro.api import ReuseSession
    from repro.workloads import opmw_workload

    session = ReuseSession(strategy="signature", execute=True, backend="inprocess")
    for df in opmw_workload():
        session.submit(df.copy())
    if plane == "off":
        session.configure_obs(metrics=False, trace=False)
    elif plane == "default":
        session.configure_obs(metrics=True, trace=False)
    elif plane == "traced":
        session.configure_obs(metrics=True, trace=True)
    else:  # pragma: no cover - guarded by PLANES
        raise ValueError(plane)
    session.run(3)  # compile + warm every segment before any timed window
    return session


def bench_overhead(steps: int, windows: int) -> Dict[str, Any]:
    sessions = {plane: _make_session(plane) for plane in PLANES}
    best: Dict[str, float] = {plane: float("inf") for plane in PLANES}
    try:
        # round-robin windows: plane order rotates so no plane always runs
        # first (cold) or last (thermally throttled)
        for w in range(windows):
            order = PLANES[w % len(PLANES):] + PLANES[: w % len(PLANES)]
            for plane in order:
                session = sessions[plane]
                if plane == "traced":
                    session.drain_spans()  # empty ring: steady-state recording cost
                t0 = time.perf_counter()
                session.run(steps)
                best[plane] = min(best[plane], (time.perf_counter() - t0) / steps)
    finally:
        for session in sessions.values():
            session.close()
    ms = {plane: 1e3 * best[plane] for plane in PLANES}
    return {
        "steps": steps,
        "windows": windows,
        "ms_per_step": {k: round(v, 4) for k, v in ms.items()},
        "default_overhead_pct": round(100.0 * (ms["default"] / ms["off"] - 1.0), 2),
        "traced_overhead_pct": round(100.0 * (ms["traced"] / ms["off"] - 1.0), 2),
    }


def bench_instrument_cost(reps: int = 200_000) -> Dict[str, Any]:
    """Microcosts of one counter inc / histogram observe / sampled span,
    live vs null — context for the end-to-end number, no bar."""
    from repro.obs import MetricsRegistry, NULL_REGISTRY, Tracer

    rows: List[Dict[str, Any]] = []
    for name, reg in (("live", MetricsRegistry()), ("null", NULL_REGISTRY)):
        c = reg.counter("bench_counter", "bench")
        h = reg.histogram("bench_hist", "bench")
        t0 = time.perf_counter()
        for _ in range(reps):
            c.inc()
        inc_ns = 1e9 * (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            h.observe(1.5)
        obs_ns = 1e9 * (time.perf_counter() - t0) / reps
        rows.append(
            {"registry": name, "counter_inc_ns": round(inc_ns, 1),
             "histogram_observe_ns": round(obs_ns, 1)}
        )
    tracer = Tracer(enabled=True, capacity=4096)
    t0 = time.perf_counter()
    for _ in range(reps // 10):
        with tracer.span("bench", "step"):
            pass
    span_ns = 1e9 * (time.perf_counter() - t0) / (reps // 10)
    return {"reps": reps, "rows": rows, "span_ns": round(span_ns, 1)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60, help="steps per timed window")
    ap.add_argument("--windows", type=int, default=7)
    ap.add_argument("--max-overhead-pct", type=float, default=3.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer/shorter windows, bar stays armed")
    ap.add_argument("--out", default=os.path.join("results", "benchmarks", "BENCH_pr10.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.steps, args.windows = min(args.steps, 25), min(args.windows, 4)

    print(f"obs overhead on the jit hot path ({args.windows} windows x {args.steps} steps):")
    overhead = bench_overhead(args.steps, args.windows)
    for plane in PLANES:
        print(f"  {plane:8s}: {overhead['ms_per_step'][plane]:8.3f} ms/step")
    print(f"  default overhead: {overhead['default_overhead_pct']:+.2f}%   "
          f"traced: {overhead['traced_overhead_pct']:+.2f}%")

    print("instrument microcosts (live vs null registry):")
    micro = bench_instrument_cost()
    for r in micro["rows"]:
        print(f"  {r['registry']:5s}: inc {r['counter_inc_ns']:7.1f} ns   "
              f"observe {r['histogram_observe_ns']:7.1f} ns")
    print(f"  span (enabled, stride 1): {micro['span_ns']:.1f} ns")

    bars = {
        "default_overhead_lt_3pct":
            overhead["default_overhead_pct"] < args.max_overhead_pct,
    }
    record = stamp(
        {
            "bench": "obs_overhead",
            "smoke": bool(args.smoke),
            "max_overhead_pct": args.max_overhead_pct,
            "overhead": overhead,
            "micro": micro,
            "bars": bars,
            "all_bars_met": all(bars.values()),
        }
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    if not record["all_bars_met"]:
        print(f"ACCEPTANCE BARS MISSED: {[k for k, v in bars.items() if not v]}")
        return 2
    print("all acceptance bars met")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
