"""Host metadata stamped into every BENCH_*.json / bench writer output.

Benchmark numbers are meaningless without the machine that produced them:
ms/step on a 4-core CI runner and a 64-core dev box differ by an order of
magnitude, and JAX version bumps move jit timings. Every writer calls
:func:`host_metadata` and records the result under a ``"host"`` key so
results stay comparable across runs and runners.

JAX version is read from package metadata (``importlib.metadata``) rather
than ``import jax`` — the dryrun/multiproc benches are JAX-free and must
stay that way.
"""
from __future__ import annotations

import os
import platform
import sys
from typing import Any, Dict


def _dist_version(name: str) -> str:
    try:
        from importlib.metadata import version

        return version(name)
    except Exception:
        return "unknown"


def host_metadata() -> Dict[str, Any]:
    """Machine/toolchain facts for benchmark provenance (JSON-safe dict)."""
    return {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "implementation": platform.python_implementation(),
        "jax": _dist_version("jax"),
        "jaxlib": _dist_version("jaxlib"),
        "executable": sys.executable,
    }


def stamp(record: Dict[str, Any]) -> Dict[str, Any]:
    """Set the ``"host"`` key in place and return the record, so writers
    can wrap their final dict in one call. Always overwrites: a record
    merged from an older file should carry the machine that wrote it."""
    record["host"] = host_metadata()
    return record
