"""Elastic cluster plane benchmark — PR 7 acceptance (BENCH_pr7.json).

Three experiments over the multiproc data plane:

  * **supervision overhead** — ms/step on a steady deployment with the
    heartbeat supervisor + per-step spill snapshots armed vs bare,
    best-of-N windows, on the **jit worker plane** (the production
    plane). Spill cost is a small constant per worker per wave (the
    payload is ephemeral-filtered to a few hundred bytes per segment),
    so it amortizes against real XLA compute. Acceptance bar: overhead
    under 5%. The same measurement on the dry plane — where a step does
    almost no compute, so the constant cannot amortize — is reported as
    context, not gated.
  * **recovery after kill** — SIGKILL one worker mid-trace under
    supervision; the step that hits the dead pipe triggers respawn +
    redeploy from spill snapshots and the run completes. Reports the
    measured redeploy latency and asserts sink counts identical to an
    uninterrupted run (the exactly-once contract).
  * **autoscaler grow-then-shrink** — a bursty trace (light load, then a
    submission burst, then removal). The EWMA-pressure autoscaler, with
    thresholds calibrated from the measured light-phase pressure, must
    grow the pool during the burst and shrink it back after — the
    pool-size timeline is recorded.

Usage:
    PYTHONPATH=src python benchmarks/elasticity_bench.py \
        [--workers 2] [--steps 40] [--out results/benchmarks/BENCH_pr7.json]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time
from typing import Dict, List

from repro.api import flow
from repro.cluster import Autoscaler, WorkerSupervisor
from repro.runtime.system import StreamSystem
from repro.runtime.worker import MultiprocBackend

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def _chains(n: int, depth: int = 3, tag: str = "el") -> List:
    dags = []
    for i in range(n):
        b = flow(f"{tag}{i}").source(f"sensor{i}")
        for k in range(depth):
            b.then("kalman", q=0.1 + i, stage=k)
        dags.append(b.sink("store").build())
    return dags


def _system(workers: int, plane: str = "dry", batch: int = 0,
            **backend_kw) -> StreamSystem:
    be = MultiprocBackend(workers=workers, worker_plane=plane, **backend_kw)
    kw = {"base_batch": batch} if batch else {}
    return StreamSystem(
        strategy="signature", backend=be, step_mode="concurrent",
        max_workers=max(workers, 2), **kw,
    )


def _counts(system: StreamSystem) -> Dict:
    return {
        name: {s: d["count"] for s, d in system.sink_digests(name).items()}
        for name in sorted(system.manager.submitted)
    }


def _ms_per_step(system: StreamSystem, steps: int, windows: int = 5) -> float:
    """Best-of-N windows (the min is the honest floor under container
    scheduling jitter, same methodology as the PR 5 bench)."""
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        for _ in range(steps):
            system.step()
        best = min(best, (time.perf_counter() - t0) / steps)
    return 1e3 * best


def bench_overhead(workers: int, chains: int, steps: int,
                   plane: str = "dry", batch: int = 0,
                   rounds: int = 3) -> Dict[str, float]:
    """Steady-state ms/step, supervised vs bare, same deployment.

    Bare and supervised runs alternate for ``rounds`` rounds and each
    mode takes its minimum — paired sampling, so slow drift on a shared
    host (the dominant noise source) cannot masquerade as overhead."""
    ms: Dict[str, float] = {"bare": float("inf"), "supervised": float("inf")}
    warm = 1 if plane == "dry" else 4  # jit: compiles outside the clock
    for _ in range(rounds):
        for mode in ("bare", "supervised"):
            system = _system(workers, plane=plane, batch=batch)
            sup = None
            if mode == "supervised":
                # stock supervisor config: 0.5s heartbeat, spill snapshots
                sup = WorkerSupervisor(system.backend).start()
            for df in _chains(chains):
                system.submit(df)
            for _ in range(warm):  # deploy + first publish outside the clock
                system.step()
            ms[mode] = min(ms[mode], _ms_per_step(system, steps))
            if mode == "supervised":
                spill = system.backend._spill_ewma
                if spill is not None:
                    ms["spill_ms_per_worker_step"] = spill
            if sup is not None:
                sup.stop()
            system.close()
    for mode in ("bare", "supervised"):
        print(f"  {plane}/{mode:10s}: {ms[mode]:7.2f} ms/step")
    ms["overhead_pct"] = 100.0 * (ms["supervised"] - ms["bare"]) / ms["bare"]
    return ms


def bench_recovery(workers: int, chains: int, steps: int) -> Dict[str, object]:
    """Kill one worker mid-trace; report redeploy latency and conformance."""
    # uninterrupted reference
    ref = _system(workers)
    for df in _chains(chains):
        ref.submit(df)
    for _ in range(steps):
        ref.step()
    expect = _counts(ref)
    ref.close()

    system = _system(workers)
    sup = WorkerSupervisor(
        system.backend, heartbeat_interval=0.2, snapshot_states=True
    ).start()
    for df in _chains(chains):
        system.submit(df)
    kill_at = steps // 2
    t_kill = 0.0
    for i in range(steps):
        if i == kill_at:
            victim = system.backend._procs[workers - 1]
            t_kill = time.perf_counter()
            os.kill(victim.pid, signal.SIGKILL)
        system.step()
    t_done = time.perf_counter()
    got = _counts(system)
    respawns = list(system.backend.respawns)
    sup.stop()
    system.close()
    assert got == expect, "post-recovery sink counts diverged from uninterrupted run"
    assert respawns, "worker was killed but no respawn was recorded"
    out = {
        "kill_at_step": kill_at,
        "respawns": len(respawns),
        "redeploy_ms": round(float(respawns[0]["ms"]), 2),
        "segments_redeployed": len(respawns[0]["segments"]),
        "detect_plus_recover_s": round(t_done - t_kill, 3),
        "sink_counts_identical": True,
    }
    print(f"  killed worker at step {kill_at}: redeploy {out['redeploy_ms']} ms, "
          f"{out['segments_redeployed']} segments, counts identical")
    return out


def bench_autoscale(steps_per_phase: int, batch: int = 1024) -> Dict[str, object]:
    """Light -> burst -> shrink trace; thresholds calibrated from the
    measured light-phase pressure so the bench is robust to host speed.

    Runs on the jit plane: dry steps finish in microseconds, where
    scheduling jitter is the same magnitude as the signal itself. Real
    XLA compute puts the light/burst pressure ratio (~6x) far above the
    noise floor. Calibration reads the *settled* EWMA — the first light
    steps carry compile spikes that would inflate the baseline."""
    system = _system(1, plane="jit", batch=batch)
    light = _chains(2, tag="lo")
    burst = _chains(10, tag="hi")
    for df in light:
        system.submit(df)
    for _ in range(2 * steps_per_phase):  # deploy + compile + EWMA settle
        system.step()
    probe = Autoscaler(system.backend)  # placeholder policy, replaced below
    samples = []
    for _ in range(steps_per_phase):
        system.step()
        samples.append(probe.pressure())
    p_light = sorted(samples)[len(samples) // 2]  # median: spike-proof
    # the burst carries ~6x the light-phase load, so grow at 2x the light
    # baseline (safely above measurement noise, far below the burst) and
    # shrink back under 1.2x; short patience/cooldown so the bursty
    # phases (tens of steps) can express a full grow+shrink cycle
    high_ms, low_ms = 2.0 * p_light, 1.2 * p_light
    scaler = Autoscaler(
        system.backend, min_workers=1, max_workers=4,
        high_ms=high_ms, low_ms=low_ms,
        patience=2, cooldown=3,
    )
    timeline: List[int] = []

    def run_phase(n: int) -> None:
        for _ in range(n):
            system.step()
            scaler.observe()
            timeline.append(system.backend.n_workers)

    run_phase(steps_per_phase)          # light: should hold at 1
    for df in burst:
        system.submit(df)
    run_phase(2 * steps_per_phase)      # burst: pressure ~5x light -> grow
    peak = max(timeline)
    for df in burst:
        system.remove(df.name)
    run_phase(3 * steps_per_phase)      # shrink: pressure decays -> scale down
    final = timeline[-1]
    actions = list(scaler.actions)
    system.close()
    out = {
        "worker_plane": "jit",
        "base_batch": batch,
        "light_pressure_ms": round(p_light, 4),
        "high_ms": round(high_ms, 4),
        "low_ms": round(low_ms, 4),
        "peak_workers": peak,
        "final_workers": final,
        "grew": peak > 1,
        "shrank_back": final < peak,
        "actions": actions,
        "pool_timeline": timeline,
    }
    print(f"  pool 1 -> {peak} (burst) -> {final} (drain), "
          f"{len(actions)} scaling actions")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--chains", type=int, default=6)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--phase-steps", type=int, default=15,
                    help="steps per autoscaler phase (light/burst/shrink)")
    ap.add_argument("--batch", type=int, default=16384,
                    help="event batch for the jit overhead phase (large enough "
                         "that per-wave spill cost amortizes against compute)")
    ap.add_argument("--jit-steps", type=int, default=12,
                    help="steps per timing window in the jit overhead phase")
    ap.add_argument("--out", default=os.path.join("results", "benchmarks", "BENCH_pr7.json"))
    args = ap.parse_args(argv)

    print(f"supervision overhead, jit plane "
          f"({args.workers} workers, {args.chains} chains, batch {args.batch}):")
    overhead = bench_overhead(args.workers, args.chains, args.jit_steps,
                              plane="jit", batch=args.batch)
    print("supervision overhead, dry plane (context only — no compute to "
          "amortize the constant spill cost against):")
    overhead_dry = bench_overhead(args.workers, args.chains, args.steps)
    print("recovery after SIGKILL:")
    recovery = bench_recovery(args.workers, args.chains, args.steps)
    print("autoscaler grow-then-shrink:")
    autoscale = bench_autoscale(args.phase_steps)

    record = {
        "bench": "elastic_cluster_plane",
        "deployment": {
            "workers": args.workers, "chains": args.chains,
            "steps": args.steps, "transport": "shm",
            "overhead_plane": "jit", "overhead_batch": args.batch,
        },
        "supervision": {
            "worker_plane": "jit",
            "base_batch": args.batch,
            "bare_ms_per_step": round(overhead["bare"], 3),
            "supervised_ms_per_step": round(overhead["supervised"], 3),
            "overhead_pct": round(overhead["overhead_pct"], 2),
            "spill_ms_per_worker_step": round(
                overhead.get("spill_ms_per_worker_step", 0.0), 4
            ),
        },
        "supervision_dry_context": {
            "worker_plane": "dry",
            "bare_ms_per_step": round(overhead_dry["bare"], 3),
            "supervised_ms_per_step": round(overhead_dry["supervised"], 3),
            "overhead_pct": round(overhead_dry["overhead_pct"], 2),
            "note": (
                "dry steps do near-zero compute, so the constant per-wave "
                "spill write cannot amortize; not an acceptance gate"
            ),
        },
        "recovery": recovery,
        "autoscale": autoscale,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(stamp(record), f, indent=1)
    print(f"wrote {args.out}")
    # Acceptance bars. Exit code 2 = bar missed on a healthy run (noisy
    # shared runners can tolerate it in smoke jobs; crashes still fail hard).
    ok = True
    if record["supervision"]["overhead_pct"] >= 5.0:
        print(f"WARNING: supervision overhead "
              f"{record['supervision']['overhead_pct']:.1f}% >= 5%")
        ok = False
    if not (autoscale["grew"] and autoscale["shrank_back"]):
        print("WARNING: autoscaler did not complete a grow-then-shrink cycle")
        ok = False
    return 0 if ok else 2


if __name__ == "__main__":
    raise SystemExit(main())
