"""Distributed data plane benchmark — lifting the GIL cap on concurrent
stepping (PR 5 acceptance numbers, written to BENCH_pr5.json).

PR 4's wave/ready-queue scheduler overlaps independent segments on a
thread pool, but per-segment Python dispatch holds the GIL, capping the
measured sync→concurrent speedup on the 8-kalman-chain deployment. This
benchmark steps the *same* deployment through three data planes:

  * ``sync``      — in-process jit, one-thread launch-order sweep;
  * ``threads``   — sharded backend, ``step_mode="concurrent"`` — PR 4's
                    thread-pool dispatch (the GIL-capped plane);
  * ``multiproc`` — worker *processes* over the shm transport
                    (``backend="multiproc"``): segments compile and step
                    in separate interpreters, boundary streams ride
                    shared-memory ring buffers, and each dependency wave
                    is one batched pipe RPC per worker.

Two regimes are measured, because they bound different things:

  * **dispatch-bound** (small batch): per-segment Python dispatch is the
    step cost. Threads gain ~nothing over sync here — the GIL serializes
    exactly the part that dominates — while worker processes run their
    dispatch in parallel interpreters. This is the regime the acceptance
    bar targets: multiproc must beat the threaded plane's ms/step.
  * **compute-bound** (large batch): XLA kernels dominate. Every plane is
    then limited by the host's *effective* parallel capacity, which the
    benchmark calibrates directly (two pure-CPU burner processes vs one);
    on a 2-core CI container that ceiling is ~×1.3, so threads and
    processes land within noise of each other — reported for context,
    with the calibrated ceiling alongside.

Sink digests are asserted identical across all three planes in both
regimes (the determinism contract), and the calibrated dry-run makespan
model is reported as the unlimited-hardware roofline.

Usage:
    PYTHONPATH=src python benchmarks/distributed_bench.py \
        [--chains 8] [--steps 20] [--workers N] [--out results/benchmarks/BENCH_pr5.json]
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import time
from typing import Dict, List

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.api import ReuseSession, flow

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def _chains(n_chains: int, depth: int = 4) -> List:
    """Independent compute-heavy kalman chains — one segment each, one
    dependency wave: the best case for overlap (kalman is a lax.scan over
    the batch, so each segment is real single-stream work)."""
    dags = []
    for i in range(n_chains):
        b = flow(f"cc{i}").source(f"sensor{i}")
        for k in range(depth):
            b.then("kalman", q=0.1 + i, stage=k)
        dags.append(b.sink("store").build())
    return dags


def _burn(q):
    t0 = time.perf_counter()
    x = 0
    for i in range(30_000_000):
        x += i
    q.put(time.perf_counter() - t0)


def host_parallel_ceiling(n: int = 2) -> float:
    """Effective speedup this host gives n CPU-bound *processes* vs one —
    the hard upper bound on any concurrency mechanism's compute-bound
    gain (cloud CI containers often deliver well under their nominal
    core count)."""
    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    _burn(q)
    serial = q.get()
    procs = [ctx.Process(target=_burn, args=(q,)) for _ in range(n)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    wall = time.perf_counter() - t0
    for _ in procs:
        q.get()
    return n * serial / wall


def _bench_session(session: ReuseSession, dags, steps: int, windows: int = 5):
    """Best-of-N windows ms/step (the min is the honest floor under the
    container's CPU scheduling jitter); compiles warm outside the clock."""
    for df in dags:
        session.submit(df.copy())
    session.run(2)  # compile + warm
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        session.run(steps)
        best = min(best, (time.perf_counter() - t0) / steps)
    return 1e3 * best


def _measure_regime(dags, base_batch: int, steps: int, workers: int) -> Dict[str, float]:
    planes = {
        "sync": dict(backend="inprocess", step_mode="sync"),
        "threads": dict(backend="sharded", step_mode="concurrent",
                        max_workers=workers),
        "multiproc": dict(backend="multiproc", step_mode="concurrent",
                          workers=workers, max_workers=max(workers, 2)),
    }
    ms: Dict[str, float] = {}
    counts: Dict[str, Dict] = {}
    for name, kw in planes.items():
        session = ReuseSession(
            strategy="signature", execute=True, base_batch=base_batch, **kw
        )
        ms[name] = _bench_session(session, dags, steps)
        counts[name] = {
            df.name: {s: v["count"] for s, v in session.sink_digests(df.name).items()}
            for df in dags
        }
        session.close()
        print(f"  {name:10s}: {ms[name]:8.2f} ms/step")
    for name in ("threads", "multiproc"):
        assert counts[name] == counts["sync"], f"{name} diverged from sync sink counts"
    return ms


def _dryrun_roofline(dags, base_batch: int) -> Dict[str, float]:
    """Makespan model of the deployment, calibrated from a short jit run."""
    from repro.ops.costs import fit_latency_model

    cal = ReuseSession(strategy="signature", execute=True, backend="inprocess",
                       base_batch=base_batch, step_mode="sync")
    for df in dags:
        cal.submit(df.copy())
    cal.run(2)
    cal._system.backend.reports.clear()
    cal.run(5)
    model = fit_latency_model(cal._system.backend.latency_samples())
    cal.close()
    dry = {}
    for mode in ("sync", "concurrent"):
        s = ReuseSession(strategy="signature", execute=True, backend="dryrun",
                         base_batch=base_batch, step_mode=mode)
        s._system.backend.calibrate(model)
        for df in dags:
            s.submit(df.copy())
        dry[mode] = s.run(1)[0].makespan_ms
        s.close()
    return dry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chains", type=int, default=8)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dispatch-batch", type=int, default=64,
                    help="base_batch for the dispatch-bound (GIL-cap) regime")
    ap.add_argument("--compute-batch", type=int, default=8192,
                    help="base_batch for the compute-bound regime")
    ap.add_argument("--workers", type=int, default=0, help="multiproc pool (0 = cpu count)")
    ap.add_argument("--out", default=os.path.join("results", "benchmarks", "BENCH_pr5.json"))
    args = ap.parse_args(argv)

    workers = args.workers or (os.cpu_count() or 2)
    dags = _chains(args.chains, args.depth)

    ceiling = host_parallel_ceiling(workers)
    print(f"host: {os.cpu_count()} cpus, effective parallel ceiling ×{ceiling:.2f} "
          f"for {workers} processes")

    print(f"dispatch-bound regime (batch {args.dispatch_batch}):")
    disp = _measure_regime(dags, args.dispatch_batch, args.steps, workers)
    print(f"compute-bound regime (batch {args.compute_batch}):")
    comp = _measure_regime(dags, args.compute_batch, args.steps, workers)
    dry = _dryrun_roofline(dags, args.compute_batch)

    record = {
        "bench": "distributed_data_plane",
        "deployment": {
            "chains": args.chains, "depth": args.depth, "steps": args.steps,
        },
        "host_cpus": os.cpu_count(),
        "host_parallel_ceiling": round(ceiling, 2),
        "workers": workers,
        "transport": "shm",
        "dispatch_bound": {
            "base_batch": args.dispatch_batch,
            "sync_ms_per_step": round(disp["sync"], 2),
            "threads_ms_per_step": round(disp["threads"], 2),
            "multiproc_ms_per_step": round(disp["multiproc"], 2),
            "threads_speedup_vs_sync": round(disp["sync"] / disp["threads"], 2),
            "multiproc_speedup_vs_sync": round(disp["sync"] / disp["multiproc"], 2),
            "multiproc_speedup_vs_threads": round(disp["threads"] / disp["multiproc"], 2),
        },
        "compute_bound": {
            "base_batch": args.compute_batch,
            "sync_ms_per_step": round(comp["sync"], 2),
            "threads_ms_per_step": round(comp["threads"], 2),
            "multiproc_ms_per_step": round(comp["multiproc"], 2),
            "threads_speedup_vs_sync": round(comp["sync"] / comp["threads"], 2),
            "multiproc_speedup_vs_sync": round(comp["sync"] / comp["multiproc"], 2),
            "multiproc_speedup_vs_threads": round(comp["threads"] / comp["multiproc"], 2),
        },
        "dryrun_makespan_sync_ms": round(dry["sync"], 2),
        "dryrun_makespan_concurrent_ms": round(dry["concurrent"], 2),
        "dryrun_makespan_ratio": round(dry["sync"] / max(dry["concurrent"], 1e-12), 2),
        "sink_counts_identical": True,
    }
    print(
        f"\ndispatch-bound: threads ×{record['dispatch_bound']['threads_speedup_vs_sync']} vs sync "
        f"(GIL-capped), multiproc ×{record['dispatch_bound']['multiproc_speedup_vs_sync']} "
        f"(×{record['dispatch_bound']['multiproc_speedup_vs_threads']} over threads)\n"
        f"compute-bound: threads ×{record['compute_bound']['threads_speedup_vs_sync']}, "
        f"multiproc ×{record['compute_bound']['multiproc_speedup_vs_sync']} "
        f"(host ceiling ×{record['host_parallel_ceiling']}); "
        f"dryrun roofline ×{record['dryrun_makespan_ratio']} on unlimited hardware"
    )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(stamp(record), f, indent=1)
    print(f"wrote {args.out}")
    # The PR acceptance bar: where the GIL is the binding constraint,
    # worker processes must beat the threaded plane's ms/step. Exit code 2
    # is reserved for missing the bar (so CI smokes on noisy shared
    # runners can tolerate it while still failing hard on crashes).
    if record["dispatch_bound"]["multiproc_ms_per_step"] >= record["dispatch_bound"]["threads_ms_per_step"]:
        print("WARNING: multiproc did not beat threaded concurrent stepping "
              "in the dispatch-bound regime")
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
