"""Hot-path roofline — zero-copy fetch, chain batching, segment fusion
(PR 8 acceptance numbers, written to BENCH_pr8.json).

Three sections, matching the three compounding hot-path changes:

  * **fetch**  — raw ``ShmTransport`` fetch cost across payload sizes,
    view (zero-copy, the new default) vs ``copy=True`` (the escape
    hatch). The bar: view-fetch cost is flat in payload size — it is a
    header decode + ``np.frombuffer`` over the mmap, no memcpy.
  * **chain** — a deep stack of same-worker segments (each submission
    extends the previous chain by one kalman stage, so the multiproc
    coordinator sees a 12-deep linear segment chain on one worker),
    stepped unbatched (one RPC per *wave*), chained (one ``step_chain``
    RPC per *step*), and chained+fused (the whole chain recompiled into
    one donated-buffer segment). The bar: chained ≥ ×1.5 step throughput
    over unbatched, fused at least as good as chained.
  * **trace** — the full OPMW rw1 churn trace replayed with and without
    periodic ``fuse()`` in both step modes; sink digests must be
    bit-identical (counts AND checksums).

Any missed bar exits 2 (the CI contract); ``--smoke`` shrinks the trace
section for the CI job while keeping every bar armed.

Usage:
    PYTHONPATH=src python benchmarks/hotpath_bench.py \
        [--depth 12] [--steps 30] [--smoke] \
        [--out results/benchmarks/BENCH_pr8.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


# -- section 1: zero-copy fetch cost ------------------------------------------


def bench_fetch(sizes=(64, 1024, 16384, 131072), reps: int = 400) -> Dict[str, Any]:
    from repro.runtime.transport import ShmTransport

    t = ShmTransport()
    rows: List[Dict[str, Any]] = []
    try:
        for n in sizes:
            topic = f"stream/fetch{n}"
            batch = np.random.default_rng(7).random((n, 8)).astype(np.float32)
            t.publish(topic, batch)
            t.fetch(topic)  # attach + warm
            best = {"view": float("inf"), "copy": float("inf")}
            for mode, copy in (("view", False), ("copy", True)):
                for _ in range(5):
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        t.fetch(topic, copy=copy)
                    best[mode] = min(best[mode], (time.perf_counter() - t0) / reps)
            rows.append(
                {
                    "rows": n,
                    "nbytes": int(batch.nbytes),
                    "view_us": round(1e6 * best["view"], 3),
                    "copy_us": round(1e6 * best["copy"], 3),
                }
            )
    finally:
        t.close()
    # flatness: the largest payload's view fetch within 5x the smallest's
    # (both are O(1); the factor absorbs scheduler jitter on tiny times)
    vmin, vmax = rows[0]["view_us"], rows[-1]["view_us"]
    flat = vmax <= max(5.0 * vmin, vmin + 20.0)
    return {
        "rows": rows,
        "view_flat_in_size": bool(flat),
        "copy_over_view_at_largest": round(rows[-1]["copy_us"] / rows[-1]["view_us"], 2),
    }


# -- section 2: deep same-worker chain ----------------------------------------


def _stacked_chain_dags(depth: int):
    """dag k = source → kalman_1..k → sink_k; signature reuse makes each
    submission one new segment (kalman_k + sink_k) downstream of the
    previous — a depth-deep linear segment chain."""
    from repro.api import flow

    dags = []
    for k in range(1, depth + 1):
        b = flow(f"deep{k:02d}").source("sensor")
        for i in range(k):
            b.then("kalman", q=0.1, stage=i)
        dags.append(b.sink("store").build())
    return dags


def _bench_chain_plane(dags, steps: int, fuse: bool, chain_batching: bool,
                       base_batch: int, windows: int = 5):
    from repro.api import ReuseSession

    session = ReuseSession(
        strategy="signature",
        execute=True,
        base_batch=base_batch,
        backend="multiproc",
        workers=1,  # one worker = the whole chain is worker-local
        step_mode="concurrent",
        backend_options={"chain_batching": chain_batching},
    )
    for df in dags:
        session.submit(df.copy())
    if fuse:
        session.fuse()
    session.run(2)  # compile + warm
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        session.run(steps)
        best = min(best, (time.perf_counter() - t0) / steps)
    digests = {
        df.name: session.sink_digests(df.name) for df in dags
    }
    segments = len(session._system.backend.segments)
    session.close()
    return 1e3 * best, digests, segments


def bench_chain(depth: int, steps: int, base_batch: int = 64) -> Dict[str, Any]:
    dags = _stacked_chain_dags(depth)
    ms: Dict[str, float] = {}
    digests: Dict[str, Any] = {}
    segs: Dict[str, int] = {}
    for name, (fuse, chain) in {
        "unbatched": (False, False),
        "chained": (False, True),
        "chained_fused": (True, True),
    }.items():
        ms[name], digests[name], segs[name] = _bench_chain_plane(
            dags, steps, fuse, chain, base_batch
        )
        print(f"  {name:14s}: {ms[name]:8.2f} ms/step  ({segs[name]} segments)")
    identical = digests["chained"] == digests["unbatched"] == digests["chained_fused"]
    return {
        "depth": depth,
        "steps": steps,
        "base_batch": base_batch,
        "segments": segs,
        "ms_per_step": {k: round(v, 3) for k, v in ms.items()},
        "chained_speedup": round(ms["unbatched"] / ms["chained"], 2),
        "fused_speedup": round(ms["unbatched"] / ms["chained_fused"], 2),
        "digests_identical": bool(identical),
    }


# -- section 3: OPMW rw1 fused-vs-unfused identity ----------------------------


def bench_trace(step_modes=("sync", "concurrent"), max_events: int = 0) -> Dict[str, Any]:
    from repro.api import ReuseSession
    from repro.workloads import opmw_workload, replay, rw_trace

    dags = opmw_workload()
    events = rw_trace(dags, seed=11)  # the rw1 trace (seed convention)
    if max_events:
        events = events[:max_events]
    out: Dict[str, Any] = {"events": len(events), "modes": {}}
    for mode in step_modes:
        runs = {}
        for fuse in (False, True):
            session = ReuseSession(execute=True, backend="inprocess", step_mode=mode)
            fused_total = 0
            for i, _ in enumerate(replay(session, dags, events)):
                session.step()
                if fuse and i % 5 == 4:
                    fused_total += len(session.fuse())
            session.run(2)
            runs[fuse] = {
                n: session.sink_digests(n) for n in sorted(session.manager.submitted)
            }
            if fuse:
                out["modes"].setdefault(mode, {})["fuse_calls_nonempty"] = fused_total
            session.close()
        identical = runs[True] == runs[False]
        out["modes"].setdefault(mode, {})["digests_identical"] = bool(identical)
        print(f"  {mode:10s}: fused == unfused -> {identical}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--depth", type=int, default=12)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--base-batch", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: truncate the OPMW trace section")
    ap.add_argument("--out", default=os.path.join("results", "benchmarks", "BENCH_pr8.json"))
    args = ap.parse_args(argv)

    print("zero-copy shm fetch (view vs copy):")
    fetch = bench_fetch()
    for r in fetch["rows"]:
        print(f"  {r['rows']:7d} rows ({r['nbytes']:>9d} B): "
              f"view {r['view_us']:8.2f} us   copy {r['copy_us']:8.2f} us")
    print(f"  view flat in size: {fetch['view_flat_in_size']}  "
          f"(copy/view at largest: x{fetch['copy_over_view_at_largest']})")

    print(f"deep same-worker chain (depth {args.depth}, batch {args.base_batch}):")
    chain = bench_chain(args.depth, args.steps, args.base_batch)
    print(f"  chained speedup x{chain['chained_speedup']}  "
          f"fused speedup x{chain['fused_speedup']}")

    print("OPMW rw1 trace, fused vs unfused:" + ("  [smoke]" if args.smoke else ""))
    trace = bench_trace(max_events=30 if args.smoke else 0)

    bars = {
        "fetch_view_flat": fetch["view_flat_in_size"],
        "chained_speedup_ge_1_5": chain["chained_speedup"] >= 1.5,
        "chain_digests_identical": chain["digests_identical"],
        "trace_digests_identical": all(
            m["digests_identical"] for m in trace["modes"].values()
        ),
    }
    record = stamp(
        {
            "bench": "hotpath",
            "smoke": bool(args.smoke),
            "fetch": fetch,
            "chain": chain,
            "trace": trace,
            "bars": bars,
            "all_bars_met": all(bars.values()),
        }
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    if not record["all_bars_met"]:
        print(f"ACCEPTANCE BARS MISSED: {[k for k, v in bars.items() if not v]}")
        return 2
    print("all acceptance bars met")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
