"""Paper Figs. 2/3/4 — running task count, cumulative core usage, and the
reuse histogram over the 6 traces (OPMW/RIoT × SEQ/RW1/RW2).

Default (no reuse) vs Reuse (signature strategy) run through the
`repro.api.ReuseSession` control plane (pause accounting rides the
session's ``on_unmerge`` hook); core usage uses the calibrated cost model
(cost_weight per task type × CORES_PER_UNIT, paused tasks at
PAUSE_FRACTION — the §5.3 observation that 274 paused tasks ≈ 7.5 cores
while 471 active ≈ 74).

``--backend NAME`` instead drives the traces through a real
ExecutionBackend data plane (``dryrun`` / ``inprocess`` / ``sharded``):
every event deploys/pauses segments and the per-event live/paused/cost
series come from the backend's own accounting. ``--backend dryrun`` sweeps
a full trace in milliseconds (no JAX) and is the CI smoke for backend
regressions; the jit backends additionally move real event batches.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import Counter
from typing import Dict, List, Optional

from repro.api import ReuseSession
from repro.ops.costs import cost_weight_for_task
from repro.workloads import opmw_workload, replay, riot_workload, rw_trace, seq_trace

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp

CORES_PER_UNIT = 0.157   # calibrated: 471 π tasks ≈ 74 cores (paper §5.3)
PAUSE_FRACTION = 0.17    # 274 paused ≈ 7.5 cores ⇒ ~0.027 / 0.157

_COST_CACHE: Dict[tuple, float] = {}


def _task_cost(task) -> float:
    key = (task.type, task.config)
    if key not in _COST_CACHE:
        _COST_CACHE[key] = cost_weight_for_task(task)
    return _COST_CACHE[key]


def run_trace_with_pause(dags, events) -> Dict[str, List]:
    """Control-plane trace with the paper's pause accounting.

    Paused (deployed-but-terminated) tasks are pooled **by equivalence
    class** (Merkle signature): the pool is bounded by the number of
    distinct classes ever deployed — matching §5.3's "all 274 tasks that
    were once running … consume 7.5 cores". A class leaves the pool when
    an equivalent task is running again (physically: the manager resumes
    the paused task instead of deploying a fresh copy).
    """
    from repro.core.signatures import compute_signatures

    default = ReuseSession(strategy="none")
    reuse = ReuseSession(strategy="signature")
    paused: Dict[str, float] = {}           # class signature -> cost
    sig_of_rid: Dict[str, str] = {}
    task_cost_by_rid: Dict[str, float] = {}

    @reuse.on_unmerge
    def _pool_terminated(ev) -> None:
        # terminated tasks join the paused pool, keyed by equivalence class
        for tid in ev.terminated_tasks:
            paused[sig_of_rid.get(tid, tid)] = task_cost_by_rid.get(tid, 1.0)

    series = {
        "default_tasks": [], "reuse_tasks": [],
        "default_cores": [], "reuse_cores": [], "reuse_cores_defrag": [],
        "reuse_hist": [],
    }
    # The two sessions replay the same trace in lockstep; the reuse session's
    # on_unmerge hook pools terminated tasks as they happen.
    lockstep = zip(replay(default, dags, events), replay(reuse, dags, events))
    for (ev, _), _ in lockstep:
        if ev.op == "add":
            for df in reuse.manager.running.values():
                sigs = compute_signatures(df)
                for tid, t in df.tasks.items():
                    task_cost_by_rid.setdefault(tid, _task_cost(t))
                    sig_of_rid.setdefault(tid, sigs[tid])

        d_tasks = sum(len(df) for df in default.manager.running.values())
        d_cores = CORES_PER_UNIT * sum(
            _task_cost(t)
            for df in default.manager.running.values()
            for t in df.tasks.values()
        )
        running_sigs = {
            sig_of_rid[tid] for df in reuse.manager.running.values() for tid in df.tasks
        }
        for sig in list(paused):
            if sig in running_sigs:
                del paused[sig]
        r_tasks = reuse.running_task_count
        r_active_cores = CORES_PER_UNIT * sum(
            _task_cost(t) for df in reuse.manager.running.values() for t in df.tasks.values()
        )
        r_cores = r_active_cores + CORES_PER_UNIT * PAUSE_FRACTION * sum(paused.values())

        mult = Counter()
        for sub, tmap in reuse.manager.task_maps.items():
            for rid in set(tmap.values()):
                mult[rid] += 1
        hist = Counter(v for v in mult.values())
        series["default_tasks"].append(d_tasks)
        series["reuse_tasks"].append(r_tasks)
        series["default_cores"].append(round(d_cores, 2))
        series["reuse_cores"].append(round(r_cores, 2))
        # beyond-paper: periodic defragmentation relaunches fused DAGs and
        # frees paused tasks — its core usage is the active set only
        series["reuse_cores_defrag"].append(round(r_active_cores, 2))
        series["reuse_hist"].append({str(k): v for k, v in hist.items()})
    return series


def summarize(series: Dict[str, List], drain_start: int | None = None) -> Dict[str, float]:
    dt, rt = series["default_tasks"], series["reuse_tasks"]
    dc, rc = series["default_cores"], series["reuse_cores"]
    rcd = series["reuse_cores_defrag"]
    peak_i = max(range(len(dt)), key=lambda i: dt[i])
    live = [i for i in range(len(dt)) if dt[i] > 0]
    task_red = [1 - rt[i] / dt[i] for i in live]
    # the paper's headline metric: *cumulative* CPU over the whole trace
    cum_red = 1 - sum(rc) / max(sum(dc), 1e-9)
    cum_red_defrag = 1 - sum(rcd) / max(sum(dc), 1e-9)
    # the paper reports RW medians over the *walk* phase (pre-drain)
    w = drain_start if drain_start is not None else len(dc)
    cum_red_walk = 1 - sum(rc[:w]) / max(sum(dc[:w]), 1e-9)
    # the §5.3 pause-overhead crossover: steps where Reuse > Default cores
    crossover = sum(1 for i in range(len(dc)) if rc[i] > dc[i] and dc[i] > 0)
    # time-weighted reuse histogram (fraction of running tasks shared >1)
    tot = shared = 0
    for h in series["reuse_hist"]:
        for mult, cnt in h.items():
            tot += cnt
            if int(mult) > 1:
                shared += cnt
    return {
        "peak_default_tasks": dt[peak_i],
        "peak_reuse_tasks": rt[peak_i],
        "peak_task_reduction": round(1 - rt[peak_i] / dt[peak_i], 3),
        "mean_task_reduction": round(sum(task_red) / len(task_red), 3),
        "peak_default_cores": dc[peak_i],
        "peak_reuse_cores": rc[peak_i],
        "peak_core_reduction": round(1 - rc[peak_i] / dc[peak_i], 3),
        "cum_core_reduction": round(cum_red, 3),
        "cum_core_reduction_walk": round(cum_red_walk, 3),
        "cum_core_reduction_defrag": round(cum_red_defrag, 3),
        "crossover_steps": crossover,
        "frac_tasks_shared": round(shared / max(tot, 1), 3),
    }


def run_trace_on_backend(
    dags, events, backend: str, step_mode: Optional[str] = None,
    max_workers: Optional[int] = None,
) -> Dict[str, List]:
    """Drive one trace through a real ExecutionBackend data plane.

    Default (no reuse) and Reuse (signature) sessions replay the trace in
    lockstep; after every event each data plane steps once and the series
    record the *backend's own* live/paused/cost accounting — the same
    counters for every backend (the ExecutionBackend contract), which is
    what makes ``--backend dryrun`` a faithful millisecond-scale stand-in
    for the jit planes. ``step_mode="concurrent"`` routes every step
    through the dependency-aware wave pipeline; the counters are
    mode-invariant by contract (tests/test_concurrent.py asserts it on
    this exact trace), so a concurrent run reproduces the sync series.
    """
    default = ReuseSession(
        strategy="none", execute=True, backend=backend,
        step_mode=step_mode, max_workers=max_workers,
    )
    reuse = ReuseSession(
        strategy="signature", execute=True, backend=backend,
        step_mode=step_mode, max_workers=max_workers,
    )
    series: Dict[str, List] = {
        "default_tasks": [], "reuse_tasks": [],
        "default_paused": [], "reuse_paused": [],
        "default_cores": [], "reuse_cores": [],
    }
    lockstep = zip(replay(default, dags, events), replay(reuse, dags, events))
    for _ in lockstep:
        d = default.step()
        r = reuse.step()
        series["default_tasks"].append(d.live_tasks)
        series["reuse_tasks"].append(r.live_tasks)
        series["default_paused"].append(d.paused_tasks)
        series["reuse_paused"].append(r.paused_tasks)
        series["default_cores"].append(round(d.cost, 4))
        series["reuse_cores"].append(round(r.cost, 4))
    default.close()  # release the concurrent dispatch pools
    reuse.close()
    return series


def summarize_backend(series: Dict[str, List]) -> Dict[str, float]:
    dt, rt = series["default_tasks"], series["reuse_tasks"]
    dc, rc = series["default_cores"], series["reuse_cores"]
    peak_i = max(range(len(dt)), key=lambda i: dt[i])
    return {
        "peak_default_tasks": dt[peak_i],
        "peak_reuse_tasks": rt[peak_i],
        "peak_task_reduction": round(1 - rt[peak_i] / max(dt[peak_i], 1), 3),
        "cum_core_reduction": round(1 - sum(rc) / max(sum(dc), 1e-9), 3),
        "peak_reuse_paused": max(series["reuse_paused"]),
    }


def main(
    out_dir: str = "results/benchmarks",
    backend: Optional[str] = None,
    workloads_filter: Optional[List[str]] = None,
    traces_filter: Optional[List[str]] = None,
    step_mode: Optional[str] = None,
    max_workers: Optional[int] = None,
) -> Dict[str, Dict]:
    os.makedirs(out_dir, exist_ok=True)
    if workloads_filter and (bad := set(workloads_filter) - {"opmw", "riot"}):
        raise SystemExit(f"unknown --workloads {sorted(bad)} (choose from opmw, riot)")
    if traces_filter and (bad := set(traces_filter) - {"seq", "rw1", "rw2"}):
        raise SystemExit(f"unknown --traces {sorted(bad)} (choose from seq, rw1, rw2)")
    workloads = {"opmw": opmw_workload(), "riot": riot_workload()}
    if workloads_filter:
        workloads = {k: v for k, v in workloads.items() if k in workloads_filter}
    out: Dict[str, Dict] = {}
    for wname, dags in workloads.items():
        traces = {
            "seq": seq_trace(dags, seed=3),
            "rw1": rw_trace(dags, seed=11),
            "rw2": rw_trace(dags, seed=23),
        }
        if traces_filter:
            traces = {k: v for k, v in traces.items() if k in traces_filter}
        for tname, events in traces.items():
            t0 = time.time()
            if backend:
                series = run_trace_on_backend(
                    dags, events, backend, step_mode=step_mode, max_workers=max_workers
                )
                s = summarize_backend(series)
                s["backend"] = backend
                s["step_mode"] = step_mode or "sync"
                s["wall_s"] = round(time.time() - t0, 3)
                out[f"{wname}_{tname}"] = s
                suffix = "" if (step_mode or "sync") == "sync" else f"_{step_mode}"
                path = os.path.join(
                    out_dir, f"backend_{backend}_{wname}_{tname}{suffix}.json"
                )
                with open(path, "w") as f:
                    json.dump(stamp({"series": series, "summary": s}), f, indent=1)
                print(
                    f"{wname}/{tname} [{backend}]: peak tasks "
                    f"{s['peak_default_tasks']}→{s['peak_reuse_tasks']} "
                    f"(−{s['peak_task_reduction']:.0%}), cores "
                    f"−{s['cum_core_reduction']:.0%} cum, peak paused "
                    f"{s['peak_reuse_paused']}  [{s['wall_s']}s]"
                )
                continue
            drain_start = len(dags) if tname == "seq" else (2 * len(dags)) // 3 + 100
            series = run_trace_with_pause(dags, events)
            s = summarize(series, drain_start=drain_start)
            s["wall_s"] = round(time.time() - t0, 2)
            out[f"{wname}_{tname}"] = s
            with open(os.path.join(out_dir, f"fig2_3_4_{wname}_{tname}.json"), "w") as f:
                json.dump(stamp({"series": series, "summary": s}), f, indent=1)
            print(
                f"{wname}/{tname}: peak tasks {s['peak_default_tasks']}→"
                f"{s['peak_reuse_tasks']} (−{s['peak_task_reduction']:.0%}), "
                f"cores −{s['peak_core_reduction']:.0%} peak / "
                f"−{s['cum_core_reduction_walk']:.0%} walk / "
                f"−{s['cum_core_reduction']:.0%} cum "
                f"(defrag −{s['cum_core_reduction_defrag']:.0%}), "
                f"crossover {s['crossover_steps']} steps, "
                f"shared>1 {s['frac_tasks_shared']:.0%}  [{s['wall_s']}s]"
            )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--backend",
        help="run traces through this ExecutionBackend (dryrun/inprocess/sharded) "
        "instead of the control-plane cost model",
    )
    ap.add_argument("--workloads", help="comma list, e.g. opmw,riot")
    ap.add_argument("--traces", help="comma list, e.g. seq,rw1,rw2")
    ap.add_argument(
        "--step-mode", choices=("sync", "concurrent"), default=None,
        help="stepping pipeline for --backend runs (counters are mode-invariant)",
    )
    ap.add_argument("--max-workers", type=int, default=None)
    ap.add_argument("--out-dir", default="results/benchmarks")
    args = ap.parse_args()
    main(
        out_dir=args.out_dir,
        backend=args.backend,
        workloads_filter=args.workloads.split(",") if args.workloads else None,
        traces_filter=args.traces.split(",") if args.traces else None,
        step_mode=args.step_mode,
        max_workers=args.max_workers,
    )
