"""Defragmentation benefit on the *real* data plane (the paper's
future-work, implemented) — any ExecutionBackend via ``--backend``.

Runs a RIoT subset through StreamSystem with reuse: submit, remove some
(creating paused tasks + broker-linked partial segments), then measure
steady-state step wall-time and segment/broker-hop counts before and
after ``defragment()``. Sink digests are asserted identical across the
defrag (state-preserving relaunch; on the dry-run backend only counts
are meaningful — checksums are jit-only).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from repro.runtime.system import StreamSystem
from repro.workloads import riot_workload

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def _steady_ms(system: StreamSystem, steps: int = 30) -> float:
    system.run(3)  # warm the jit caches
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        system.step()
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1e3 * times[len(times) // 2]  # median


def main(out_dir: str = "results/benchmarks", backend: str = "inprocess") -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    dags = [d for d in riot_workload() if d.name.startswith(("urban", "meter"))]
    sys_ = StreamSystem(strategy="signature", base_batch=8, backend=backend)
    for d in dags:
        sys_.submit(d.copy())
    # remove a third — pausing tasks, fragmenting segments
    removed = [d.name for i, d in enumerate(dags) if i % 3 == 0]
    for name in removed:
        sys_.remove(name)
    live = [d.name for d in dags if d.name not in removed]

    before = {
        "segments": len(sys_.backend.segments),
        "deployed_tasks": sys_.deployed_task_count,
        "running_tasks": sys_.running_task_count,
        "broker_topics": len(getattr(sys_.backend, "forwarding", [])),
        "step_ms": round(_steady_ms(sys_), 2),
    }
    digests_before = {n: sys_.sink_digests(n) for n in live}

    killed = sys_.defragment()
    after = {
        "segments": len(sys_.backend.segments),
        "deployed_tasks": sys_.deployed_task_count,
        "running_tasks": sys_.running_task_count,
        "step_ms": round(_steady_ms(sys_), 2),
        "segments_killed": killed,
    }
    # run on; outputs must continue coherently (counts advance, no resets)
    sys_.run(3)
    digests_after = {n: sys_.sink_digests(n) for n in live}
    for n in live:
        for sink, st in digests_after[n].items():
            assert st["count"] >= digests_before[n][sink]["count"], (n, sink)

    out = {
        "backend": backend,
        "before": before,
        "after": after,
        "deployed_task_drop": before["deployed_tasks"] - after["deployed_tasks"],
        "step_speedup": round(before["step_ms"] / max(after["step_ms"], 1e-9), 2),
    }
    print(
        f"defrag: segments {before['segments']}→{after['segments']}, deployed "
        f"tasks {before['deployed_tasks']}→{after['deployed_tasks']}, "
        f"step {before['step_ms']:.1f}→{after['step_ms']:.1f} ms "
        f"(×{out['step_speedup']:.2f})"
    )
    suffix = "" if backend == "inprocess" else f"_{backend}"
    with open(os.path.join(out_dir, f"defrag_benefit{suffix}.json"), "w") as f:
        json.dump(stamp(out), f, indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="inprocess", help="ExecutionBackend registry name")
    ap.add_argument("--out-dir", default="results/benchmarks")
    args = ap.parse_args()
    main(out_dir=args.out_dir, backend=args.backend)
