"""Locality-aware fusion planner + compiled-segment reuse cache
(PR 9 acceptance numbers, written to BENCH_pr9.json).

Four sections, matching the four compounding optimizer changes:

  * **cross_worker** — a 12-deep linear segment chain spread over 4
    workers (each submission extends the previous chain by one kalman
    stage; round-robin placement puts consecutive segments on different
    workers, so every hop crosses a process boundary). ``fuse()``
    migrates the whole chain onto one worker and recompiles it into one
    donated-buffer segment. The bar: fused ≥ ×2 step throughput over
    unfused, with bit-identical sink digests.
  * **cache** — the OPMW rw1 churn trace under the Default ("none")
    strategy, where every submission deploys its own segments: the
    compiled-segment reuse cache is what keeps resubmissions and
    structurally overlapping submissions from paying XLA again. The
    bars: end-of-trace hit rate ≥ 0.5, and cache-hit submissions land
    (submit + first step) faster than cold-compile submissions.
  * **wide_wave** — 8 parallel two-segment chains balanced over 4
    workers. Consolidating them all onto the cheapest worker would
    serialize a wide wave; the wave-aware planner must keep step time
    from regressing (≤ ×1.25 of unfused) while still taking whatever
    fusions are free.
  * **trace** — the full OPMW rw1 trace replayed with and without
    periodic ``fuse()`` (now wave-scored, with the peephole pallas
    kernels active on fused segments) in both step modes; sink digests
    must be bit-identical.

Any missed bar exits 2 (the CI contract); ``--smoke`` shrinks the trace
sections for the CI job while keeping every bar armed.

Usage:
    PYTHONPATH=src python benchmarks/fusion_optimizer_bench.py \
        [--depth 12] [--steps 30] [--smoke] \
        [--out results/benchmarks/BENCH_pr9.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


# -- section 1: cross-worker chain fusion --------------------------------------


def _stacked_chain_dags(depth: int):
    """dag k = source → kalman_1..k → sink_k; signature reuse makes each
    submission one new segment downstream of the previous — a depth-deep
    linear segment chain, placed round-robin across the workers."""
    from repro.api import flow

    dags = []
    for k in range(1, depth + 1):
        b = flow(f"deep{k:02d}").source("sensor")
        for i in range(k):
            b.then("kalman", q=0.1, stage=i)
        dags.append(b.sink("store").build())
    return dags


def _bench_cross_worker_plane(dags, steps: int, fuse: bool, workers: int,
                              base_batch: int, windows: int = 5):
    from repro.api import ReuseSession

    session = ReuseSession(
        strategy="signature",
        execute=True,
        base_batch=base_batch,
        backend="multiproc",
        workers=workers,
        transport="shm",
        step_mode="concurrent",
        backend_options={"chain_batching": True},
    )
    for df in dags:
        session.submit(df.copy())
    backend = session._system.backend
    spread_before = len(set(backend.device_of.values()))
    session.run(2)  # compile + warm (also feeds the latency model)
    report = None
    if fuse:
        session.fuse()
        report = session.fusion_report.to_dict() if session.fusion_report else None
    session.run(2)  # warm the (possibly recompiled) plane — equal step counts
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        session.run(steps)
        best = min(best, (time.perf_counter() - t0) / steps)
    digests = {df.name: session.sink_digests(df.name) for df in dags}
    spread_after = len(set(backend.device_of.values()))
    segments = len(backend.segments)
    session.close()
    return 1e3 * best, digests, {
        "segments": segments,
        "workers_occupied_before": spread_before,
        "workers_occupied_after": spread_after,
        "fusion_report": report,
    }


def bench_cross_worker(depth: int, steps: int, workers: int = 4,
                       base_batch: int = 64) -> Dict[str, Any]:
    dags = _stacked_chain_dags(depth)
    unfused_ms, unfused_digests, unfused_info = _bench_cross_worker_plane(
        dags, steps, False, workers, base_batch
    )
    print(f"  unfused: {unfused_ms:8.2f} ms/step  "
          f"({unfused_info['segments']} segments on "
          f"{unfused_info['workers_occupied_after']} workers)")
    fused_ms, fused_digests, fused_info = _bench_cross_worker_plane(
        dags, steps, True, workers, base_batch
    )
    print(f"  fused  : {fused_ms:8.2f} ms/step  "
          f"({fused_info['segments']} segments on "
          f"{fused_info['workers_occupied_after']} workers)")
    return {
        "depth": depth,
        "steps": steps,
        "workers": workers,
        "base_batch": base_batch,
        "ms_per_step": {"unfused": round(unfused_ms, 3), "fused": round(fused_ms, 3)},
        "unfused": unfused_info,
        "fused": fused_info,
        "fused_speedup": round(unfused_ms / fused_ms, 2),
        "digests_identical": bool(fused_digests == unfused_digests),
    }


# -- section 2: compiled-segment reuse cache under churn -----------------------


def bench_cache(max_events: int = 0) -> Dict[str, Any]:
    from repro.api import ReuseSession
    from repro.workloads import opmw_workload, rw_trace

    dags = opmw_workload()
    by_name = {d.name: d for d in dags}
    events = rw_trace(dags, seed=11)  # the rw1 trace (seed convention)
    if max_events:
        events = events[:max_events]
    session = ReuseSession(strategy="none", execute=True, backend="inprocess")
    miss_lat: List[float] = []
    hit_lat: List[float] = []
    prev_misses = 0
    for ev in events:
        if ev.op == "remove":
            session.remove(ev.name)
            continue
        t0 = time.perf_counter()
        session.submit(by_name[ev.name].copy())
        session.step()  # first step = trace/compile (or cache hit) + run
        dt = 1e3 * (time.perf_counter() - t0)
        misses = session.stats().compile_cache_misses
        (miss_lat if misses > prev_misses else hit_lat).append(dt)
        prev_misses = misses
    st = session.stats()
    session.close()
    total = st.compile_cache_hits + st.compile_cache_misses
    hit_rate = st.compile_cache_hits / total if total else 0.0
    cold_ms = sum(miss_lat) / len(miss_lat) if miss_lat else 0.0
    warm_ms = sum(hit_lat) / len(hit_lat) if hit_lat else float("inf")
    print(f"  {len(events)} events: {st.compile_cache_hits} hits / "
          f"{st.compile_cache_misses} misses (rate {hit_rate:.2f})")
    print(f"  submit+step: cold {cold_ms:8.2f} ms   warm {warm_ms:8.2f} ms")
    return {
        "events": len(events),
        "hits": st.compile_cache_hits,
        "misses": st.compile_cache_misses,
        "evictions": st.compile_cache_evictions,
        "entries": st.compile_cache_entries,
        "hit_rate": round(hit_rate, 3),
        "cold_submit_step_ms": round(cold_ms, 3),
        "warm_submit_step_ms": round(warm_ms, 3),
        "warm_below_cold": bool(warm_ms < cold_ms),
    }


# -- section 3: wide wave — planner must not serialize parallel chains ---------


def _wide_wave_dags(chains: int):
    """chain c = two stacked submissions (base, extension): each pair
    becomes a two-segment private chain, independent of the others."""
    from repro.api import flow

    dags = []
    for c in range(chains):
        base = flow(f"wave{c:02d}a").source("sensor")
        base.then("kalman", q=0.1, lane=c)
        dags.append(base.sink("store").build())
        ext = flow(f"wave{c:02d}b").source("sensor")
        ext.then("kalman", q=0.1, lane=c)
        ext.then("kalman", q=0.2, lane=c)
        dags.append(ext.sink("store").build())
    return dags


def _bench_wave_plane(dags, steps: int, fuse: bool, workers: int,
                      base_batch: int, windows: int = 5):
    from repro.api import ReuseSession

    session = ReuseSession(
        strategy="signature",
        execute=True,
        base_batch=base_batch,
        backend="multiproc",
        workers=workers,
        transport="shm",
        step_mode="concurrent",
        backend_options={"chain_batching": True},
    )
    for df in dags:
        session.submit(df.copy())
    session.run(3)  # warm + latency samples for the planner's cost model
    report = None
    if fuse:
        session.fuse()
        report = session.fusion_report.to_dict() if session.fusion_report else None
    session.run(2)  # equal step counts on both planes (digest comparison)
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        session.run(steps)
        best = min(best, (time.perf_counter() - t0) / steps)
    digests = {df.name: session.sink_digests(df.name) for df in dags}
    session.close()
    return 1e3 * best, digests, report


def bench_wide_wave(chains: int, steps: int, workers: int = 4,
                    base_batch: int = 64) -> Dict[str, Any]:
    dags = _wide_wave_dags(chains)
    unfused_ms, unfused_digests, _ = _bench_wave_plane(
        dags, steps, False, workers, base_batch
    )
    fused_ms, fused_digests, report = _bench_wave_plane(
        dags, steps, True, workers, base_batch
    )
    accepted = len(report["accepted"]) if report else 0
    rejected = len(report["rejected"]) if report else 0
    ratio = fused_ms / unfused_ms
    print(f"  unfused: {unfused_ms:8.2f} ms/step   planner-on: {fused_ms:8.2f} "
          f"ms/step  (x{ratio:.2f}; {accepted} fused, {rejected} kept wide)")
    return {
        "chains": chains,
        "steps": steps,
        "workers": workers,
        "ms_per_step": {"unfused": round(unfused_ms, 3), "planner": round(fused_ms, 3)},
        "planner_over_unfused": round(ratio, 3),
        "chains_fused": accepted,
        "chains_kept_wide": rejected,
        "fusion_report": report,
        "digests_identical": bool(fused_digests == unfused_digests),
    }


# -- section 4: OPMW rw1 fused-vs-unfused identity -----------------------------


def bench_trace(step_modes=("sync", "concurrent"), max_events: int = 0) -> Dict[str, Any]:
    from repro.api import ReuseSession
    from repro.workloads import opmw_workload, replay, rw_trace

    dags = opmw_workload()
    events = rw_trace(dags, seed=11)
    if max_events:
        events = events[:max_events]
    out: Dict[str, Any] = {"events": len(events), "modes": {}}
    for mode in step_modes:
        runs = {}
        for fuse in (False, True):
            session = ReuseSession(execute=True, backend="inprocess", step_mode=mode)
            fused_total = 0
            for i, _ in enumerate(replay(session, dags, events)):
                session.step()
                if fuse and i % 5 == 4:
                    fused_total += len(session.fuse())
            session.run(2)
            runs[fuse] = {
                n: session.sink_digests(n) for n in sorted(session.manager.submitted)
            }
            if fuse:
                out["modes"].setdefault(mode, {})["fuse_calls_nonempty"] = fused_total
            session.close()
        identical = runs[True] == runs[False]
        out["modes"].setdefault(mode, {})["digests_identical"] = bool(identical)
        print(f"  {mode:10s}: fused == unfused -> {identical}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--depth", type=int, default=12)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--chains", type=int, default=8)
    ap.add_argument("--base-batch", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: truncate the trace-driven sections")
    ap.add_argument("--out", default=os.path.join("results", "benchmarks", "BENCH_pr9.json"))
    args = ap.parse_args(argv)
    steps = 10 if args.smoke else args.steps

    print(f"cross-worker chain fusion (depth {args.depth}, {args.workers} workers):")
    cross = bench_cross_worker(args.depth, steps, args.workers, args.base_batch)
    print(f"  fused speedup x{cross['fused_speedup']}")

    print("compiled-segment reuse cache (OPMW rw1, Default strategy):"
          + ("  [smoke]" if args.smoke else ""))
    cache = bench_cache(max_events=40 if args.smoke else 0)

    print(f"wide wave ({args.chains} chains over {args.workers} workers):")
    wave = bench_wide_wave(args.chains, steps, args.workers, args.base_batch)

    print("OPMW rw1 trace, fused vs unfused:" + ("  [smoke]" if args.smoke else ""))
    trace = bench_trace(max_events=30 if args.smoke else 0)

    bars = {
        "cross_worker_speedup_ge_2": cross["fused_speedup"] >= 2.0,
        "cross_worker_digests_identical": cross["digests_identical"],
        "cache_hit_rate_ge_0_5": cache["hit_rate"] >= 0.5,
        "cache_warm_below_cold": cache["warm_below_cold"],
        "wide_wave_no_regression": wave["planner_over_unfused"] <= 1.25,
        "wide_wave_digests_identical": wave["digests_identical"],
        "trace_digests_identical": all(
            m["digests_identical"] for m in trace["modes"].values()
        ),
    }
    record = stamp(
        {
            "bench": "fusion_optimizer",
            "smoke": bool(args.smoke),
            "cross_worker": cross,
            "cache": cache,
            "wide_wave": wave,
            "trace": trace,
            "bars": bars,
            "all_bars_met": all(bars.values()),
        }
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    if not record["all_bars_met"]:
        print(f"ACCEPTANCE BARS MISSED: {[k for k, v in bars.items() if not v]}")
        return 2
    print("all acceptance bars met")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
