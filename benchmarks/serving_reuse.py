"""Multi-tenant LM reuse-serving (beyond-paper): the paper's merge
applied to tenant pipelines sharing backbone prefixes. Reports running
tasks + deployed cost + measured step wall-time, Default vs Reuse, and
asserts bit-identical tenant outputs.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

from repro.serve import ReuseServing, TenantPipeline

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def _build(strategy: str, tenants: int):
    rs = ReuseServing(strategy=strategy, base_batch=4)
    for i in range(tenants):
        rs.add_tenant(
            TenantPipeline(
                tenant=f"t{i}",
                stream=("urban", "meter", "taxi")[i % 3],
                shared_stages=3,
                n_stages=4,
                d=64,
                layers_per_stage=4,
                adapter=f"adapter-{i}",
            )
        )
    return rs


def main(out_dir: str = "results/benchmarks", tenants: int = 9) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    out: Dict[str, Dict] = {}
    systems = {}
    for strategy in ("none", "signature"):
        rs = _build(strategy, tenants)
        rs.run(2)  # warm jit
        t0 = time.perf_counter()
        rs.run(8)
        ms = 1e3 * (time.perf_counter() - t0) / 8
        s = rs.stats()
        s["step_ms"] = round(ms, 2)
        out[strategy] = s
        systems[strategy] = rs
    # output consistency across strategies
    for i in range(tenants):
        t = f"t{i}"
        assert systems["none"].tenant_output(t) == systems["signature"].tenant_output(t), t
    out["task_reduction"] = round(
        1 - out["signature"]["running_tasks"] / out["none"]["running_tasks"], 3
    )
    out["cost_reduction"] = round(
        1 - out["signature"]["deployed_cost"] / out["none"]["deployed_cost"], 3
    )
    out["step_speedup"] = round(out["none"]["step_ms"] / out["signature"]["step_ms"], 2)
    print(
        f"reuse-serving ({tenants} tenants): tasks "
        f"{out['none']['running_tasks']}→{out['signature']['running_tasks']} "
        f"(−{out['task_reduction']:.0%}), cost −{out['cost_reduction']:.0%}, "
        f"step ×{out['step_speedup']:.2f} "
        f"({out['none']['step_ms']}→{out['signature']['step_ms']} ms)"
    )
    with open(os.path.join(out_dir, "serving_reuse.json"), "w") as f:
        json.dump(stamp(out), f, indent=1)
    return out


if __name__ == "__main__":
    main()
