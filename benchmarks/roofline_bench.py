"""Aggregate the dry-run roofline records (results/dryrun/*.json) into
the EXPERIMENTS.md §Roofline table and pick the three hillclimb cells.

Selection rule (per assignment): worst roofline fraction, most
collective-bound, and the cell most representative of the paper's
technique (the multi-tenant serving shape — decode, since reuse-serving
multiplexes tenants over shared decode backbones).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp


def load(dry_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def table(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | status | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if not r["status"].startswith("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']} | | | | | | |")
            continue
        rf = r["roofline"]
        rows.append(
            "| {arch} | {shape} | ok | {c:.3g} | {m:.3g} | {x:.3g} | {dom} | "
            "{u:.2f} | {f:.3f} |".format(
                arch=r["arch"], shape=r["shape"],
                c=rf["compute_term_s"], m=rf["memory_term_s"],
                x=rf["collective_term_s"], dom=rf["dominant"],
                u=rf["useful_flops_ratio"], f=rf["roofline_fraction"],
            )
        )
    return "\n".join(rows)


def pick_hillclimb(recs: List[Dict]) -> Dict[str, Dict]:
    ok = [r for r in recs if r["status"].startswith("ok") and r["mesh"] == "16x16"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_term_s"]
               / max(max(r["roofline"]["compute_term_s"], r["roofline"]["memory_term_s"]), 1e-12))
    # paper-representative: largest decode cell (multi-tenant serving shape)
    decodes = [r for r in ok if r["shape"].startswith("decode")]
    rep = max(decodes, key=lambda r: r["roofline"]["model_flops"])
    return {"worst_fraction": worst, "most_collective_bound": coll, "paper_representative": rep}


def main(out_dir: str = "results/benchmarks") -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    recs = load()
    if not recs:
        print("no dry-run records — run: python -m repro.launch.dryrun --all")
        return {}
    ok = sum(1 for r in recs if r["status"].startswith("ok"))
    skip = sum(1 for r in recs if r["status"].startswith("SKIP"))
    print(f"dry-run records: {len(recs)} total, {ok} ok, {skip} documented skips")
    md = ["## Roofline — single-pod 16×16 (256 chips)\n", table(recs, "16x16"),
          "\n\n## Roofline — multi-pod 2×16×16 (512 chips)\n", table(recs, "2x16x16")]
    picks = pick_hillclimb(recs)
    md.append("\n\n## Hillclimb cells\n")
    for k, r in picks.items():
        md.append(
            f"- **{k}**: {r['arch']} × {r['shape']} "
            f"(dominant={r['roofline']['dominant']}, "
            f"fraction={r['roofline']['roofline_fraction']:.4f})"
        )
        print(f"hillclimb {k}: {r['arch']} × {r['shape']}")
    with open(os.path.join(out_dir, "roofline_table.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    summary = {
        "records": len(recs), "ok": ok, "skips": skip,
        "picks": {k: f"{v['arch']}×{v['shape']}" for k, v in picks.items()},
    }
    with open(os.path.join(out_dir, "roofline_summary.json"), "w") as f:
        json.dump(stamp(summary), f, indent=1)
    return summary


if __name__ == "__main__":
    main()
