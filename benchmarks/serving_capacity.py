"""Serving capacity: reuse-aware vs reuse-disabled slot admission.

Drives one ServeFrontend per strategy through the *same* synthetic
two-tenant churn trace (tenants drawing from the shared OPMW pool) on the
dryrun backend with a fixed slot pool, and counts what each admits. The
reuse-aware frontend charges only newly-created segments, so overlapping
tenants fit far more concurrent dataflows into the same pool — the
headline `admitted_ratio` is the paper's collaboration dividend expressed
as admission capacity.

    PYTHONPATH=src python benchmarks/serving_capacity.py \\
        --events 1000000 --out results/benchmarks/BENCH_pr6.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.frontend import ServeFrontend, TenantQuota  # noqa: E402
from repro.workloads import opmw_workload, tenant_copy, tenant_trace  # noqa: E402

try:  # package (python -m benchmarks.run) vs script (python benchmarks/foo.py)
    from benchmarks._host import stamp
except ImportError:  # pragma: no cover - script execution path
    from _host import stamp

TENANTS = ("alice", "bob")


def run_trace(strategy: str, args) -> dict:
    pool = opmw_workload()
    by_name = {d.name: d for d in pool}
    fe = ServeFrontend(
        slots=args.slots,
        strategy=strategy,
        backend="dryrun",
        default_quota=TenantQuota(
            max_slots=args.slots, max_pending=args.max_pending
        ),
        defrag_every=args.defrag_every,
    )
    counts = {"ADMITTED": 0, "QUEUED": 0, "RETRY_AFTER": 0, "REJECTED": 0}
    removes = skipped = 0
    peak_dataflows = 0
    t0 = time.perf_counter()
    for ev in tenant_trace(
        pool,
        TENANTS,
        events=args.events,
        weights={"alice": 2.0, "bob": 1.0},
        p_remove=args.p_remove,
        seed=args.seed,
    ):
        if ev.op == "add":
            df = tenant_copy(by_name[ev.pool_name], ev.tenant)
            result = fe.submit(ev.tenant, df)
            counts[result.status] += 1
        else:
            # The trace doesn't know admission outcomes: only remove what
            # the frontend actually holds (admitted or still queued).
            if ev.name in fe.tenant_of or any(
                p.df.name == ev.name for p in fe._pending
            ):
                fe.remove(ev.tenant, ev.name)
                removes += 1
            else:
                skipped += 1
        peak_dataflows = max(peak_dataflows, len(fe.tenant_of))
    elapsed = time.perf_counter() - t0
    stats = fe.stats()
    fe.close()
    # Ledger admitted counts queue drains too, not just synchronous ADMITTED.
    admitted_total = sum(l["admitted"] for l in stats["ledgers"].values())
    return {
        "strategy": strategy,
        "events": args.events,
        "admitted": admitted_total,
        "outcomes": counts,
        "removes": removes,
        "removes_skipped": skipped,
        "peak_concurrent_dataflows": peak_dataflows,
        "final_slots_used": stats["slots_used"],
        "final_naive_slots": stats["naive_slots"],
        "effective_capacity": round(stats["effective_capacity"], 3),
        "events_per_sec": round(args.events / elapsed, 1),
        "elapsed_sec": round(elapsed, 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=1_000_000)
    ap.add_argument("--slots", type=int, default=96)
    ap.add_argument("--max-pending", type=int, default=8)
    ap.add_argument("--p-remove", type=float, default=0.45)
    ap.add_argument("--defrag-every", type=int, default=64)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    results = {s: run_trace(s, args) for s in ("signature", "none")}
    reuse, naive = results["signature"], results["none"]
    out = {
        "bench": "serving_capacity",
        "trace": {
            "events": args.events,
            "tenants": list(TENANTS),
            "weights": {"alice": 2.0, "bob": 1.0},
            "p_remove": args.p_remove,
            "seed": args.seed,
            "pool": "opmw (35 DAGs, 471 tasks)",
        },
        "slots": args.slots,
        "reuse_aware": reuse,
        "reuse_disabled": naive,
        "admitted_ratio": round(reuse["admitted"] / max(naive["admitted"], 1), 3),
        "peak_concurrency_ratio": round(
            reuse["peak_concurrent_dataflows"]
            / max(naive["peak_concurrent_dataflows"], 1),
            3,
        ),
        "reuse_admits_strictly_more": reuse["admitted"] > naive["admitted"],
    }
    text = json.dumps(stamp(out), indent=1)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    if not out["reuse_admits_strictly_more"]:
        print("FAIL: reuse-aware admission did not admit more dataflows", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
