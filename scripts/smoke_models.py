"""Quick dev smoke: forward + prefill + decode on every reduced config."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, forward, init_cache, init_params, prefill


def run(arch: str) -> None:
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.family == "vlm":
        memory = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.num_image_tokens, cfg.d_model))
    elif cfg.family == "audio":
        memory = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))

    logits = forward(params, cfg, tokens, memory=memory)
    assert logits.shape == (B, S, cfg.padded_vocab), (arch, logits.shape)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in forward"

    mem_len = memory.shape[1] if memory is not None else 0
    cache = init_cache(cfg, B, S + 4, memory_len=mem_len)
    plogits, cache = prefill(params, cfg, tokens, cache, memory=memory)
    assert plogits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(plogits).any()), f"{arch}: NaN in prefill"
    # prefill last-token logits must match teacher-forcing forward last step
    np.testing.assert_allclose(
        np.asarray(plogits), np.asarray(logits[:, -1]), rtol=2e-2, atol=2e-2
    )

    tok = jnp.argmax(plogits, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        dlogits, cache = decode_step(params, cfg, tok, cache)
        assert dlogits.shape == (B, cfg.padded_vocab)
        assert not bool(jnp.isnan(dlogits).any()), f"{arch}: NaN in decode"
        tok = jnp.argmax(dlogits, -1)[:, None].astype(jnp.int32)
    print(f"  OK {arch:24s} |logits| last={float(jnp.abs(dlogits).mean()):.4f}")


if __name__ == "__main__":
    archs = sys.argv[1:] or list(configs.ARCHS)
    for a in archs:
        run(a)
    print("all good")
