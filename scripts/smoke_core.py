"""Quick manual smoke of the core merge/unmerge through `repro.api` —
the paper's Fig. 1 scenario plus batched submission and journal replay."""
import sys

sys.path.insert(0, "src")

from repro.api import ReuseSession, available_strategies, flow
from repro.core import ReuseManager


def fig1_flows():
    """Paper Fig. 1: A, B, C share a source + prefix; D has a different source."""

    def build(name, chain, source, sink):
        b = flow(name).source(source)
        for typ, cfg in chain:
            b.then(typ, **cfg)
        return b.sink(sink)

    A = build("A", [("parse", {}), ("kalman", {"q": 0.1})], "urban", "store_a")
    B = build(
        "B",
        [("parse", {}), ("kalman", {"q": 0.1}), ("sliding_window", {"w": 10})],
        "urban",
        "store_b",
    )
    C = build(
        "C",
        [
            ("parse", {}),
            ("kalman", {"q": 0.1}),
            ("sliding_window", {"w": 10}),
            ("average", {}),
        ],
        "urban",
        "store_c",
    )
    D = build("D", [("parse", {}), ("kalman", {"q": 0.1})], "smartmeter", "store_d")
    return A, B, C, D


def main():
    print("registered strategies:", available_strategies())
    for strategy in ("faithful", "signature"):
        print(f"=== strategy={strategy} ===")
        session = ReuseSession(strategy=strategy, check_invariants=True)
        A, B, C, D = fig1_flows()
        for label, f in zip("ABCD", (A, B, C, D)):
            r = session.submit(f)
            print(f"{label}:", "reused", r.num_reused, "created", r.num_created)
        mgr = session.manager
        print("running DAGs:", {n: len(df.tasks) for n, df in mgr.running.items()})
        print("running task count:", session.running_task_count,
              "(submitted:", session.submitted_task_count, ")")
        # Expect: A(4)+B reuse 3 create 2+C reuse 4 create 2+D create 4 → 4+2+2+4=12 running
        rm = session.remove("B")
        print("removed B; terminated:", sorted(rm.terminated_tasks))
        print("running task count:", session.running_task_count)
        session.verify()
        for name in ("A", "C", "D"):
            session.remove(name)
        print("after drain:", session.running_task_count,
              "running DAGs:", len(mgr.running))
        session.verify()

    # batched submit ≡ sequential submits
    seq = ReuseSession(check_invariants=True)
    for f in fig1_flows():
        seq.submit(f)
    bat = ReuseSession(check_invariants=True)
    bat.submit_many(fig1_flows())
    assert bat.running_task_count == seq.running_task_count == 12
    assert {n: sorted(d.tasks) for n, d in bat.manager.running.items()} == {
        n: sorted(d.tasks) for n, d in seq.manager.running.items()
    }
    print("submit_many ≡ sequential OK")

    # journal replay check
    mgr = bat.manager
    mgr.remove("B")
    clone = ReuseManager.replay(mgr.journal)
    assert clone.running_task_count == mgr.running_task_count
    assert {n: len(d.tasks) for n, d in clone.running.items()} == {
        n: len(d.tasks) for n, d in mgr.running.items()
    }
    clone.verify()
    print("journal replay OK")


if __name__ == "__main__":
    main()
