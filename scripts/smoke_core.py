"""Quick manual smoke of the core merge/unmerge — Fig. 1 scenario."""
import sys

sys.path.insert(0, "src")

from repro.core import Dataflow, ReuseManager, Task


def fig1_dataflows():
    """Paper Fig. 1: A, B, C share a source + prefix; D has a different source."""

    def df(name, chain, source, sink):
        d = Dataflow(name)
        prev = Task.make(f"{name}.src", source, "SOURCE")
        d.add_task(prev)
        for i, (typ, cfg) in enumerate(chain):
            t = Task.make(f"{name}.{i}.{typ}", typ, cfg)
            d.add_task(t)
            d.add_stream(prev.id, t.id)
            prev = t
        snk = Task.make(f"{name}.sink", sink, "SINK")
        d.add_task(snk)
        d.add_stream(prev.id, snk.id)
        return d

    A = df("A", [("parse", {}), ("kalman", {"q": 0.1})], "urban", "store_a")
    B = df(
        "B",
        [("parse", {}), ("kalman", {"q": 0.1}), ("sliding_window", {"w": 10})],
        "urban",
        "store_b",
    )
    C = df(
        "C",
        [
            ("parse", {}),
            ("kalman", {"q": 0.1}),
            ("sliding_window", {"w": 10}),
            ("average", {}),
        ],
        "urban",
        "store_c",
    )
    D = df("D", [("parse", {}), ("kalman", {"q": 0.1})], "smartmeter", "store_d")
    return A, B, C, D


def main():
    for strategy in ("faithful", "signature"):
        print(f"=== strategy={strategy} ===")
        mgr = ReuseManager(strategy=strategy, check_invariants=True)
        A, B, C, D = fig1_dataflows()
        rA = mgr.submit(A)
        print("A:", "reused", rA.num_reused, "created", rA.num_created)
        rB = mgr.submit(B)
        print("B:", "reused", rB.num_reused, "created", rB.num_created)
        rC = mgr.submit(C)
        print("C:", "reused", rC.num_reused, "created", rC.num_created)
        rD = mgr.submit(D)
        print("D:", "reused", rD.num_reused, "created", rD.num_created)
        print("running DAGs:", {n: len(df.tasks) for n, df in mgr.running.items()})
        print("running task count:", mgr.running_task_count, "(submitted:", mgr.submitted_task_count, ")")
        # Expect: A(4)+B reuse 3 create 2+C reuse 4 create 2+D create 4 → 4+2+2+4=12 running
        rm = mgr.remove("B")
        print("removed B; terminated:", sorted(rm.terminated_tasks))
        print("running task count:", mgr.running_task_count)
        mgr.verify()
        mgr.remove("A")
        mgr.remove("C")
        mgr.remove("D")
        print("after drain:", mgr.running_task_count, "running DAGs:", len(mgr.running))
        mgr.verify()
    # journal replay check
    mgr = ReuseManager(strategy="signature")
    A, B, C, D = fig1_dataflows()
    mgr.submit(A); mgr.submit(B); mgr.submit(C); mgr.submit(D)
    mgr.remove("B")
    clone = ReuseManager.replay(mgr.journal)
    assert clone.running_task_count == mgr.running_task_count
    assert {n: len(d.tasks) for n, d in clone.running.items()} == {
        n: len(d.tasks) for n, d in mgr.running.items()
    }
    clone.verify()
    print("journal replay OK")


if __name__ == "__main__":
    main()
