"""End-to-end telemetry smoke — the PR 10 acceptance scenario, runnable
by hand or from the CI ``observability`` job.

One in-process :class:`ServeFrontend` on the dryrun backend (JAX-free),
tracing armed, OPMW churn driving admission/removal while a real HTTP
client scrapes ``/metrics`` mid-run. Checks, each fatal:

  1. the scrape is valid Prometheus text 0.0.4 — round-trips through
     :func:`repro.obs.parse_prometheus`;
  2. the reuse-savings gauges match ground truth: ``repro_reuse_tasks_saved``
     equals ``session.stats()`` submitted − running task counts, the serve
     gauges equal the frontend's ledgers/slot pool at scrape time;
  3. the Chrome-trace export is loadable JSON with merge/step/segment
     spans (the artifact CI uploads for Perfetto).

Usage:
    PYTHONPATH=src python scripts/obs_smoke.py [--out-dir results/obs_smoke]
"""
import argparse
import json
import os
import sys
import urllib.request

sys.path.insert(0, "src")

from repro.obs import parse_prometheus
from repro.serve.frontend import ServeFrontend, TenantQuota
from repro.workloads import opmw_workload, tenant_copy

FAILURES = []


def check(name, ok, detail=""):
    tag = "ok" if ok else "FAIL"
    print(f"  [{tag}] {name}" + (f"  ({detail})" if detail else ""))
    if not ok:
        FAILURES.append(name)


def sample(families, name, **labels):
    """Value of one sample in a parse_prometheus() result, or None."""
    want = {k: str(v) for k, v in labels.items()}
    for lbls, value in families.get(name, []):
        if lbls == want:
            return value
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("results", "obs_smoke"))
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    pool = opmw_workload()
    frontend = ServeFrontend(
        slots=1024, backend="dryrun", default_quota=TenantQuota(max_slots=1024)
    )
    frontend.session.enable_tracing()
    host, port = frontend.start_metrics_http(port=0)
    url = f"http://{host}:{port}/metrics"
    print(f"scraping {url}")

    try:
        # churn phase 1: admit the pool across three tenants, step between
        tenants = ("alice", "bob", "carol")
        for i, df in enumerate(pool):
            t = tenants[i % len(tenants)]
            r = frontend.submit(t, tenant_copy(df, t))
            assert r.status == "ADMITTED", r
            frontend.step()

        # mid-run scrape, while more churn is still to come
        text = urllib.request.urlopen(url, timeout=10).read().decode("utf-8")
        families = parse_prometheus(text)
        check("scrape parses as Prometheus 0.0.4",
              bool(families), f"{len(families)} families")
        for required in (
            "repro_reuse_tasks_saved",
            "repro_reuse_tasks_submitted_total",
            "repro_serve_slots_used",
            "repro_serve_effective_capacity",
            "repro_merge_events_total",
        ):
            check(f"family {required} present", required in families)

        # ground truth: session stats + frontend ledgers at scrape time.
        # No churn ran between scrape and check, so values match exactly.
        stats = frontend.session.stats()
        saved = stats.submitted_task_count - stats.running_task_count
        check(
            "repro_reuse_tasks_saved == stats submitted-running",
            sample(families, "repro_reuse_tasks_saved") == saved,
            f"gauge={sample(families, 'repro_reuse_tasks_saved')} truth={saved}",
        )
        check(
            "repro_reuse_tasks_submitted_total == stats.submitted_task_count",
            sample(families, "repro_reuse_tasks_submitted_total")
            == stats.submitted_task_count,
        )
        fstats = frontend.stats()
        check(
            "repro_serve_slots_used == frontend slots_used",
            sample(families, "repro_serve_slots_used") == fstats["slots_used"],
        )
        check(
            "repro_serve_naive_slots == frontend naive_slots",
            sample(families, "repro_serve_naive_slots") == fstats["naive_slots"],
        )
        for t, ledger in fstats["ledgers"].items():
            check(
                f"repro_serve_slots_saved{{tenant={t}}} == ledger",
                sample(families, "repro_serve_slots_saved", tenant=t)
                == ledger["slots_saved"],
            )

        # churn phase 2: remove a third of the pool, re-scrape, re-check —
        # the gauges must track live state, not the admission-time snapshot
        for i, df in enumerate(pool):
            if i % 3 == 0:
                t = tenants[i % len(tenants)]
                frontend.remove(t, f"{t}/{df.name}")
                frontend.step()
        text2 = urllib.request.urlopen(url, timeout=10).read().decode("utf-8")
        fam2 = parse_prometheus(text2)
        f2 = frontend.stats()
        check(
            "post-churn repro_serve_slots_used tracks removals",
            sample(fam2, "repro_serve_slots_used") == f2["slots_used"],
            f"gauge={sample(fam2, 'repro_serve_slots_used')} truth={f2['slots_used']}",
        )
        check(
            "unmerge events counted",
            (sample(fam2, "repro_unmerge_events_total") or 0)
            == frontend.session.manager.op_counts["unmerge_events"],
        )

        # artifacts: the raw text + the Chrome trace CI uploads
        with open(os.path.join(args.out_dir, "metrics.prom"), "w") as f:
            f.write(text2)
        trace_path = os.path.join(args.out_dir, "trace.json")
        n = frontend.session.export_chrome_trace(trace_path)
        events = json.load(open(trace_path))
        if isinstance(events, dict):
            events = events["traceEvents"]
        cats = {e.get("cat") for e in events if e.get("ph") == "X"}
        check("chrome trace exported", n > 0, f"{n} spans")
        check("trace has control spans (merge/unmerge)", "control" in cats, str(sorted(cats)))
        check("trace has step+segment spans", {"step", "segment"} <= cats)
    finally:
        frontend.close()

    if FAILURES:
        print(f"\nobs smoke FAILED: {FAILURES}")
        return 1
    print(f"\nobs smoke passed; artifacts in {args.out_dir}/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
