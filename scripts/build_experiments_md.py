"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/{benchmarks,dryrun,perf}/*.json. Narrative sections live in
docs/experiments_narrative/*.md and are stitched in order."""
import glob
import json
import os
import sys


def fmt_seconds(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g} µs"
    if x < 1:
        return f"{x*1e3:.3g} ms"
    return f"{x:.3g} s"


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compile s | HBM/dev (args+temp) | compute | memory "
        "| memory (kernel) | collective | dominant | useful | frac | frac (kernel) |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(p))
        if r["mesh"] != mesh:
            continue
        if not r["status"].startswith("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | {r['status']} | — | — | — |")
            continue
        rf = r["roofline"]
        mem = r["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9
        rows.append(
            "| {a} | {s} | {c:.0f} | {h:.1f} GB | {ct} | {mt} | {mk} | {xt} | {dom} | "
            "{u:.2f} | {f:.4f} | {fk:.4f} |".format(
                a=r["arch"], s=r["shape"], c=r["compile_s"], h=hbm,
                ct=fmt_seconds(rf["compute_term_s"]),
                mt=fmt_seconds(rf["memory_term_s"]),
                mk=fmt_seconds(rf.get("memory_term_kernel_s", 0)),
                xt=fmt_seconds(rf["collective_term_s"]),
                dom=rf["dominant"], u=rf["useful_flops_ratio"],
                f=rf["roofline_fraction"],
                fk=rf.get("roofline_fraction_kernel", 0),
            )
        )
    return "\n".join(rows)


def figs_table() -> str:
    rows = [
        "| trace | peak tasks (D→R) | peak task red. | peak cores red. | walk cores red. "
        "| cum cores red. | +defrag | crossover steps | tasks shared >1 |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(glob.glob("results/benchmarks/fig2_3_4_*.json")):
        name = os.path.basename(p)[len("fig2_3_4_"):-len(".json")]
        s = json.load(open(p))["summary"]
        rows.append(
            "| {n} | {pd}→{pr} | {t:.0%} | {pc:.0%} | {w:.0%} | {c:.0%} | {d:.0%} | {x} | {sh:.0%} |".format(
                n=name, pd=s["peak_default_tasks"], pr=s["peak_reuse_tasks"],
                t=s["peak_task_reduction"], pc=s["peak_core_reduction"],
                w=s["cum_core_reduction_walk"], c=s["cum_core_reduction"],
                d=s["cum_core_reduction_defrag"], x=s["crossover_steps"],
                sh=s["frac_tasks_shared"],
            )
        )
    return "\n".join(rows)


def perf_table() -> str:
    rows = [
        "| cell | variant | compute | memory | memory (kernel) | collective | dominant | frac | frac (kernel) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = [
        ("deepseek-v2-236b × train_4k", [
            ("GSPMD scatter dispatch (pre-EP baseline)", "results/perf/deepseek__train__moe_gspmd.json", 1),
            ("EP shard_map, accum16 (new default)", "results/dryrun/deepseek_v2_236b__train_4k__16x16.json", 1),
            ("EP shard_map + accum8", "results/perf/deepseek__train__moe_ep_accum8.json", 1),
            ("EP shard_map + accum2", "results/perf/deepseek__train__moe_ep_accum2.json", 1),
        ]),
        ("nemotron-4-340b × decode_32k", [
            ("baseline (scan cache, repeat-free attn)", "results/dryrun/nemotron_4_340b__decode_32k__16x16.json", 1),
            ("carry-layout cache [REFUTED]", "results/perf/nemotron__decode__carry_cache.json", 1),
            ("pipeline-parallel (×16 → per-token)", "results/perf/nemotron__decode__pp.json", 16),
        ]),
        ("mixtral-8x22b × long_500k", [
            ("baseline (dense-capacity MoE)", "results/dryrun/mixtral_8x22b__long_500k__16x16.json", 1),
            ("sparse top-k expert gather", "results/perf/mixtral__long__sparse.json", 1),
            ("sparse + carry cache", "results/perf/mixtral__long__sparse_carry.json", 1),
        ]),
    ]
    PEAK, CHIPS = 197e12, 256
    for cell, variants in order:
        for label, path, scale in variants:
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            if not r.get("status", "").startswith("ok"):
                continue
            rf = r["roofline"]
            ct = rf["compute_term_s"] * scale
            mt = rf["memory_term_s"] * scale
            mk = rf.get("memory_term_kernel_s", 0) * scale
            xt = rf["collective_term_s"] * scale
            terms = {"compute": ct, "memory": mt, "collective": xt}
            dom = max(terms, key=terms.get)
            mf = rf["model_flops"]
            frac = mf / (CHIPS * PEAK * max(terms.values()))
            frac_k = mf / (CHIPS * PEAK * max(ct, mk, xt))
            rows.append(
                "| {c} | {l} | {ct} | {mt} | {mk} | {xt} | {dom} | {f:.5f} | {fk:.5f} |".format(
                    c=cell, l=label,
                    ct=fmt_seconds(ct), mt=fmt_seconds(mt),
                    mk=fmt_seconds(mk), xt=fmt_seconds(xt),
                    dom=dom, f=frac, fk=frac_k,
                )
            )
        rows.append("| | | | | | | | | |")
    return "\n".join(rows)


def bench_sections() -> str:
    out = []
    p = "results/benchmarks/merge_latency.json"
    if os.path.exists(p):
        d = json.load(open(p))
        out.append("### Merge latency (faithful vs signature)\n")
        out.append("| running DAGs | faithful ms/submit | signature ms/submit | speedup |")
        out.append("|---|---|---|---|")
        for n, row in sorted(d.items(), key=lambda kv: int(kv[0])):
            out.append(
                f"| {n} | {row['faithful']['last10_mean_ms']} | "
                f"{row['signature']['last10_mean_ms']} | ×{row['speedup_at_n']} |"
            )
        out.append("")
    p = "results/benchmarks/defrag_benefit.json"
    if os.path.exists(p):
        d = json.load(open(p))
        out.append("### Defragmentation (paper future work, implemented)\n")
        out.append(
            f"segments {d['before']['segments']}→{d['after']['segments']}, "
            f"deployed tasks {d['before']['deployed_tasks']}→{d['after']['deployed_tasks']}, "
            f"median step {d['before']['step_ms']}→{d['after']['step_ms']} ms "
            f"(×{d['step_speedup']}); sink streams continue uninterrupted "
            f"(state-preserving relaunch).\n"
        )
    p = "results/benchmarks/serving_reuse.json"
    if os.path.exists(p):
        d = json.load(open(p))
        out.append("### Multi-tenant LM reuse-serving (beyond paper)\n")
        out.append(
            f"9 tenants: running tasks {d['none']['running_tasks']}→"
            f"{d['signature']['running_tasks']} (−{d['task_reduction']:.0%}), "
            f"deployed cost −{d['cost_reduction']:.0%}, measured step "
            f"×{d['step_speedup']} faster ({d['none']['step_ms']}→"
            f"{d['signature']['step_ms']} ms); tenant outputs bit-identical "
            f"to the no-reuse deployment.\n"
        )
    return "\n".join(out)


def main():
    narrative = {}
    for p in glob.glob("docs/experiments_narrative/*.md"):
        narrative[os.path.basename(p)] = open(p).read()

    doc = []
    doc.append(narrative.get("00_header.md", "# EXPERIMENTS\n"))
    doc.append("\n## §Reproduction — paper Figs. 2/3/4 (6 traces)\n")
    doc.append(figs_table())
    doc.append(narrative.get("10_repro_notes.md", ""))
    doc.append("\n" + bench_sections())
    doc.append("\n## §Dry-run + §Roofline — single-pod 16×16 (256 chips)\n")
    doc.append(narrative.get("20_roofline_notes.md", ""))
    doc.append(dryrun_table("16x16"))
    doc.append("\n## §Dry-run — multi-pod 2×16×16 (512 chips)\n")
    doc.append(dryrun_table("2x16x16"))
    doc.append("\n## §Perf — hillclimb log\n")
    doc.append(narrative.get("30_perf_narrative.md", ""))
    doc.append(perf_table())
    doc.append(narrative.get("40_perf_conclusions.md", ""))
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(doc) + "\n")
    print("EXPERIMENTS.md written", len("\n".join(doc)), "chars")


if __name__ == "__main__":
    main()
