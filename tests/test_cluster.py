"""Elastic cluster plane tests: supervision, crash recovery, autoscaling.

Five layers:
  * pure policy units: :class:`AutoscalePolicy` hysteresis/patience/
    cooldown/bounds and its constructor validation;
  * the scheduler's self-healing seam: ``run_ready_queue(recover=...)``
    re-queues recovered items with bounded retries;
  * supervisor/autoscaler attach validation and the ``snapshot_mode``
    auto-resolution (spill for same-host launchers, wire otherwise);
  * crash recovery conformance: SIGKILL a worker mid-trace (fig-1 churn
    and an OPMW rw1 slice at a seeded-random step) under supervision —
    sink counts must be identical to an uninterrupted run, in both
    snapshot modes, on the dry and (slow tier) jit worker planes;
  * elasticity: ``resize_pool`` grow/shrink conformance, the autoscaler
    end to end, the subprocess launcher end to end, heartbeat detection
    of idle crashes, and the worker-health/event surfaces.

The CI cluster-resilience job re-runs this module with
``REPRO_TEST_STEP_MODE`` sync and concurrent; results must be
mode-invariant, and worker logs are uploaded as artifacts on failure.
"""
from __future__ import annotations

import os
import random
import signal
import time

import pytest

from repro.cluster import Autoscaler, AutoscalePolicy, WorkerSupervisor
from repro.cluster.events import (
    HEARTBEAT_MISSED,
    POOL_GROWN,
    POOL_SHRUNK,
    SEGMENT_REDEPLOYED,
    WORKER_RESPAWNED,
)
from repro.runtime.backend import resolve_backend
from repro.runtime.scheduler import run_ready_queue
from repro.runtime.system import StreamSystem
from repro.runtime.worker import MultiprocBackend

from helpers import chain_df, fig1

STEP_MODE = os.environ.get("REPRO_TEST_STEP_MODE") or "sync"
MAX_WORKERS = int(os.environ.get("REPRO_TEST_MAX_WORKERS", "4"))

FIG1_OPS = [
    ("add", "A"),
    ("add", "B"),
    ("add", "C"),
    ("add", "D"),
    ("remove", "B"),
    ("defrag", ""),
    ("remove", "A"),
    ("add", "B"),
]


def _apply(system, dags, op, name):
    if op == "add":
        system.submit(dags[name].copy())
    elif op == "remove":
        system.remove(name)
    else:
        system.defragment()


def _counts(system):
    return {
        name: {s: d["count"] for s, d in system.sink_digests(name).items()}
        for name in sorted(system.manager.submitted)
    }


def _digests(system):
    return {
        name: system.sink_digests(name) for name in sorted(system.manager.submitted)
    }


def _run_fig1(backend, ops=FIG1_OPS, step_mode=STEP_MODE, tail_steps=3,
              kill_at=None, victim=1, supervise=None):
    """Replay fig-1 churn; optionally SIGKILL worker ``victim`` just
    before stepping event ``kill_at``. Returns (digests, event kinds,
    respawn count)."""
    dags = {d.name: d for d in fig1()}
    system = StreamSystem(
        strategy="signature", backend=backend, step_mode=step_mode,
        max_workers=MAX_WORKERS,
    )
    sup = None
    if supervise is not None:
        sup = WorkerSupervisor(system.backend, **supervise).start()
    for i, (op, name) in enumerate(ops):
        _apply(system, dags, op, name)
        if kill_at is not None and i == kill_at:
            be = system.backend
            os.kill(be._procs[victim % be.n_workers].pid, signal.SIGKILL)
        system.step()
    for _ in range(tail_steps):
        system.step()
    digests = _digests(system)
    kinds = [e.kind for e in system.backend.worker_events]
    respawns = len(system.backend.respawns)
    if sup is not None:
        sup.stop()
    system.close()
    return digests, kinds, respawns


# -- policy units ----------------------------------------------------------------


class TestAutoscalePolicy:
    def _policy(self, **kw):
        kw.setdefault("min_workers", 1)
        kw.setdefault("max_workers", 4)
        kw.setdefault("high_ms", 10.0)
        kw.setdefault("low_ms", 1.0)
        kw.setdefault("patience", 3)
        kw.setdefault("cooldown", 0)
        return AutoscalePolicy(**kw)

    def test_grow_needs_patience_consecutive_highs(self):
        p = self._policy()
        assert p.decide(50.0, 1) == 1
        assert p.decide(50.0, 1) == 1
        assert p.decide(50.0, 1) == 2  # third consecutive high

    def test_shrink_needs_patience_consecutive_lows(self):
        p = self._policy()
        assert p.decide(0.1, 3) == 3
        assert p.decide(0.1, 3) == 3
        assert p.decide(0.1, 3) == 2

    def test_in_band_observation_resets_streaks(self):
        p = self._policy()
        p.decide(50.0, 1)
        p.decide(50.0, 1)
        assert p.decide(5.0, 1) == 1  # hysteresis band: streak wiped
        assert p.decide(50.0, 1) == 1
        assert p.decide(50.0, 1) == 1
        assert p.decide(50.0, 1) == 2  # needs a fresh run of `patience`

    def test_cooldown_suppresses_followup_action(self):
        p = self._policy(patience=1, cooldown=2)
        assert p.decide(50.0, 1) == 2
        assert p.decide(50.0, 2) == 2  # cooling
        assert p.decide(50.0, 2) == 2  # cooling
        assert p.decide(50.0, 2) == 3  # cooldown elapsed

    def test_bounds_are_hard(self):
        p = self._policy(patience=1, max_workers=2)
        assert p.decide(50.0, 2) == 2   # at max: no grow
        assert p.decide(0.1, 1) == 1    # at min: no shrink

    def test_constructor_validation(self):
        with pytest.raises(ValueError, match="min_workers"):
            AutoscalePolicy(min_workers=0)
        with pytest.raises(ValueError, match="max_workers"):
            AutoscalePolicy(min_workers=3, max_workers=2)
        with pytest.raises(ValueError, match="hysteresis"):
            AutoscalePolicy(low_ms=10.0, high_ms=10.0)


# -- scheduler self-healing seam -------------------------------------------------


class TestRunReadyQueueRecovery:
    def test_recovered_item_is_requeued_and_completes(self):
        deps = {"a": [], "b": ["a"]}
        calls = {"a": 0, "b": 0}

        def runner(n):
            calls[n] += 1
            if n == "a" and calls["a"] == 1:
                raise RuntimeError("boom")
            return 1.0

        healed = []
        out = run_ready_queue(deps, runner, 2,
                              recover=lambda n, e: healed.append(n) or True)
        assert out == {"a": 1.0, "b": 1.0}
        assert healed == ["a"]
        assert calls == {"a": 2, "b": 1}  # dependent ran exactly once, after

    def test_retries_are_bounded(self):
        calls = {"a": 0}

        def runner(n):
            calls[n] += 1
            raise RuntimeError("always broken")

        with pytest.raises(RuntimeError, match="always broken"):
            run_ready_queue({"a": []}, runner, 2,
                            recover=lambda n, e: True, max_retries=2)
        assert calls["a"] == 3  # initial + max_retries

    def test_declined_recovery_raises(self):
        def runner(n):
            raise RuntimeError("fatal")

        with pytest.raises(RuntimeError, match="fatal"):
            run_ready_queue({"a": []}, runner, 2, recover=lambda n, e: False)


# -- attach validation + snapshot-mode resolution --------------------------------


class TestAttach:
    def test_supervisor_rejects_non_pool_backend(self):
        with pytest.raises(ValueError, match="worker-pool backend"):
            WorkerSupervisor(resolve_backend("dryrun"))

    def test_supervisor_rejects_unknown_snapshot_mode(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        try:
            with pytest.raises(ValueError, match="snapshot_mode"):
                WorkerSupervisor(be, snapshot_mode="telepathy")
        finally:
            be.close()

    def test_autoscaler_rejects_non_resizable_backend(self):
        with pytest.raises(ValueError, match="resizable worker pool"):
            Autoscaler(resolve_backend("dryrun"))

    def test_autoscaler_rejects_policy_plus_kwargs(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        try:
            with pytest.raises(ValueError, match="not both"):
                Autoscaler(be, policy=AutoscalePolicy(), high_ms=9.0)
        finally:
            be.close()

    def test_auto_snapshot_mode_resolves_to_spill_on_local_launcher(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        try:
            WorkerSupervisor(be)
            assert be.snapshot_mode == "spill"
            assert be.self_heal
            assert not be.shadow_states  # no per-step wire encodes
        finally:
            be.close()

    def test_wire_mode_arms_shadow_snapshots(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        try:
            WorkerSupervisor(be, snapshot_mode="wire")
            assert be.snapshot_mode == "wire"
            assert be.shadow_states
        finally:
            be.close()


# -- crash recovery conformance --------------------------------------------------


class TestKillRecoveryConformance:
    @pytest.mark.parametrize("snapshot_mode", ["spill", "wire"])
    def test_fig1_counts_survive_mid_trace_kill(self, snapshot_mode):
        ref, _, _ = _run_fig1(MultiprocBackend(workers=2, worker_plane="dry"))
        got, kinds, respawns = _run_fig1(
            MultiprocBackend(workers=2, worker_plane="dry"),
            kill_at=4,
            supervise=dict(heartbeat_interval=5.0, snapshot_mode=snapshot_mode),
        )
        assert {n: {s: d["count"] for s, d in v.items()} for n, v in got.items()} == {
            n: {s: d["count"] for s, d in v.items()} for n, v in ref.items()
        }
        assert respawns >= 1
        assert WORKER_RESPAWNED in kinds
        assert SEGMENT_REDEPLOYED in kinds

    def test_opmw_rw1_slice_kill_at_seeded_random_step(self):
        """The PR acceptance shape: kill a worker at a randomized (seeded)
        trace step of the OPMW rw1 trace; sink counts must be identical to
        the uninterrupted run. The CI job replays this in both step modes."""
        from repro.workloads import opmw_workload, rw_trace

        dags = {d.name: d for d in opmw_workload()}
        events = [(ev.op, ev.name) for ev in rw_trace(dags.values(), seed=11)][:16]
        kill_at = random.Random(117).randrange(2, len(events) - 2)

        def run(kill):
            system = StreamSystem(
                strategy="signature",
                backend=MultiprocBackend(workers=2, worker_plane="dry"),
                step_mode=STEP_MODE, max_workers=MAX_WORKERS,
            )
            sup = WorkerSupervisor(system.backend, heartbeat_interval=5.0).start()
            for i, (op, name) in enumerate(events):
                _apply(system, dags, op, name)
                if kill and i == kill_at:
                    be = system.backend
                    os.kill(be._procs[1].pid, signal.SIGKILL)
                system.step()
            counts = _counts(system)
            respawns = len(system.backend.respawns)
            sup.stop()
            system.close()
            return counts, respawns

        ref, _ = run(kill=False)
        got, respawns = run(kill=True)
        assert got == ref
        assert respawns >= 1

    @pytest.mark.slow
    def test_jit_plane_kill_digests_identical_to_inprocess(self):
        """Counts AND checksums: the supervised jit worker plane recovers
        a SIGKILLed worker bit-identically to the in-process jit plane."""
        dags = {d.name: d for d in fig1()}
        system = StreamSystem(strategy="signature", backend="inprocess",
                              step_mode=STEP_MODE, max_workers=MAX_WORKERS)
        for op, name in FIG1_OPS:
            _apply(system, dags, op, name)
            system.step()
        for _ in range(3):
            system.step()
        ref = _digests(system)
        system.close()

        got, _, respawns = _run_fig1(
            resolve_backend("multiproc", workers=2),
            kill_at=4, supervise=dict(heartbeat_interval=5.0),
        )
        assert got == ref
        assert respawns >= 1


# -- elasticity ------------------------------------------------------------------


class TestResizePool:
    def test_grow_and_shrink_preserve_counts(self):
        def run(resize):
            be = MultiprocBackend(workers=2, worker_plane="dry")
            system = StreamSystem(strategy="none", backend=be,
                                  step_mode=STEP_MODE, max_workers=MAX_WORKERS)
            for i in range(5):
                system.submit(
                    chain_df(f"R{i}", "urban", [("kalman", {"q": float(i)})])
                )
            for _ in range(2):
                system.step()
            if resize:
                be.resize_pool(4)
            for _ in range(2):
                system.step()
            if resize:
                be.resize_pool(1)
                assert set(be.device_of.values()) == {0}
            for _ in range(2):
                system.step()
            counts = _counts(system)
            kinds = [e.kind for e in be.worker_events]
            system.close()
            return counts, kinds

        ref, _ = run(resize=False)
        got, kinds = run(resize=True)
        assert got == ref
        assert POOL_GROWN in kinds and POOL_SHRUNK in kinds

    def test_resize_validation(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        try:
            with pytest.raises(ValueError, match=">= 1"):
                be.resize_pool(0)
        finally:
            be.close()


class TestAutoscalerEndToEnd:
    def test_forced_pressure_grows_then_shrinks_pool(self, monkeypatch):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        system = StreamSystem(strategy="none", backend=be,
                              step_mode=STEP_MODE, max_workers=MAX_WORKERS)
        for i in range(4):
            system.submit(chain_df(f"A{i}", "urban", [("kalman", {"q": float(i)})]))
        system.step()
        scaler = Autoscaler(be, min_workers=1, max_workers=3,
                            high_ms=10.0, low_ms=1.0, patience=2, cooldown=0)
        monkeypatch.setattr(scaler, "pressure", lambda: 100.0)
        for _ in range(4):
            system.step()
            scaler.observe()
        assert be.n_workers > 1
        monkeypatch.setattr(scaler, "pressure", lambda: 0.01)
        for _ in range(6):
            system.step()
            scaler.observe()
        assert be.n_workers == 1
        assert [(a["from"], a["to"]) for a in scaler.actions][0] == (1, 2)
        # the resized pool still serves a correct step
        report = system.step()
        assert report.live_tasks == be.live_task_count
        system.close()

    def test_system_autoscale_knob_binds_and_reports(self):
        be = MultiprocBackend(workers=1, worker_plane="dry")
        system = StreamSystem(
            strategy="none", backend=be, step_mode=STEP_MODE,
            max_workers=MAX_WORKERS,
            autoscale={"min_workers": 1, "max_workers": 2,
                       "high_ms": 1e9, "low_ms": 1e-9},
        )
        system.submit(chain_df("K0", "urban", [("kalman", {"q": 1.0})]))
        system.step()  # observe() runs inside step()
        health = system.worker_health()
        assert health["autoscale"]["max_workers"] == 2
        assert health["autoscale"]["actions"] == []
        system.close()


class TestHeartbeatAndHealth:
    def test_heartbeat_detects_idle_crash(self):
        be = MultiprocBackend(workers=2, worker_plane="dry")
        system = StreamSystem(strategy="none", backend=be,
                              step_mode=STEP_MODE, max_workers=MAX_WORKERS)
        for i in range(2):
            system.submit(chain_df(f"H{i}", "urban", [("kalman", {"q": float(i)})]))
        system.step()
        sup = WorkerSupervisor(be, heartbeat_interval=0.05).start()
        os.kill(be._procs[1].pid, signal.SIGKILL)
        deadline = time.monotonic() + 10.0
        while not be.respawns and time.monotonic() < deadline:
            time.sleep(0.02)  # no step issued: only the heartbeat can notice
        assert be.respawns, "heartbeat never recovered the idle crash"
        assert HEARTBEAT_MISSED in [e.kind for e in be.worker_events]
        assert be.worker_alive(1)
        system.step()  # recovered pool keeps stepping
        sup.stop()
        system.close()

    def test_check_is_synchronous(self):
        be = MultiprocBackend(workers=2, worker_plane="dry")
        system = StreamSystem(strategy="none", backend=be,
                              step_mode=STEP_MODE, max_workers=MAX_WORKERS)
        system.submit(chain_df("C0", "urban", [("kalman", {"q": 1.0})]))
        system.step()
        sup = WorkerSupervisor(be)  # not started: no background thread
        os.kill(be._procs[0].pid, signal.SIGKILL)
        time.sleep(0.1)
        assert sup.check() == [0]
        assert be.worker_alive(0)
        system.close()

    def test_supervise_knob_surfaces_worker_health(self):
        system = StreamSystem(
            strategy="none",
            backend=MultiprocBackend(workers=2, worker_plane="dry"),
            step_mode=STEP_MODE, max_workers=MAX_WORKERS,
            supervise=True,
        )
        system.submit(chain_df("W0", "urban", [("kalman", {"q": 1.0})]))
        system.step()
        health = system.worker_health()
        assert health["workers"] == 2
        assert health["alive"] == [True, True]
        assert health["supervised"] is True
        assert health["snapshot_mode"] in ("spill", "wire")
        assert "spill_ms_per_step" in health
        assert health["heartbeat_running"] is True
        system.close()  # stops the supervisor thread
        assert system._supervisor.running is False

    def test_inprocess_backends_have_no_worker_health(self):
        system = StreamSystem(strategy="none", backend="dryrun")
        assert system.worker_health() is None
        with pytest.raises(ValueError, match="worker-pool backend"):
            StreamSystem(strategy="none", backend="dryrun", supervise=True)
        system.close()

    def test_event_hook_receives_pool_events(self):
        seen = []
        be = MultiprocBackend(workers=1, worker_plane="dry")
        system = StreamSystem(strategy="none", backend=be,
                              step_mode=STEP_MODE, max_workers=MAX_WORKERS,
                              on_worker_event=seen.append)
        system.submit(chain_df("E0", "urban", [("kalman", {"q": 1.0})]))
        system.step()
        be.resize_pool(2)
        be.resize_pool(1)
        kinds = [e.kind for e in seen]
        assert POOL_GROWN in kinds and POOL_SHRUNK in kinds
        system.close()


class TestSubprocessLauncher:
    def test_end_to_end_counts_match_local_launcher(self):
        ref, _, _ = _run_fig1(
            MultiprocBackend(workers=2, worker_plane="dry"),
            ops=FIG1_OPS[:4], tail_steps=1,
        )
        be = MultiprocBackend(workers=2, worker_plane="dry",
                              launcher="subprocess")
        assert be.launcher.supports_spill  # same host, no command_prefix
        got, _, _ = _run_fig1(be, ops=FIG1_OPS[:4], tail_steps=1)
        assert {n: {s: d["count"] for s, d in v.items()} for n, v in got.items()} == {
            n: {s: d["count"] for s, d in v.items()} for n, v in ref.items()
        }
