"""Data-plane integration tests: segments, broker, pause, defrag, and the
paper's core guarantee — Reuse outputs are indistinguishable from Default.
"""
import jax.numpy as jnp
import pytest

from repro.core import ReuseManager
from repro.runtime import (
    PAUSE_EPSILON,
    StragglerPolicy,
    StreamSystem,
    place_round_robin,
)

from helpers import chain_df, diamond_df, fig1, two_source_df

STEPS = 12


def run_system(strategy, dfs, steps=STEPS, removals=(), defrag=False):
    sys_ = StreamSystem(strategy=strategy, check_invariants=(strategy != "none"))
    for df in dfs:
        sys_.submit(df.copy())
    for name in removals:
        sys_.remove(name)
    if defrag:
        sys_.defragment()
    sys_.run(steps)
    return sys_


class TestOutputConsistency:
    """Paper §3.3: running-DAG outputs must be identical to standalone runs."""

    def test_fig1_reuse_equals_default(self):
        A, B, C, D = fig1()
        default = run_system("none", [A, B, C, D])
        reuse = run_system("signature", [A, B, C, D])
        for name in "ABCD":
            d_dig = default.sink_digests(name)
            r_dig = reuse.sink_digests(name)
            assert d_dig == r_dig, f"sink outputs diverged for {name}"
            for sink in d_dig.values():
                assert sink["count"] == STEPS
                assert sink["checksum"] != 0.0

    def test_diamond_and_two_source_consistency(self):
        dfs = [diamond_df("dia"), two_source_df("ts"), *fig1()]
        default = run_system("none", dfs)
        reuse = run_system("faithful", dfs)
        for df in dfs:
            assert default.sink_digests(df.name) == reuse.sink_digests(df.name)

    def test_consistency_after_removal(self):
        A, B, C, D = fig1()
        default = run_system("none", [A, B, C, D], removals=["B"])
        reuse = run_system("signature", [A, B, C, D], removals=["B"])
        for name in "ACD":
            assert default.sink_digests(name) == reuse.sink_digests(name)

    def test_consistency_after_defrag(self):
        """Defrag must not perturb outputs (state carries over)."""
        A, B, C, D = fig1()
        plain = run_system("signature", [A, B, C, D], removals=["B"])
        defr = run_system("signature", [A, B, C, D], removals=["B"], defrag=True)
        for name in "ACD":
            assert plain.sink_digests(name) == defr.sink_digests(name)

    def test_mid_run_merge_keeps_streams_aligned(self):
        """Submit A, step, then submit B (reusing A's prefix), step more:
        B's sink sees the stream from the step it joined onward."""
        A, B, *_ = fig1()
        sys_ = StreamSystem(strategy="signature")
        sys_.submit(A)
        sys_.run(5)
        sys_.submit(B)
        sys_.run(7)
        digests = sys_.sink_digests("B")
        (sink,) = digests.values()
        assert sink["count"] == 7  # joined 5 steps in


class TestPauseAndDefrag:
    def test_pause_frees_cost_but_keeps_deployment(self):
        A, B, C, D = fig1()
        sys_ = StreamSystem(strategy="signature")
        for df in (A, B, C, D):
            sys_.submit(df)
        r_before = sys_.step()
        sys_.remove("D")  # D runs alone: all 4 of its tasks pause
        r_after = sys_.step()
        assert r_after.live_tasks == r_before.live_tasks - 4
        assert r_after.paused_tasks == 4
        assert r_after.cost < r_before.cost
        # deployment unchanged (Storm can't kill a subset)
        assert sys_.deployed_task_count == 12

    def test_paused_overhead_is_nonzero(self):
        A, *_ = fig1()
        sys_ = StreamSystem(strategy="signature")
        sys_.submit(A)
        sys_.remove("A")
        rep = sys_.step()
        assert rep.live_tasks == 0
        assert rep.paused_tasks == 4
        assert rep.cost > 0  # ε residue — the paper's drain-phase overhead

    def test_defrag_drops_paused_tasks_and_broker_hops(self):
        A, B, C, D = fig1()
        sys_ = StreamSystem(strategy="signature")
        for df in (A, B, C, D):
            sys_.submit(df)
        sys_.remove("B")
        sys_.step()
        assert sys_.deployed_task_count == 12  # 11 live + 1 paused
        sys_.executor.broker.reset_counters()
        sys_.defragment()
        sys_.step()
        assert sys_.deployed_task_count == 11  # paused dropped
        assert sys_.executor.broker.publishes == 0  # no cross-segment hops
        rep = sys_.executor.reports[-1]
        assert rep.paused_tasks == 0

    def test_default_kills_topologies_on_remove(self):
        A, B, *_ = fig1()
        sys_ = StreamSystem(strategy="none")
        sys_.submit(A)
        sys_.submit(B)
        assert sys_.deployed_task_count == 9
        sys_.remove("A")
        rep = sys_.step()
        assert sys_.deployed_task_count == 5
        assert rep.paused_tasks == 0  # kill, not pause


class TestSegmentsAndBroker:
    def test_incremental_launch_uses_broker(self):
        A, B, *_ = fig1()
        sys_ = StreamSystem(strategy="signature")
        sys_.submit(A)
        sys_.step()
        before = sys_.executor.broker.publishes
        sys_.submit(B)  # B's new tasks subscribe to A's kalman output
        sys_.step()
        assert sys_.executor.broker.publishes > before
        assert len(sys_.executor.segments) == 2

    def test_fully_contained_submission_launches_nothing(self):
        _, _, C, _ = fig1()
        A = chain_df("A2", "urban", [("parse", {}), ("kalman", {"q": 0.1})], "store_a")
        sys_ = StreamSystem(strategy="signature")
        sys_.submit(C)
        n_seg = len(sys_.executor.segments)
        # A2's entire prefix exists; only its sink differs from C's tasks
        r = sys_.submit(A)
        assert r.num_created == 1
        assert len(sys_.executor.segments) == n_seg + 1

    def test_multi_parent_canonical_order(self):
        """Join tasks concatenate parent batches in signature order — stable
        across Default/Reuse (covered indirectly by consistency tests; here
        we check the join batch size doubles)."""
        ts = two_source_df("ts")
        sys_ = StreamSystem(strategy="signature")
        r = sys_.submit(ts)
        sys_.step()
        join_run = r.plan.task_map["ts.j"]
        assert sys_.task_batch[join_run] == 2 * sys_.base_batch


class TestSchedulerModels:
    def test_round_robin_placement(self):
        p = place_round_robin({"seg1": 20, "seg2": 4})
        # seg1: 3 workers (8+8+4), seg2: 1 worker → 4 workers, 1 node
        assert p.workers_used == 4
        assert p.nodes_used == 1
        assert len(p.assignments["seg1"]) == 20

    def test_placement_never_shares_worker_across_segments(self):
        p = place_round_robin({"a": 9, "b": 1})
        workers_a = {w for w in p.assignments["a"]}
        workers_b = {w for w in p.assignments["b"]}
        assert not (workers_a & workers_b)

    def test_straggler_policy_flags_and_resets(self):
        pol = StragglerPolicy(factor=2.0, alpha=1.0)
        for step in range(3):
            flagged = pol.observe(step, {"s1": 10.0, "s2": 10.0, "s3": 50.0})
            if step == 0:
                assert flagged == ["s3"]
        assert pol.events and pol.events[0].segment == "s3"

    def test_executor_redispatch_bookkeeping(self):
        A, *_ = fig1()
        sys_ = StreamSystem(strategy="signature")
        sys_.submit(A)
        sys_.step()
        sys_.executor.redispatch("seg1")
        assert sys_.executor.redispatches[-1][1] == "seg1"
