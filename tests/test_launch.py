"""Launch layer: cell construction, input specs, sharding inference —
divisibility-safe on every assigned arch (no 512-device compile here;
that's launch/dryrun.py's job in a fresh process)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh, mesh_sizes
from repro.models import abstract_params
from repro.models import sharding as shd


def _fake_rules(sizes):
    r = shd.AxisRules(sizes)
    return r


@pytest.mark.parametrize("arch", list(configs.ARCHS))
def test_param_specs_divisible(arch):
    """Every inferred spec divides its dim on the production mesh sizes."""
    cfg = configs.get_config(arch)
    rules = _fake_rules({"data": 16, "model": 16})
    tree = abstract_params(cfg)
    spec_tree = shd.infer_param_specs(tree, rules)

    def check(path, leaf, spec):
        for i, d in enumerate(leaf.shape):
            axes = spec[i] if i < len(spec) else None
            if axes is None:
                continue
            for a in axes if isinstance(axes, tuple) else (axes,):
                size = rules.mesh_sizes[a]
                assert d % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), tree, spec_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


@pytest.mark.parametrize("arch", list(configs.ARCHS))
@pytest.mark.parametrize("shape", [s.name for s in configs.SHAPES])
def test_input_specs_complete(arch, shape):
    cfg = configs.get_config(arch)
    cell = configs.shape_cell(shape)
    if configs.cell_supported(cfg, cell):
        pytest.skip("documented skip")
    ins = S.input_specs(arch, shape)
    assert "tokens" in ins
    B = cell.global_batch
    assert ins["tokens"].shape[0] == B
    if cell.kind == "decode":
        assert ins["tokens"].shape == (B, 1)
    else:
        assert ins["tokens"].shape == (B, cell.seq_len)
    if cfg.family in ("vlm", "audio") and cell.kind != "decode":
        assert "memory" in ins


def test_batch_axes_fallback():
    rules = _fake_rules({"pod": 2, "data": 16, "model": 16})
    assert S._data_axes_for(256, rules) == ("pod", "data")
    assert S._data_axes_for(16, rules) == ("pod",)  # 16 % 32 ≠ 0 but % 2 = 0
    assert S._data_axes_for(1, rules) == ()


def test_skip_matrix_matches_design():
    """long_500k runs exactly for the sub-quadratic archs."""
    runnable = {
        a for a in configs.ARCHS
        if not configs.cell_supported(
            configs.get_config(a), configs.shape_cell("long_500k")
        )
    }
    assert runnable == {"mixtral_8x22b", "xlstm_1_3b", "zamba2_2_7b"} or runnable == {
        "mixtral-8x22b", "xlstm-1.3b", "zamba2-2.7b"
    }


def test_param_count_sane():
    """Totals are in the right ballpark for the published model names."""
    expect = {
        "granite-20b": (15e9, 25e9),
        "nemotron-4-340b": (300e9, 380e9),
        "qwen1.5-110b": (95e9, 125e9),
        "qwen3-4b": (3e9, 5.5e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "mixtral-8x22b": (120e9, 155e9),
        "llama-3.2-vision-90b": (75e9, 100e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "seamless-m4t-medium": (0.3e9, 1.4e9),
    }
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        lo, hi = expect[cfg.name]
        total, active = cfg.param_count()
        assert lo <= total <= hi, (cfg.name, total / 1e9)
        if cfg.family != "hybrid":  # zamba2's shared block is applied 9×
            assert active <= total


def test_host_mesh_lower_smoke():
    """A reduced cell lowers on the 1×1 host mesh (full trace, no alloc)."""
    mesh = make_host_mesh()
    cfg = configs.get_smoke_config("qwen3-4b")
    rules = S.make_rules(mesh)
    from repro.models import abstract_params as ap
    from repro.train import AdamWConfig, abstract_train_state, make_train_step

    opt = AdamWConfig()
    step = make_train_step(cfg, opt, accum=1)
    state = abstract_train_state(cfg, opt)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
    }
    with mesh:
        with shd.use_rules(rules):
            lowered = jax.jit(step).lower(state, batch)
    assert "while" in lowered.as_text()  # layer scan present
