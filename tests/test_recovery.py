"""Crash-recovery conformance suite for the durable checkpoint/restore path.

The contract under test (ISSUE 3 acceptance): kill the data plane at *any*
step, restore from the newest valid on-disk checkpoint, and the resumed
run's sink counts, Fig. 2 running-task series and ``account()`` totals are
indistinguishable from an uninterrupted run — on all three backends, and
across backends (checkpoint under ``inprocess``, restore under ``dryrun``
and vice versa; jit→jit restores are bit-exact including checksums).

Layers:
  * the pytree codec and CheckpointStore mechanics (atomic writes,
    monotonic ids, torn-last-checkpoint tolerance);
  * kill-at-randomized-step conformance on the OPMW rw1 trace (dry-run:
    full 35-DAG trace; jit backends: Fig. 1 scale, OPMW subset as slow);
  * cross-backend restores;
  * durable lifecycle details (defrag/forward/pause survival, payload
    fixed point);
  * ReuseSession recovery: hooks re-attached, stats continuity, cadence;
  * the launch CLI's --checkpoint-dir/--restore crash-resume loop.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime.checkpoint import (
    CHECKPOINT_FORMAT_VERSION,
    CheckpointError,
    CheckpointStore,
    decode_pytree,
    encode_pytree,
    is_checkpoint_path,
    payload_digest,
)
from repro.runtime.system import StreamSystem

from helpers import fig1

BACKENDS = ["inprocess", "sharded", "dryrun"]

# ---------------------------------------------------------------------------
# trace driving helpers
# ---------------------------------------------------------------------------


def _fig1_dags():
    A, B, C, D = fig1()
    return {d.name: d for d in (A, B, C, D)}


# (op, name) sequences; every event is followed by exactly one step().
FIG1_OPS = [
    ("add", "A"),
    ("add", "B"),
    ("add", "C"),
    ("add", "D"),
    ("remove", "B"),
    ("defrag", ""),
    ("remove", "A"),
    ("add", "B"),
]


def _opmw_dags():
    from repro.workloads import opmw_workload

    return {d.name: d for d in opmw_workload()}


def _opmw_ops(truncate=None):
    from repro.workloads import opmw_workload, rw_trace

    dags = opmw_workload()
    events = [(ev.op, ev.name) for ev in rw_trace(dags, seed=11)]
    return events[:truncate] if truncate else events


def _apply(system, dags_by_name, op, name):
    if op == "add":
        system.submit(dags_by_name[name].copy())
    elif op == "remove":
        system.remove(name)
    elif op == "defrag":
        system.defragment()
    else:  # pragma: no cover - defensive
        raise ValueError(op)


def _final_state(system):
    digests = {
        name: {s: d["count"] for s, d in system.sink_digests(name).items()}
        for name in system.manager.submitted
    }
    live, paused, cost = system.backend.account()
    return digests, (live, paused, cost)


def _run_uninterrupted(backend, dags_by_name, ops):
    """Baseline: apply + step every event; return (series, digests, account)."""
    system = StreamSystem(strategy="signature", backend=backend)
    series = []
    for op, name in ops:
        _apply(system, dags_by_name, op, name)
        rep = system.step()
        series.append((rep.live_tasks, rep.paused_tasks, rep.cost))
    digests, acct = _final_state(system)
    return series, digests, acct, system


def _run_with_crash(
    backend, dags_by_name, ops, kill_at, ckpt_dir, restore_backend=None
):
    """Checkpoint every step, 'crash' after event ``kill_at``, restore from
    disk (optionally on a different backend), finish the trace."""
    system = StreamSystem(
        strategy="signature",
        backend=backend,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=1,
    )
    series = []
    for op, name in ops[: kill_at + 1]:
        _apply(system, dags_by_name, op, name)
        rep = system.step()
        series.append((rep.live_tasks, rep.paused_tasks, rep.cost))
    del system  # the crash: in-memory state is gone; only checkpoints remain

    restored = StreamSystem.restore(ckpt_dir, backend=restore_backend)
    for op, name in ops[kill_at + 1 :]:
        _apply(restored, dags_by_name, op, name)
        rep = restored.step()
        series.append((rep.live_tasks, rep.paused_tasks, rep.cost))
    digests, acct = _final_state(restored)
    return series, digests, acct, restored


def _assert_conformant(base, crashed, cost_exact=True):
    b_series, b_digests, b_acct, _ = base
    c_series, c_digests, c_acct, _ = crashed
    assert [(l, p) for l, p, _ in c_series] == [(l, p) for l, p, _ in b_series]
    rel = 0 if cost_exact else 1e-9
    for (_, _, bc), (_, _, cc) in zip(b_series, c_series):
        assert cc == pytest.approx(bc, rel=rel or 1e-15)
    assert c_digests == b_digests
    assert c_acct[:2] == b_acct[:2]
    assert c_acct[2] == pytest.approx(b_acct[2], rel=rel or 1e-15)


# randomized kill points, fixed seed so CI failures reproduce
_RNG = np.random.default_rng(7)
DRYRUN_KILLS = sorted(int(k) for k in _RNG.choice(len(_opmw_ops()) - 2, 5, replace=False))
FIG1_KILLS = [0, 3, 5]


# ---------------------------------------------------------------------------
# pytree codec
# ---------------------------------------------------------------------------


class TestPytreeCodec:
    def test_scalars_round_trip(self):
        for v in (None, True, False, 0, 7, -3, 1.5, "x", ()):
            assert decode_pytree(encode_pytree(v)) == v

    def test_arrays_round_trip_bit_exact(self):
        arrs = [
            np.arange(12, dtype=np.float32).reshape(3, 4),
            np.array(5, dtype=np.int32),  # 0-d scalar (the jit sink count)
            np.asarray(np.arange(8.0)[::2]),  # non-contiguous source
            np.zeros((0, 4), dtype=np.float64),  # empty
        ]
        for a in arrs:
            b = decode_pytree(encode_pytree(a))
            assert b.shape == a.shape and b.dtype == a.dtype
            assert np.array_equal(b, a)

    def test_nested_containers_round_trip(self):
        x = {"a": (1, [2.0, {"b": np.ones((2,), np.float32)}]), "c": ()}
        y = decode_pytree(encode_pytree(x))
        assert isinstance(y["a"], tuple) and isinstance(y["a"][1], list)
        assert np.array_equal(y["a"][1][1]["b"], x["a"][1][1]["b"])
        assert y["c"] == ()

    def test_unencodable_leaf_raises(self):
        with pytest.raises(TypeError, match="cannot checkpoint"):
            encode_pytree(object())


# ---------------------------------------------------------------------------
# CheckpointStore mechanics
# ---------------------------------------------------------------------------


class TestCheckpointStore:
    def test_monotonic_ids_and_latest(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        p1 = store.save({"v": 1})
        p2 = store.save({"v": 2})
        p3 = store.save({"v": 3})
        assert [os.path.basename(p) for p in (p1, p2, p3)] == [
            "ckpt-00000001.json",
            "ckpt-00000002.json",
            "ckpt-00000003.json",
        ]
        cid, env = store.latest()
        assert cid == 3 and env["payload"] == {"v": 3}
        assert env["checkpoint_format"] == CHECKPOINT_FORMAT_VERSION

    def test_atomic_write_leaves_no_tmp(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save({"v": 1})
        assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]

    def test_torn_last_checkpoint_falls_back(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save({"v": 1})
        good = store.save({"v": 2})
        # a crash mid-write: the newest file is truncated JSON
        with open(store.path_of(3), "w") as f:
            f.write('{"checkpoint_format": 1, "payload": {"v": 3')
        cid, env = store.latest()
        assert cid == 2 and env["payload"] == {"v": 2}
        assert store.latest_payload() == {"v": 2}
        # and the torn id is never reused
        assert store.save({"v": 4}).endswith("ckpt-00000004.json")
        assert good != store.path_of(4)

    def test_sha_corruption_detected_and_skipped(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save({"v": 1})
        path2 = store.save({"v": 2})
        env = json.load(open(path2))
        env["payload"]["v"] = 999  # bit-flip after the digest was taken
        json.dump(env, open(path2, "w"))
        with pytest.raises(CheckpointError, match="sha256"):
            store.load(path2)
        assert store.latest_payload() == {"v": 1}

    def test_unsupported_format_rejected(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        path = store.save({"v": 1})
        env = json.load(open(path))
        env["checkpoint_format"] = 99
        env["sha256"] = payload_digest(env["payload"])
        json.dump(env, open(path, "w"))
        with pytest.raises(CheckpointError, match="unsupported format"):
            store.load(path)

    def test_missing_file_and_empty_dir(self, tmp_path):
        store = CheckpointStore(str(tmp_path / "nowhere"))
        assert store.list_ids() == []
        assert store.latest() is None
        with pytest.raises(CheckpointError, match="no valid checkpoint"):
            store.latest_payload()
        with pytest.raises(CheckpointError, match="does not exist"):
            store.load(str(tmp_path / "nope.json"))

    def test_is_checkpoint_path_dispatch(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        path = store.save({"v": 1})
        assert is_checkpoint_path(str(tmp_path))  # directory
        assert is_checkpoint_path(path)  # ckpt-*.json
        journal = tmp_path / "journal.jsonl"
        journal.write_text('{"op": "submit"}\n')
        assert not is_checkpoint_path(str(journal))

    def test_restore_refuses_dir_with_only_torn_checkpoints(self, tmp_path):
        (tmp_path / "ckpt-00000001.json").write_text("{ garbage")
        with pytest.raises(CheckpointError, match="no valid checkpoint"):
            StreamSystem.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# kill-at-any-step conformance — dry-run on the full OPMW rw1 trace
# ---------------------------------------------------------------------------

_BASELINES = {}


def _baseline(key, backend, dags_by_name, ops):
    if key not in _BASELINES:
        _BASELINES[key] = _run_uninterrupted(backend, dags_by_name, ops)
    return _BASELINES[key]


class TestKillRestoreDryrunOPMW:
    @pytest.mark.parametrize("kill_at", DRYRUN_KILLS)
    def test_rw1_full_trace(self, kill_at, ckpt_dir):
        """The acceptance contract: full 35-DAG OPMW rw1 trace, kill at a
        randomized event, restore, identical Fig. 2 series + account."""
        dags, ops = _opmw_dags(), _opmw_ops()
        base = _baseline(("dryrun", "rw1"), "dryrun", dags, ops)
        crashed = _run_with_crash("dryrun", dags, ops, kill_at, ckpt_dir)
        _assert_conformant(base, crashed)

    @pytest.mark.parametrize("kill_at", DRYRUN_KILLS[:3])
    def test_rw1_truncated_sink_counts(self, kill_at, ckpt_dir):
        """Truncated trace (submissions still present at the end) so the
        per-submission sink counts are a non-trivial comparison."""
        dags, ops = _opmw_dags(), _opmw_ops(truncate=60)
        base = _baseline(("dryrun", "rw1:60"), "dryrun", dags, ops)
        crashed = _run_with_crash("dryrun", dags, ops, min(kill_at, 58), ckpt_dir)
        _assert_conformant(base, crashed)
        assert crashed[1], "truncated trace should leave live submissions"


# ---------------------------------------------------------------------------
# kill-at-any-step conformance — jit backends
# ---------------------------------------------------------------------------


class TestKillRestoreJit:
    @pytest.mark.parametrize("kill_at", FIG1_KILLS)
    def test_inprocess_fig1(self, kill_at, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("inprocess", "fig1"), "inprocess", dags, FIG1_OPS)
        crashed = _run_with_crash("inprocess", dags, FIG1_OPS, kill_at, ckpt_dir)
        _assert_conformant(base, crashed)

    @pytest.mark.parametrize("kill_at", [2, 4])
    def test_sharded_fig1(self, kill_at, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("sharded", "fig1"), "sharded", dags, FIG1_OPS)
        crashed = _run_with_crash("sharded", dags, FIG1_OPS, kill_at, ckpt_dir)
        _assert_conformant(base, crashed)

    def test_inprocess_checksums_bit_exact_after_restore(self, ckpt_dir):
        """Same-backend jit restore round-trips full device state — sink
        *checksums* (order-sensitive folds), not just counts, continue as
        if the crash never happened."""
        dags = _fig1_dags()
        _, _, _, base_sys = _baseline(("inprocess", "fig1"), "inprocess", dags, FIG1_OPS)
        _, _, _, crashed_sys = _run_with_crash(
            "inprocess", dags, FIG1_OPS, 3, ckpt_dir
        )
        for name in base_sys.manager.submitted:
            assert crashed_sys.sink_digests(name) == base_sys.sink_digests(name)

    @pytest.mark.slow
    @pytest.mark.parametrize("backend", ["inprocess", "sharded"])
    def test_opmw_subset_seq_trace(self, backend, ckpt_dir):
        from repro.workloads import opmw_workload, seq_trace

        dags_list = opmw_workload()[:5]
        dags = {d.name: d for d in dags_list}
        ops = [(ev.op, ev.name) for ev in seq_trace(dags_list, seed=5)]
        base = _run_uninterrupted(backend, dags, ops)
        crashed = _run_with_crash(backend, dags, ops, len(ops) // 2, ckpt_dir)
        _assert_conformant(base, crashed)


# ---------------------------------------------------------------------------
# cross-backend restore (inprocess ↔ dryrun, sharded ↔ dryrun)
# ---------------------------------------------------------------------------


class TestCrossBackendRestore:
    @pytest.mark.parametrize("kill_at", [1, 4])
    def test_inprocess_checkpoint_restores_on_dryrun(self, kill_at, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("inprocess", "fig1"), "inprocess", dags, FIG1_OPS)
        crashed = _run_with_crash(
            "inprocess", dags, FIG1_OPS, kill_at, ckpt_dir, restore_backend="dryrun"
        )
        assert crashed[3].backend.name == "dryrun"
        _assert_conformant(base, crashed, cost_exact=False)

    @pytest.mark.parametrize("kill_at", [2, 5])
    def test_dryrun_checkpoint_restores_on_inprocess(self, kill_at, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("dryrun", "fig1"), "dryrun", dags, FIG1_OPS)
        crashed = _run_with_crash(
            "dryrun", dags, FIG1_OPS, kill_at, ckpt_dir, restore_backend="inprocess"
        )
        assert crashed[3].backend.name == "inprocess"
        _assert_conformant(base, crashed, cost_exact=False)

    def test_sharded_checkpoint_restores_on_dryrun(self, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("sharded", "fig1"), "sharded", dags, FIG1_OPS)
        crashed = _run_with_crash(
            "sharded", dags, FIG1_OPS, 3, ckpt_dir, restore_backend="dryrun"
        )
        _assert_conformant(base, crashed, cost_exact=False)

    def test_dryrun_checkpoint_restores_on_sharded(self, ckpt_dir):
        dags = _fig1_dags()
        base = _baseline(("dryrun", "fig1"), "dryrun", dags, FIG1_OPS)
        crashed = _run_with_crash(
            "dryrun", dags, FIG1_OPS, 4, ckpt_dir, restore_backend="sharded"
        )
        restored = crashed[3]
        _assert_conformant(base, crashed, cost_exact=False)
        # re-placement ran through the PlacementPolicy on the restoring host
        assert set(restored.backend.device_of) == set(restored.backend.segments)
        assert restored.backend.device_of_at_checkpoint == {}  # dryrun had none

    def test_cross_backend_dryrun_matches_jit_baseline_on_opmw(self, ckpt_dir):
        """OPMW-scale cross check on the dry-run side of the contract:
        checkpoint dryrun mid-trace, restore dryrun (identity) and compare
        against the dryrun baseline — the jit equivalence of those series
        is already covered by test_backends.TestDryRunContract."""
        dags, ops = _opmw_dags(), _opmw_ops(truncate=40)
        base = _baseline(("dryrun", "rw1:40"), "dryrun", dags, ops)
        crashed = _run_with_crash("dryrun", dags, ops, 20, ckpt_dir)
        _assert_conformant(base, crashed)


# ---------------------------------------------------------------------------
# durable lifecycle details
# ---------------------------------------------------------------------------


class TestDurableLifecycle:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_payload_roundtrip_is_fixed_point(self, backend, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend=backend)
        for op, name in FIG1_OPS[:6]:
            _apply(system, dags, op, name)
            system.step()
        payload = system.checkpoint_payload()
        blob = json.dumps(payload, sort_keys=True)
        restored = StreamSystem.from_payload(json.loads(blob))
        assert restored.checkpoint_payload() == payload
        assert restored.backend.snapshot() == system.backend.snapshot()

    def test_paused_tasks_stay_paused_and_cost_epsilon(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="dryrun")
        for name in "ABCD":
            system.submit(dags[name].copy())
        system.step()
        system.remove("D")  # D is disjoint: all 4 tasks pause
        system.step()
        system.checkpoint(ckpt_dir)
        restored = StreamSystem.restore(ckpt_dir)
        assert restored.backend.paused == system.backend.paused
        live, paused, _ = restored.backend.account()
        assert (live, paused) == (8, 4)
        # resume still works post-restore (the inverse control signal)
        restored.backend.resume(set(system.backend.paused))
        assert restored.backend.account()[:2] == (12, 0)

    def test_forward_signals_survive_restore(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(dags["A"].copy())
        system.submit(dags["B"].copy())  # reuses A's prefix → forward signal
        system.run(2)
        fwd = {n: set(s) for n, s in system.backend.forwarding.items()}
        assert any(fwd.values()), "expected runtime forward() signals"
        system.checkpoint(ckpt_dir)
        restored = StreamSystem.restore(ckpt_dir)
        assert {n: set(s) for n, s in restored.backend.forwarding.items()} == fwd

    def test_defragmented_state_survives_restore(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="dryrun")
        for name in "ABC":
            system.submit(dags[name].copy())
        system.run(3)
        system.remove("B")
        system.defragment()  # paused tasks dropped, one fused segment
        system.step()
        system.checkpoint(ckpt_dir)
        before = _final_state(system)
        restored = StreamSystem.restore(ckpt_dir)
        assert _final_state(restored) == before
        assert len(restored.backend.segments) == len(system.backend.segments)
        assert not restored.backend.paused

    def test_restore_into_used_backend_raises(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="dryrun")
        system.submit(dags["A"].copy())
        system.checkpoint(ckpt_dir)
        dirty = StreamSystem(strategy="signature", backend="dryrun")
        dirty.submit(dags["B"].copy())
        with pytest.raises(ValueError, match="fresh backend"):
            dirty.backend.restore_state(
                CheckpointStore(ckpt_dir).latest_payload()["data"]
            )

    def test_broker_buffers_and_counters_survive(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(dags["A"].copy())
        system.submit(dags["B"].copy())
        system.run(2)
        broker = system.backend.broker
        system.checkpoint(ckpt_dir)
        restored = StreamSystem.restore(ckpt_dir)
        rbroker = restored.backend.broker
        assert set(rbroker.topics()) == set(broker.topics())
        for t, batch in broker.topics().items():
            assert np.array_equal(np.asarray(rbroker.fetch(t)), np.asarray(batch))
        assert rbroker.bytes_published == broker.bytes_published
        assert rbroker.publishes == broker.publishes

    def test_ewma_and_owner_index_survive(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="dryrun")
        for name in "ABC":
            system.submit(dags[name].copy())
        system.run(4)
        system.checkpoint(ckpt_dir)
        restored = StreamSystem.restore(ckpt_dir)
        assert restored.backend.ewma_ms == system.backend.ewma_ms
        assert restored.backend._owner_of == system.backend._owner_of
        assert restored.backend.task_defs == system.backend.task_defs
        assert restored.task_batch == system.task_batch
        assert restored._segments_of == system._segments_of


# ---------------------------------------------------------------------------
# ReuseSession recovery: hooks, stats, cadence
# ---------------------------------------------------------------------------


class TestSessionRecovery:
    def _flows(self):
        from repro.api import flow

        a = (
            flow("A").source("urban").then("senml_parse").then("kalman", q=0.1)
            .sink("store").build()
        )
        b = (
            flow("B").source("urban").then("senml_parse").then("kalman", q=0.1)
            .then("avg").sink("store").build()
        )
        return a, b

    def test_session_restore_full_system(self, ckpt_dir):
        from repro.api import ReuseSession

        a, b = self._flows()
        session = ReuseSession(execute=True, backend="dryrun", checkpoint_dir=ckpt_dir)
        session.submit(a)
        session.run(3)
        session.submit(b)
        session.run(2)
        session.checkpoint()
        want = session.sink_digests("A"), session.sink_digests("B")
        restored = ReuseSession.restore(ckpt_dir)
        assert restored.executes and restored.backend_name == "dryrun"
        assert (restored.sink_digests("A"), restored.sink_digests("B")) == want

    def test_hooks_survive_restore(self, ckpt_dir):
        """The satellite fix: on_merge/on_step hooks passed to restore()
        re-attach to the restored planes and fire for post-restore ops."""
        from repro.api import ReuseSession, flow

        a, b = self._flows()
        session = ReuseSession(execute=True, backend="dryrun", checkpoint_dir=ckpt_dir)
        session.submit(a)
        session.run(2)
        session.checkpoint()

        seen = []
        restored = ReuseSession.restore(
            ckpt_dir,
            on_merge=lambda ev: seen.append(("merge", ev.name)),
            on_step=lambda ev: seen.append(("step", ev.step)),
        )
        restored.submit(b)
        restored.step()
        assert ("merge", "B") in seen
        # step numbering continues from the checkpointed count (2), so the
        # re-attached hook sees the *global* step index — stats continuity
        assert ("step", 3) in seen
        # decorator registration still works on a restored session
        @restored.on_step
        def _more(ev):
            seen.append(("step2", ev.step))

        restored.step()
        assert ("step2", 4) in seen

    def test_stats_continuity_after_restore(self, ckpt_dir):
        from repro.api import ReuseSession

        a, b = self._flows()
        session = ReuseSession(execute=True, backend="dryrun", checkpoint_dir=ckpt_dir)
        session.submit(a)
        session.submit(b)
        session.run(5)
        session.checkpoint()
        before = session.stats()
        restored = ReuseSession.restore(ckpt_dir)
        after = restored.stats()
        assert after == before
        restored.step()
        assert restored.stats().steps_run == before.steps_run + 1

    def test_checkpoint_every_cadence(self, ckpt_dir):
        from repro.api import ReuseSession

        a, _ = self._flows()
        session = ReuseSession(
            execute=True, backend="dryrun", checkpoint_dir=ckpt_dir, checkpoint_every=2
        )
        session.submit(a)
        session.run(7)  # steps 2, 4, 6 auto-checkpoint
        store = CheckpointStore(ckpt_dir)
        assert store.list_ids() == [1, 2, 3]
        # the restored session resumes at step 6 (step 7 died with the
        # crash) and keeps cadence + directory: steps 7, 8 → checkpoint 4
        restored = ReuseSession.restore(ckpt_dir)
        restored.run(2)
        assert store.list_ids() == [1, 2, 3, 4]

    def test_checkpoint_needs_data_plane(self, tmp_path):
        from repro.api import ReuseSession
        from repro.core import DataflowError

        with pytest.raises(DataflowError, match="data plane"):
            ReuseSession(checkpoint_dir=str(tmp_path))
        session = ReuseSession()
        with pytest.raises(DataflowError, match="data plane"):
            session.checkpoint(str(tmp_path))

    def test_journal_restore_still_control_plane_only(self, tmp_path):
        from repro.api import ReuseSession

        a, b = self._flows()
        path = str(tmp_path / "journal.jsonl")
        session = ReuseSession(journal_path=path)
        session.submit(a)
        session.submit(b)
        restored = ReuseSession.restore(path)
        assert not restored.executes
        restored.verify()
        assert restored.running_task_count == session.running_task_count


# ---------------------------------------------------------------------------
# launch CLI crash-resume
# ---------------------------------------------------------------------------


def _run_cli(args, **kw):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        env=env, capture_output=True, text=True, **kw,
    )


class TestCliRecovery:
    def test_crash_resume_matches_uninterrupted(self, ckpt_dir, tmp_path):
        full = str(tmp_path / "full.json")
        part = str(tmp_path / "part.json")
        rest = str(tmp_path / "rest.json")
        base = _run_cli(["--trace", "riot/seq", "--json", full])
        assert base.returncode == 0, base.stderr
        crash = _run_cli(
            ["--trace", "riot/seq", "--checkpoint-dir", ckpt_dir,
             "--max-events", "17", "--json", part]
        )
        assert crash.returncode == 0, crash.stderr
        resume = _run_cli(
            ["--trace", "riot/seq", "--checkpoint-dir", ckpt_dir,
             "--restore", "--json", rest]
        )
        assert resume.returncode == 0, resume.stderr
        full_rec = json.load(open(full))
        part_rec = json.load(open(part))
        rest_rec = json.load(open(rest))
        assert rest_rec["resumed_at_event"] == 17
        stitched = {
            k: part_rec["series"][k] + rest_rec["series"][k]
            for k in ("live_tasks", "paused_tasks", "cores")
        }
        # makespan_ms is measured wall-time (timing-dependent), so the
        # stitching identity covers the deterministic counter series.
        assert stitched == {k: full_rec["series"][k] for k in stitched}

    def test_restore_without_checkpoint_dir_fails(self):
        proc = _run_cli(["--trace", "riot/seq", "--restore"])
        assert proc.returncode != 0
        assert "--checkpoint-dir" in (proc.stderr + proc.stdout)


# ---------------------------------------------------------------------------
# background checkpointing — snapshot on the stepping thread, encode/fsync/
# rename on a writer thread; torn-write semantics unchanged
# ---------------------------------------------------------------------------


class TestBackgroundCheckpointing:
    def test_deferred_encoding_is_payload_identical(self):
        """The snapshot + writer-thread encode must produce byte-identical
        payloads to the synchronous path (jit states, broker buffers and
        all) — background mode changes *when* encoding happens, never what
        is written."""
        from repro.runtime.checkpoint import deferred_encoder, encode_deferred

        dags = _fig1_dags()
        system = StreamSystem(strategy="signature", backend="inprocess")
        for op, name in FIG1_OPS[:4]:
            _apply(system, dags, op, name)
            system.step()
        sync_payload = system.checkpoint_payload()
        bg_payload = encode_deferred(system.checkpoint_payload(deferred_encoder))
        assert bg_payload == sync_payload

    def test_cadence_writes_off_thread_and_restores(self, ckpt_dir):
        dags = _fig1_dags()
        system = StreamSystem(
            strategy="signature", backend="dryrun",
            checkpoint_dir=ckpt_dir, checkpoint_every=1,
            checkpoint_background=True,
        )
        for op, name in FIG1_OPS:
            _apply(system, dags, op, name)
            system.step()
        system.flush_checkpoints()
        store = CheckpointStore(ckpt_dir)
        assert len(store.list_ids()) == len(FIG1_OPS)
        digests, acct = _final_state(system)
        restored = StreamSystem.restore(ckpt_dir)
        assert restored.checkpoint_background  # survives the restore
        r_digests, r_acct = _final_state(restored)
        assert r_digests == digests and r_acct == acct

    @pytest.mark.parametrize("kill_at", [5, 23])
    def test_kill_at_event_with_background_writer(self, kill_at, ckpt_dir):
        """Crash without a flush: queued-but-unwritten checkpoints are lost,
        the restore lands on the newest durable prefix (journal length =
        resume offset), and the finished trace is conformant with the
        uninterrupted baseline — the kill-at-any-step contract, unchanged.

        The crash is simulated deterministically by gating the store: only
        the first ``durable`` saves reach disk, the rest behave like
        checkpoints still queued when the process died — so the truncated
        prefix + replayed tail path is exercised on every run (a plain
        ``del`` races the daemon writer, which usually wins)."""
        dags, ops = _opmw_dags(), _opmw_ops(truncate=40)
        base = _baseline(("dryrun", "rw1:40"), "dryrun", dags, ops)

        system = StreamSystem(
            strategy="signature", backend="dryrun",
            checkpoint_dir=ckpt_dir, checkpoint_every=1,
            checkpoint_background=True,
        )
        durable = kill_at // 2 + 1  # checkpoints that beat the crash to disk
        real_save = system.checkpoint_store.save
        written = []

        def gated_save(payload):
            if len(written) >= durable:
                return "<lost-in-crash>"  # queued but never made durable
            written.append(1)
            return real_save(payload)

        system.checkpoint_store.save = gated_save
        series = []
        for op, name in ops[: kill_at + 1]:
            _apply(system, dags, op, name)
            rep = system.step()
            series.append((rep.live_tasks, rep.paused_tasks, rep.cost))
        system.flush_checkpoints()  # drain the queue through the gate
        del system  # the crash

        restored = StreamSystem.restore(ckpt_dir)
        resumed = len(restored.manager.journal)
        assert resumed == durable  # newest durable prefix, tail lost
        series = series[:resumed]  # replayed events re-produce the tail
        for op, name in ops[resumed:]:
            _apply(restored, dags, op, name)
            rep = restored.step()
            series.append((rep.live_tasks, rep.paused_tasks, rep.cost))
        digests, acct = _final_state(restored)
        _assert_conformant(base, (series, digests, acct, restored))

    def test_explicit_checkpoint_flushes_queue_first(self, ckpt_dir):
        system = StreamSystem(
            strategy="signature", backend="dryrun",
            checkpoint_dir=ckpt_dir, checkpoint_every=1,
            checkpoint_background=True,
        )
        system.submit(_fig1_dags()["A"].copy())
        system.step()  # queues checkpoint 1 in the background
        path = system.checkpoint()  # must flush, then write synchronously
        store = CheckpointStore(ckpt_dir)
        ids = store.list_ids()
        assert len(ids) == 2 and path.endswith(store.filename(ids[-1]))

    def test_writer_failure_surfaces_on_flush(self, ckpt_dir):
        from repro.runtime.checkpoint import CheckpointError

        system = StreamSystem(
            strategy="signature", backend="dryrun",
            checkpoint_dir=ckpt_dir, checkpoint_every=1,
            checkpoint_background=True,
        )
        def boom(payload):
            raise OSError("disk on fire")

        system.checkpoint_store.save = boom
        system.submit(_fig1_dags()["A"].copy())
        system.step()
        with pytest.raises(CheckpointError, match="background checkpoint"):
            system.flush_checkpoints()

    def test_needs_checkpoint_dir(self):
        with pytest.raises(ValueError, match="checkpoint_background"):
            StreamSystem(backend="dryrun", checkpoint_background=True)
        from repro.api import ReuseSession
        from repro.core import DataflowError

        with pytest.raises(DataflowError, match="checkpoint_background"):
            ReuseSession(checkpoint_background=True)

    def test_session_background_smoke(self, ckpt_dir):
        from repro.api import ReuseSession

        with ReuseSession(
            strategy="signature", execute=True, backend="dryrun",
            checkpoint_dir=ckpt_dir, checkpoint_every=1,
            checkpoint_background=True,
        ) as session:
            session.submit(_fig1_dags()["A"].copy())
            session.run(3)
        # context exit closes the system, which flushes the writer
        restored = ReuseSession.restore(ckpt_dir)
        assert restored.stats().steps_run == 3
