"""Hot-path tests: segment fusion, worker chain batching, buffer donation.

Four layers:
  * pure planning — :func:`repro.runtime.scheduler.compute_chains` (worker-
    local dependency batching) and :func:`repro.core.defrag.plan_fusion`
    (maximal private-pipe segment chains);
  * donation — fused segments compile with XLA buffer donation and the
    executable's memory analysis proves the aliasing holds (and that
    unfused segments don't alias);
  * semantics — fused and unfused deployments produce bit-identical sink
    digests across transports, step modes and backends, including the
    worker ``step_chain`` batching on/off;
  * guards — background checkpointing disables donation, fuse() is a
    no-op when there is nothing linear to fuse.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.defrag import plan_fusion
from repro.runtime.scheduler import compute_chains

from helpers import chain_df, fig1


# -- planning ------------------------------------------------------------------


class TestComputeChains:
    def test_chains_follow_global_wave_order(self):
        deps = {"a": set(), "b": {"a"}, "c": {"a"}, "d": {"b", "c"}}
        order = {"a": 0, "b": 1, "c": 2, "d": 3}
        chains, wave_of = compute_chains(
            deps, {"a": 0, "b": 0, "c": 1, "d": 0}, order=order
        )
        assert chains == {0: ["a", "b", "d"], 1: ["c"]}
        assert wave_of == {"a": 0, "b": 1, "c": 1, "d": 2}

    def test_single_worker_gets_one_chain(self):
        deps = {"a": set(), "b": {"a"}, "c": {"b"}}
        chains, _ = compute_chains(deps, {"a": 7, "b": 7, "c": 7})
        assert chains == {7: ["a", "b", "c"]}


class TestPlanFusion:
    def test_linear_chain_found(self):
        deps = {"s1": set(), "s2": {"s1"}, "s3": {"s2"}}
        plan = plan_fusion(deps, {"s1": "run1", "s2": "run2", "s3": "run3"})
        assert [c.members for c in plan.chains] == [["s1", "s2", "s3"]]
        # labeled with the *newest* member's running-DAG name (merges
        # rename the running DAG as it grows)
        assert plan.chains[0].dag_name == "run3"
        assert plan.total_segments == 3

    def test_fan_out_blocks_fusion_but_downstream_chain_survives(self):
        # a feeds b AND c → a joins no chain; b→d is still a private pipe
        deps = {"a": set(), "b": {"a"}, "c": {"a"}, "d": {"b"}}
        plan = plan_fusion(deps, {n: "r" for n in deps})
        assert [c.members for c in plan.chains] == [["b", "d"]]

    def test_fan_in_blocks_fusion(self):
        deps = {"a": set(), "b": set(), "c": {"a", "b"}}
        assert plan_fusion(deps, {n: "r" for n in deps}).chains == []

    def test_min_length_respected(self):
        deps = {"a": set(), "b": {"a"}}
        assert plan_fusion(deps, {n: "r" for n in deps}, min_length=3).chains == []
        plan = plan_fusion(deps, {n: "r" for n in deps}, min_length=2)
        assert [c.members for c in plan.chains] == [["a", "b"]]


# -- donation ------------------------------------------------------------------


class TestDonation:
    def test_fused_segment_aliases_buffers_unfused_does_not(self):
        from repro.runtime.segment import donation_report
        from repro.runtime.system import StreamSystem

        A, B, C, _ = fig1()
        system = StreamSystem(strategy="signature", backend="inprocess")
        for df in (A, B, C):
            system.submit(df.copy())
        system.run(2)
        unfused = list(system.backend.segments.values())[0]
        rep0 = donation_report(unfused, _boundary_inputs(system, unfused))
        assert not rep0["fused"]
        assert not rep0["donation_holds"]
        assert rep0["alias_size_in_bytes"] == 0

        fused = system.fuse()
        assert len(fused) == 1
        (name,) = fused
        seg = system.backend.segments[name]
        assert seg.spec.fused
        rep1 = donation_report(seg, _boundary_inputs(system, seg))
        assert rep1["donation_holds"]
        assert rep1["alias_size_in_bytes"] > 0
        # donated states mean the step allocates less than argument+output
        assert (
            rep1["total_allocation_size"]
            < rep1["argument_size_in_bytes"]
            + rep1["output_size_in_bytes"]
            + rep1["temp_size_in_bytes"]
        )
        system.close()

    def test_background_checkpointing_disables_donation(self, tmp_path):
        from repro.runtime.system import StreamSystem

        A, B, _, _ = fig1()
        system = StreamSystem(
            strategy="signature",
            backend="inprocess",
            checkpoint_dir=str(tmp_path),
            checkpoint_every=1,
            checkpoint_background=True,
        )
        system.submit(A.copy())
        system.submit(B.copy())
        system.run(2)
        fused = system.fuse()
        assert fused  # the chain still fuses into one segment...
        for name in fused:
            # ...but without donation: the deferred checkpoint encoder
            # holds step-k state references a donated step would invalidate
            assert not system.backend.segments[name].spec.fused
        system.run(2)
        system.quiesce()
        system.close()


def _boundary_inputs(system, seg):
    return {t: np.asarray(system.backend.transport.fetch(t)) for t in seg.boundary_topics}


# -- semantics: fused == unfused, chained == unchained -------------------------


CHURN = [("add", "A"), ("add", "B"), ("add", "C"), ("remove", "B"), ("add", "D")]


def _run_churn(transport, step_mode, fuse, **kw):
    from repro.runtime.system import StreamSystem

    dags = {d.name: d for d in fig1()}
    system = StreamSystem(
        strategy="signature",
        transport=transport,
        step_mode=step_mode,
        **kw,
    )
    for op, name in CHURN:
        if op == "add":
            system.submit(dags[name].copy())
        else:
            system.remove(name)
        system.step()
    if fuse:
        system.fuse()
    system.run(3)
    digests = {n: system.sink_digests(n) for n in sorted(system.manager.submitted)}
    system.close()
    return digests


class TestFusedDigestIdentity:
    @pytest.mark.parametrize("transport", ["inproc", "shm", "tcp"])
    @pytest.mark.parametrize("step_mode", ["sync", "concurrent"])
    def test_fig1_churn_all_transports_both_modes(self, transport, step_mode):
        ref = _run_churn(transport, step_mode, fuse=False, backend="inprocess")
        got = _run_churn(transport, step_mode, fuse=True, backend="inprocess")
        assert got == ref  # counts AND checksums — bit-identical sinks

    def test_kernel_backed_op_fuses_bit_identically(self):
        from repro.runtime.system import StreamSystem

        stages = [("parse", {}), ("rmsnorm", {}), ("kalman", {"q": 0.1})]
        A = chain_df("KA", "urban", stages)
        B = chain_df("KB", "urban", stages + [("rmsnorm", {"eps": 1e-5})])

        def run(fuse):
            system = StreamSystem(strategy="signature", backend="inprocess")
            system.submit(A.copy())
            system.submit(B.copy())
            system.run(2)
            if fuse:
                fused = system.fuse()
                assert fused  # KB's suffix chain fused onto KA's segment
            system.run(3)
            out = {n: system.sink_digests(n) for n in ("KA", "KB")}
            system.close()
            return out

        assert run(True) == run(False)

    def test_fuse_noop_when_nothing_linear(self):
        from repro.runtime.system import StreamSystem

        dags = {d.name: d for d in fig1()}
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(dags["A"].copy())
        system.submit(dags["D"].copy())  # disjoint DAGs — no private pipes
        system.run(1)
        assert system.fuse() == {}
        fused = None
        system.submit(dags["B"].copy())
        system.step()
        fused = system.fuse()
        assert len(fused) == 1
        assert system.fuse() == {}  # idempotent: the chain is gone
        system.close()


@pytest.mark.slow
class TestOpmwTraceIdentity:
    """Truncated OPMW random-walk trace: fused == unfused in both step
    modes (the full rw1 trace runs in benchmarks/hotpath_bench.py)."""

    @pytest.mark.parametrize("step_mode", ["sync", "concurrent"])
    def test_rw_trace_fused_identity(self, step_mode):
        from repro.api import ReuseSession
        from repro.workloads import opmw_workload, replay, rw_trace

        dags = opmw_workload()[:8]
        events = rw_trace(dags, seed=11, steps=10)

        def run(fuse):
            session = ReuseSession(
                execute=True, backend="inprocess", step_mode=step_mode
            )
            for i, _ in enumerate(replay(session, dags, events)):
                session.step()
                if fuse and i % 5 == 4:
                    session.fuse()
            session.run(2)
            out = {
                n: session.sink_digests(n)
                for n in sorted(session.manager.submitted)
            }
            session.close()
            return out

        assert run(True) == run(False)


@pytest.mark.slow
class TestChainBatching:
    def test_chain_on_off_digests_identical(self):
        ref = _run_churn(
            "shm", "concurrent", fuse=False,
            backend="multiproc", workers=2,
            backend_options={"chain_batching": False},
        )
        got = _run_churn(
            "shm", "concurrent", fuse=False,
            backend="multiproc", workers=2,
            backend_options={"chain_batching": True},
        )
        assert got == ref

    def test_chain_batching_composes_with_fusion(self):
        ref = _run_churn(
            "shm", "concurrent", fuse=False,
            backend="multiproc", workers=2,
            backend_options={"chain_batching": False},
        )
        got = _run_churn(
            "shm", "concurrent", fuse=True,
            backend="multiproc", workers=2,
        )
        assert got == ref

    def test_chains_disabled_under_rpc_timeout(self):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(
            strategy="signature", backend="multiproc", workers=1,
            step_mode="concurrent",
        )
        be = system.backend
        assert be._use_chains()
        be.rpc_timeout = 5.0  # supervised: per-wave RPCs keep hang detection
        assert not be._use_chains()
        be.rpc_timeout = None
        be.chain_batching = False
        assert not be._use_chains()
        system.close()
