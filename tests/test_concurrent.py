"""Concurrent dependency-aware stepping pipeline tests.

Five layers:
  * wave / ready-queue scheduler units (``compute_waves`` /
    ``run_ready_queue``): topological levels, launch-order tie-breaks,
    genuine thread overlap, error draining, cycle detection and
    persistent-pool reuse;
  * the topic-granular Broker: per-topic sequencing (``fetch_synced``),
    drop-safety under in-flight dispatch, and the no-leak topic lifecycle
    across kill/unmerge/defragment under concurrent stepping;
  * the determinism contract: for every backend ``step_mode="concurrent"``
    yields per-DAG sink counts identical to ``"sync"`` — on the fig-1
    churn scenario, on the OPMW rw1 trace (full trace on dryrun, a
    truncated slice on the jit planes), and across a checkpoint/restore
    boundary taken in either mode and restored into either mode;
  * EWMA-fed adaptive placement: ``ewma_aware`` assigns new segments to
    the least-pressured device and migrates an injected straggler off its
    device on redispatch;
  * the satellites: CheckpointStore ``keep_last`` retention GC, dry-run
    latency calibration (``fit_latency_model`` → realistic ``segment_ms``
    and a wave-max makespan), and the opt-in StepReport ring buffer
    surviving checkpoint/restore.

The CI concurrency-stress job runs this module at ``max_workers`` 1 and 4
via ``REPRO_TEST_MAX_WORKERS`` (width must never change results).
"""
from __future__ import annotations

import json
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.ops.costs import LatencyModel, fit_latency_model
from repro.runtime.broker import Broker, topic_for
from repro.runtime.checkpoint import CheckpointStore
from repro.runtime.scheduler import (
    EwmaAwarePlacement,
    WaveEvent,
    compute_waves,
    resolve_placement,
    run_ready_queue,
)
from repro.runtime.system import StreamSystem

from helpers import chain_df, fig1

BACKENDS = ["inprocess", "sharded", "dryrun"]
JIT_BACKENDS = ["inprocess", "sharded"]

# The CI stress job sweeps this (1 = serialized dispatch, 4 = real overlap);
# results must be identical at any width.
MAX_WORKERS = int(os.environ.get("REPRO_TEST_MAX_WORKERS", "4"))

# (op, name) churn used by the cross-mode determinism tests; every event is
# followed by one step, with a tail of extra steps after the last event.
FIG1_OPS = [
    ("add", "A"),
    ("add", "B"),
    ("add", "C"),
    ("add", "D"),
    ("remove", "B"),
    ("defrag", ""),
    ("remove", "A"),
    ("add", "B"),
]


def _fig1_by_name():
    return {d.name: d for d in fig1()}


def _apply(system, dags_by_name, op, name):
    if op == "add":
        system.submit(dags_by_name[name].copy())
    elif op == "remove":
        system.remove(name)
    elif op == "defrag":
        system.defragment()
    else:  # pragma: no cover - defensive
        raise ValueError(op)


def _sink_counts(system):
    return {
        name: {s: d["count"] for s, d in system.sink_digests(name).items()}
        for name in system.manager.submitted
    }


def _run_ops(backend, dags_by_name, ops, step_mode, tail_steps=3, **kw):
    system = StreamSystem(
        strategy="signature",
        backend=backend,
        step_mode=step_mode,
        max_workers=MAX_WORKERS,
        **kw,
    )
    series = []
    for op, name in ops:
        _apply(system, dags_by_name, op, name)
        rep = system.step()
        series.append((rep.live_tasks, rep.paused_tasks, round(rep.cost, 6)))
    for _ in range(tail_steps):
        rep = system.step()
        series.append((rep.live_tasks, rep.paused_tasks, round(rep.cost, 6)))
    counts = _sink_counts(system)
    system.close()
    return series, counts, system


def _opmw_dags():
    from repro.workloads import opmw_workload

    return {d.name: d for d in opmw_workload()}


def _opmw_ops(truncate=None):
    from repro.workloads import opmw_workload, rw_trace

    dags = opmw_workload()
    events = [(ev.op, ev.name) for ev in rw_trace(dags, seed=11)]
    return events[:truncate] if truncate else events


# -- wave scheduler units -------------------------------------------------------


class TestComputeWaves:
    def test_empty(self):
        assert compute_waves({}) == []

    def test_chain(self):
        deps = {"a": set(), "b": {"a"}, "c": {"b"}}
        assert compute_waves(deps) == [["a"], ["b"], ["c"]]

    def test_diamond(self):
        deps = {"a": set(), "b": {"a"}, "c": {"a"}, "d": {"b", "c"}}
        assert compute_waves(deps) == [["a"], ["b", "c"], ["d"]]

    def test_order_breaks_ties_within_wave(self):
        deps = {"x": set(), "y": set(), "z": set()}
        waves = compute_waves(deps, order={"x": 3, "y": 1, "z": 2})
        assert waves == [["y", "z", "x"]]

    def test_cycle_raises(self):
        with pytest.raises(ValueError, match="cycle"):
            compute_waves({"a": {"b"}, "b": {"a"}})


class TestRunReadyQueue:
    def test_respects_dependencies(self):
        deps = {"a": set(), "b": {"a"}, "c": {"a"}, "d": {"b", "c"}}
        done, lock = [], threading.Lock()

        def runner(name):
            time.sleep(0.005)
            with lock:
                done.append(name)
            return 1.0

        out = run_ready_queue(deps, runner, max_workers=MAX_WORKERS)
        assert set(out) == set(deps)
        assert done.index("a") < done.index("b")
        assert done.index("a") < done.index("c")
        assert done.index("d") == 3

    def test_independent_segments_genuinely_overlap(self):
        """Both runners must be in flight at once or the rendezvous hangs."""
        ev_a, ev_b = threading.Event(), threading.Event()

        def runner(name):
            mine, theirs = (ev_a, ev_b) if name == "a" else (ev_b, ev_a)
            mine.set()
            assert theirs.wait(timeout=10.0), "independent segments serialized"
            return 1.0

        out = run_ready_queue({"a": set(), "b": set()}, runner, max_workers=2)
        assert set(out) == {"a", "b"}

    def test_error_propagates_and_halts_dependents(self):
        ran = []

        def runner(name):
            ran.append(name)
            if name == "a":
                raise RuntimeError("boom")
            return 1.0

        deps = {"a": set(), "b": {"a"}, "c": set()}
        with pytest.raises(RuntimeError, match="boom"):
            run_ready_queue(deps, runner, max_workers=1)
        assert "b" not in ran  # dependent of the failed segment never dispatched

    def test_cycle_raises(self):
        with pytest.raises(RuntimeError, match="cycle"):
            run_ready_queue({"a": {"b"}, "b": {"a"}}, lambda n: 0.0)

    def test_external_pool_reused_not_shut_down(self):
        pool = ThreadPoolExecutor(max_workers=2)
        try:
            for _ in range(3):
                out = run_ready_queue(
                    {"a": set(), "b": {"a"}}, lambda n: 0.5, pool=pool
                )
                assert out == {"a": 0.5, "b": 0.5}
            # still alive: the caller owns its lifecycle
            assert pool.submit(lambda: 42).result() == 42
        finally:
            pool.shutdown()

    def test_backend_keeps_persistent_pool(self):
        from repro.runtime.backend import ExecutionBackend

        sys_ = StreamSystem(
            strategy="signature", backend="inprocess",
            step_mode="concurrent", max_workers=2,
        )
        for df in fig1()[:2]:
            sys_.submit(df.copy())
        sys_.step()
        pool = sys_.backend._pool
        assert pool is not None
        sys_.step()
        assert sys_.backend._pool is pool  # reused, not re-created per step
        sys_.backend.configure_stepping(max_workers=3)  # resize drops the pool
        assert sys_.backend._pool is None
        sys_.step()
        assert sys_.backend._pool is not None
        sys_.close()
        assert sys_.backend._pool is None
        assert isinstance(sys_.backend, ExecutionBackend)


# -- topic-granular broker ------------------------------------------------------


def _batch(fill=1.0, n=4):
    return np.full((n, 8), fill, dtype=np.float32)


class TestBrokerTopics:
    def test_sequence_advances_per_publish(self):
        b = Broker()
        assert b.seq("t") == 0
        b.publish("t", _batch())
        b.publish("t", _batch(2.0))
        assert b.seq("t") == 2
        assert b.sequences() == {"t": 2}

    def test_fetch_synced_returns_once_sequence_reached(self):
        b = Broker()
        b.publish("t", _batch(7.0))
        out = b.fetch_synced("t", 1)
        assert float(out[0, 0]) == 7.0

    def test_fetch_synced_blocks_until_producer_publishes(self):
        b = Broker()
        got = []

        def consumer():
            got.append(b.fetch_synced("t", 1, timeout=10.0))

        t = threading.Thread(target=consumer)
        t.start()
        time.sleep(0.02)
        assert not got  # still waiting on the producer
        b.publish("t", _batch(3.0))
        t.join(timeout=10.0)
        assert got and float(got[0][0, 0]) == 3.0

    def test_drop_wakes_blocked_fetch_with_keyerror(self):
        b = Broker()
        b.publish("t", _batch())
        errs = []

        def consumer():
            try:
                b.fetch_synced("t", 2, timeout=10.0)
            except KeyError as e:
                errs.append(e)

        t = threading.Thread(target=consumer)
        t.start()
        time.sleep(0.02)
        b.drop("t")  # kill/unmerge mid-step: waiter must not deadlock
        t.join(timeout=10.0)
        assert len(errs) == 1

    def test_drop_then_republish_resets_sequence(self):
        b = Broker()
        b.publish("t", _batch())
        b.drop("t")
        assert not b.has("t")
        with pytest.raises(KeyError):
            b.fetch("t")
        b.publish("t", _batch())
        assert b.seq("t") == 1  # fresh topic state after drop

    def test_len_and_topics_count_only_published(self):
        b = Broker()
        b.publish("a", _batch())
        b.publish("b", _batch())
        b.drop("a")
        assert len(b) == 1
        assert set(b.topics()) == {"b"}

    def test_publish_counters_thread_safe(self):
        b = Broker()
        batch = _batch()

        def blast(topic):
            for _ in range(200):
                b.publish(topic, batch)

        threads = [threading.Thread(target=blast, args=(f"t{i}",)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert b.publishes == 800
        assert b.bytes_published == 800 * batch.size * batch.dtype.itemsize


class TestTopicLifecycle:
    """Regression-guard: per-topic state never leaks past its segment."""

    def _live_task_topics(self, backend):
        return {
            topic_for(tid)
            for seg in backend.segments.values()
            for tid in seg.spec.task_ids
        }

    def test_no_topic_leaks_across_churn_concurrent(self):
        dags = _fig1_by_name()
        sys_ = StreamSystem(
            strategy="signature", backend="inprocess",
            step_mode="concurrent", max_workers=MAX_WORKERS,
        )
        for op, name in FIG1_OPS:
            _apply(sys_, dags, op, name)
            sys_.step()
            # every registered topic belongs to a deployed task; nothing
            # from killed segments (defrag/unmerge/kill) survives
            assert set(sys_.backend.broker._topics) <= self._live_task_topics(
                sys_.backend
            )
        sys_.close()

    def test_defragment_drops_boundary_topics(self):
        dags = _fig1_by_name()
        sys_ = StreamSystem(
            strategy="signature", backend="inprocess",
            step_mode="concurrent", max_workers=MAX_WORKERS,
        )
        for name in ("A", "B", "C"):
            sys_.submit(dags[name].copy())
        sys_.run(2)
        assert len(sys_.backend.broker) > 0  # incremental merge → boundaries
        sys_.defragment()
        # one fused segment per DAG: no cross-segment streams remain, and
        # the killed segments' topics went with them
        sys_.run(2)
        assert len(sys_.backend.seg_deps) == len(sys_.backend.segments)
        assert all(not d for d in sys_.backend.seg_deps.values())
        assert set(sys_.backend.broker._topics) <= self._live_task_topics(
            sys_.backend
        )
        sys_.close()

    def test_remove_sole_submission_drops_all_topics(self):
        dags = _fig1_by_name()
        sys_ = StreamSystem(
            strategy="none", backend="inprocess",
            step_mode="concurrent", max_workers=MAX_WORKERS,
        )
        sys_.submit(dags["A"].copy())
        sys_.step()
        sys_.remove("A")  # no reuses → segments killed, topics dropped
        assert len(sys_.backend.segments) == 0
        assert len(sys_.backend.broker._topics) == 0
        assert sys_.backend.seg_deps == {}
        sys_.close()


# -- cross-mode determinism (the tentpole contract) ------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
class TestConcurrentDeterminism:
    def test_fig1_churn_sink_counts_identical(self, backend):
        dags = _fig1_by_name()
        sync_series, sync_counts, _ = _run_ops(backend, dags, FIG1_OPS, "sync")
        conc_series, conc_counts, _ = _run_ops(backend, dags, FIG1_OPS, "concurrent")
        assert conc_counts == sync_counts
        assert conc_series == sync_series  # live/paused/cost trajectories too

    def test_restore_lands_in_either_mode(self, backend, tmp_path):
        """Checkpoint taken in one mode restores into the other (and back),
        finishing with the same sink counts as the uninterrupted run."""
        dags = _fig1_by_name()
        _, base_counts, _ = _run_ops(backend, dags, FIG1_OPS, "sync")
        for ckpt_mode, restore_mode in (
            ("sync", "concurrent"),
            ("concurrent", "sync"),
        ):
            ckpt = str(tmp_path / f"ck-{backend}-{ckpt_mode}-{restore_mode}")
            kill_at = 4
            system = StreamSystem(
                strategy="signature", backend=backend, checkpoint_dir=ckpt,
                checkpoint_every=1, step_mode=ckpt_mode, max_workers=MAX_WORKERS,
            )
            for op, name in FIG1_OPS[: kill_at + 1]:
                _apply(system, dags, op, name)
                system.step()
                system.checkpoint()
            system.close()
            del system  # crash

            restored = StreamSystem.restore(ckpt, step_mode=restore_mode)
            assert restored.backend.step_mode == restore_mode
            for op, name in FIG1_OPS[kill_at + 1 :]:
                _apply(restored, dags, op, name)
                restored.step()
            restored.run(3)
            assert _sink_counts(restored) == base_counts
            restored.close()

    def test_restore_defaults_to_checkpointed_mode(self, backend, tmp_path):
        dags = _fig1_by_name()
        ckpt = str(tmp_path / "ck")
        system = StreamSystem(
            strategy="signature", backend=backend, checkpoint_dir=ckpt,
            step_mode="concurrent", max_workers=MAX_WORKERS,
        )
        system.submit(dags["A"].copy())
        system.step()
        system.checkpoint()
        system.close()
        restored = StreamSystem.restore(ckpt)
        assert restored.backend.step_mode == "concurrent"
        assert restored.backend.max_workers == MAX_WORKERS
        restored.close()


class TestOpmwTraceDeterminism:
    def test_rw1_full_trace_dryrun(self):
        """The acceptance contract on the full 35-DAG OPMW rw1 trace."""
        dags, ops = _opmw_dags(), _opmw_ops()
        sync_series, sync_counts, _ = _run_ops("dryrun", dags, ops, "sync")
        conc_series, conc_counts, _ = _run_ops("dryrun", dags, ops, "concurrent")
        assert conc_counts == sync_counts
        assert conc_series == sync_series

    def test_rw1_full_trace_dryrun_restore_boundary(self, tmp_path):
        dags, ops = _opmw_dags(), _opmw_ops()
        _, base_counts, _ = _run_ops("dryrun", dags, ops, "sync", tail_steps=0)
        kill_at = len(ops) // 2
        ckpt = str(tmp_path / "ck")
        system = StreamSystem(
            strategy="signature", backend="dryrun", checkpoint_dir=ckpt,
            checkpoint_every=1, step_mode="concurrent", max_workers=MAX_WORKERS,
        )
        for op, name in ops[: kill_at + 1]:
            _apply(system, dags, op, name)
            system.step()
            system.checkpoint()
        del system

        restored = StreamSystem.restore(ckpt, step_mode="sync")
        for op, name in ops[kill_at + 1 :]:
            _apply(restored, dags, op, name)
            restored.step()
        assert _sink_counts(restored) == base_counts

    @pytest.mark.slow
    @pytest.mark.parametrize("backend", JIT_BACKENDS)
    def test_rw1_slice_jit(self, backend):
        """The jit planes on an rw1 slice (full trace lives in the dryrun
        test above — jit compile cost per merge makes the full 100+-event
        trace a multi-minute run per mode)."""
        dags, ops = _opmw_dags(), _opmw_ops(truncate=20)
        _, sync_counts, _ = _run_ops(backend, dags, ops, "sync", tail_steps=1)
        _, conc_counts, _ = _run_ops(backend, dags, ops, "concurrent", tail_steps=1)
        assert conc_counts == sync_counts


@pytest.mark.parametrize("backend", JIT_BACKENDS)
class TestJitDigestIdentity:
    def test_checksums_bit_identical_across_modes(self, backend):
        """Beyond counts: jit sink checksums are bit-identical, because
        per-topic sequencing hands every consumer exactly its producer's
        batch of the same step."""
        dags = _fig1_by_name()
        out = {}
        for mode in ("sync", "concurrent"):
            sys_ = StreamSystem(
                strategy="signature", backend=backend,
                step_mode=mode, max_workers=MAX_WORKERS,
            )
            for name in ("A", "B", "C", "D"):
                sys_.submit(dags[name].copy())
            sys_.run(5)
            out[mode] = {
                name: sys_.sink_digests(name) for name in ("A", "B", "C", "D")
            }
            sys_.close()
        assert out["sync"] == out["concurrent"]


# -- EWMA-fed adaptive placement -------------------------------------------------


class TestEwmaAwarePlacement:
    def test_registered(self):
        assert resolve_placement("ewma_aware").name == "ewma_aware"

    def test_assign_prefers_least_pressured_device(self):
        p = EwmaAwarePlacement()
        # device 0 lightly loaded but slow; device 1 busier but fast
        idx = p.assign(None, 2, load={0: 1, 1: 5}, ewma={0: 80.0, 1: 2.0})
        assert idx == 1
        # without EWMA signal it degrades to least-loaded
        assert p.assign(None, 2, load={0: 3, 1: 1}) == 1

    def test_redispatch_migrates_off_slow_device(self):
        p = EwmaAwarePlacement()
        new = p.redispatch(None, current=0, n_devices=3,
                           load={0: 2, 1: 2, 2: 2},
                           ewma={0: 100.0, 1: 9.0, 2: 4.0})
        assert new == 2
        # single device: nowhere to go
        assert p.redispatch(None, current=0, n_devices=1, load={0: 2}) == 0

    def test_static_policies_stay_put(self):
        for name in ("round_robin", "least_loaded"):
            p = resolve_placement(name)
            assert p.redispatch(None, current=1, n_devices=4, load={}) == 1

    def test_injected_straggler_migrates(self):
        """Acceptance: a synthetically-slowed segment on the sharded
        backend moves to another device on redispatch."""
        import jax

        from repro.runtime.sharded import ShardedBackend

        cpu = jax.devices()[0]
        backend = ShardedBackend(
            placement="ewma_aware",
            devices=[cpu, cpu],  # two slots on one physical device
            straggler_factor=3.0,
            step_mode="concurrent",
            max_workers=MAX_WORKERS,
        )
        sys_ = StreamSystem(strategy="signature", backend=backend)
        for i in range(4):
            sys_.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
        victim = sorted(backend.device_of)[0]

        # Inject the straggler: the victim's simulated step-time dwarfs the
        # rest (base _step_one still runs, so data results stay correct).
        orig_step_one = type(backend)._step_one

        def slowed(seg):
            orig_step_one(backend, seg)
            return 200.0 if seg.name == victim else 2.0

        backend._step_one = slowed
        before = backend.device_of[victim]
        for _ in range(12):
            sys_.step()
            if backend.redispatches:
                break
        assert backend.redispatches, "straggler was never flagged"
        assert any(n == victim for _, n in backend.redispatches)
        assert backend.device_of[victim] != before  # migrated, not re-queued
        # the plane still steps correctly after the migration
        rep = sys_.step()
        assert rep.live_tasks == backend.live_task_count
        sys_.close()

    def test_ewma_feeds_assign_on_sharded(self):
        import jax

        from repro.runtime.sharded import ShardedBackend

        cpu = jax.devices()[0]
        backend = ShardedBackend(placement="ewma_aware", devices=[cpu, cpu])
        sys_ = StreamSystem(strategy="signature", backend=backend)
        sys_.submit(chain_df("S0", "urban", [("kalman", {"q": 0.0})]))
        (first_seg,) = backend.device_of
        first = backend.device_of[first_seg]
        # make the first segment's device look hot; the next submission
        # must land on the other one
        backend.ewma_ms[first_seg] = 500.0
        sys_.submit(chain_df("S1", "meter", [("kalman", {"q": 1.0})]))
        (second,) = (
            idx for name, idx in backend.device_of.items() if name != first_seg
        )
        assert second != first
        sys_.close()


# -- satellite: checkpoint GC ----------------------------------------------------


class TestCheckpointRetention:
    def _payload(self, i):
        return {"n": i}

    def test_keep_last_prunes_old_valid(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=2)
        for i in range(5):
            store.save(self._payload(i))
        ids = store.list_ids()
        assert len(ids) == 2
        assert store.latest_payload()["n"] == 4  # newest survives

    def test_newest_valid_never_pruned(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=1)
        for i in range(3):
            store.save(self._payload(i))
        assert len(store.list_ids()) == 1
        assert store.latest_payload()["n"] == 2

    def test_keep_last_validation(self, tmp_path):
        with pytest.raises(ValueError, match="keep_last"):
            CheckpointStore(str(tmp_path), keep_last=0)
        with pytest.raises(ValueError, match="keep_last"):
            CheckpointStore(str(tmp_path)).prune(keep_last=0)

    def test_torn_files_always_reaped(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=3)
        store.save(self._payload(0))  # id 1
        torn = store.path_of(2)
        with open(torn, "w") as f:
            f.write('{"half a check')  # simulated mid-write crash
        removed = store.prune()
        assert torn in removed
        assert not os.path.exists(torn)
        assert store.list_ids() == [1]  # valid one kept (within keep_last)

    def test_torn_reaped_even_without_policy(self, tmp_path):
        store = CheckpointStore(str(tmp_path))  # no keep_last
        store.save(self._payload(0))  # id 1
        with open(store.path_of(2), "w") as f:
            f.write("garbage")
        removed = store.prune()
        assert removed == [store.path_of(2)]
        assert store.list_ids() == [1]  # valid checkpoints untouched

    def test_unsupported_format_never_reaped(self, tmp_path):
        """Version skew: an intact checkpoint from a different software
        version is skipped by restore but must survive retention — another
        binary sharing the directory can still restore it."""
        store = CheckpointStore(str(tmp_path), keep_last=1)
        store.save(self._payload(0))  # id 1
        alien = store.path_of(2)
        with open(alien, "w") as f:
            json.dump(
                {"checkpoint_format": 999, "sha256": "x", "payload": {"n": 9}}, f
            )
        for i in range(3):
            store.save(self._payload(i))  # each save prunes
        assert os.path.exists(alien)  # never reaped
        # and it does not count toward keep_last: one valid + the alien
        assert len(store.list_ids()) == 2

    def test_prune_validates_each_file_once(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=5)
        for i in range(3):
            store.save(self._payload(i))
        loads = []
        orig_load = store.load
        store.load = lambda x: (loads.append(x), orig_load(x))[1]
        store.save(self._payload(3))  # triggers prune
        assert loads == []  # everything already validated by this instance

    def test_ids_stay_monotonic_after_prune(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep_last=1)
        for i in range(3):
            store.save(self._payload(i))  # ids 1..3; prune keeps 3
        store.save(self._payload(99))
        assert store.list_ids() == [4]  # pruned ids are never re-minted

    def test_session_plumbing(self, tmp_path):
        from repro.api import ReuseSession

        ckpt = str(tmp_path / "ck")
        s = ReuseSession(
            strategy="signature", execute=True, backend="dryrun",
            checkpoint_dir=ckpt, checkpoint_every=1, checkpoint_keep_last=2,
        )
        s.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        s.run(6)  # auto-checkpoints every step
        assert len(CheckpointStore(ckpt).list_ids()) == 2
        # retention survives checkpoint → restore
        restored = ReuseSession.restore(ckpt)
        assert restored._system.checkpoint_keep_last == 2
        assert restored._system.checkpoint_store.keep_last == 2
        restored.run(4)
        assert len(CheckpointStore(ckpt).list_ids()) == 2

    def test_keep_last_needs_checkpoint_dir(self):
        with pytest.raises(ValueError, match="checkpoint_keep_last"):
            StreamSystem(backend="dryrun", checkpoint_keep_last=2)


# -- satellite: dry-run latency calibration --------------------------------------


class TestLatencyCalibration:
    def test_fit_recovers_coefficients(self):
        rng = np.random.default_rng(0)
        truth = {"kalman": 2.0, "parse": 0.5}
        samples = []
        for _ in range(12):
            units = {t: float(rng.uniform(1, 10)) for t in truth}
            ms = sum(truth[t] * u for t, u in units.items())
            samples.append((units, ms))
        model = fit_latency_model(samples)
        for t, c in truth.items():
            assert model.ms_per_unit[t] == pytest.approx(c, rel=1e-6)
        assert model.segment_ms({"kalman": 3.0}) == pytest.approx(6.0, rel=1e-6)

    def test_unseen_type_uses_mean_fallback(self):
        model = fit_latency_model([({"a": 2.0}, 4.0)])
        assert model.default_ms_per_unit == pytest.approx(2.0)
        assert model.segment_ms({"never-seen": 1.0}) == pytest.approx(2.0)

    def test_empty_samples(self):
        model = fit_latency_model([])
        assert model.segment_ms({"x": 5.0}) == 0.0

    def test_negative_coefficients_clipped(self):
        # contradictory observations force a negative LS solution for one type
        samples = [({"a": 1.0, "b": 1.0}, 1.0), ({"a": 1.0}, 2.0)]
        model = fit_latency_model(samples)
        assert all(c >= 0.0 for c in model.ms_per_unit.values())

    def test_calibrated_dryrun_reports_realistic_segment_ms(self):
        sys_ = StreamSystem(strategy="signature", backend="dryrun")
        sys_.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        sys_.backend.calibrate(LatencyModel({"kalman": 1.0}, default_ms_per_unit=0.5))
        rep = sys_.step()
        (seg,) = sys_.backend.segments.values()
        expected = sum(
            (1.0 if sys_.backend.task_defs[t].type == "kalman" else 0.5)
            * seg.cost_of[t] * seg.spec.batch_of[t]
            for t in seg.spec.task_ids
        )
        assert rep.segment_ms[seg.name] == pytest.approx(expected)
        assert rep.makespan_ms == pytest.approx(expected)

    def test_jit_samples_calibrate_dryrun(self):
        """End-to-end feed: record jit StepReports → fit → dry-run reports
        non-trivial segment_ms."""
        jit = StreamSystem(strategy="signature", backend="inprocess")
        jit.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        jit.run(4)
        samples = jit.backend.latency_samples()
        assert samples
        model = fit_latency_model(samples)
        dry = StreamSystem(strategy="signature", backend="dryrun")
        dry.backend.calibrate(model)
        dry.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        rep = dry.step()
        assert rep.makespan_ms > 0.0

    def test_makespan_wave_max_vs_wave_sum(self):
        """Dryrun concurrent makespan is Σ over waves of the wave max;
        sync is the plain sum — the acceptance's wave-max-not-wave-sum."""
        dags = _fig1_by_name()
        per_mode = {}
        for mode in ("sync", "concurrent"):
            sys_ = StreamSystem(
                strategy="signature", backend="dryrun", step_mode=mode,
            )
            sys_.backend.calibrate(LatencyModel({}, default_ms_per_unit=1.0))
            # A→B→C merge incrementally (a chain of waves); D is independent
            # and shares wave 0, so at least one wave has 2 segments and
            # wave-max < wave-sum there.
            for name in ("A", "B", "C", "D"):
                sys_.submit(dags[name].copy())
            rep = sys_.step()
            waves = sys_.backend.segment_waves()
            assert len(waves) > 1
            assert any(len(w) > 1 for w in waves)
            agg = max if mode == "concurrent" else sum
            expected = sum(agg(rep.segment_ms[n] for n in w) for w in waves)
            assert rep.makespan_ms == pytest.approx(expected)
            per_mode[mode] = rep.makespan_ms
        assert per_mode["concurrent"] < per_mode["sync"]


# -- satellite: StepReport ring buffer -------------------------------------------


class TestReportHistory:
    def test_ring_buffer_bounds_memory(self):
        sys_ = StreamSystem(
            strategy="signature", backend="dryrun", report_history=5,
        )
        sys_.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        sys_.run(12)
        assert [r.step for r in sys_.backend.reports] == list(range(8, 13))

    def test_unbounded_by_default_and_not_persisted(self):
        sys_ = StreamSystem(strategy="signature", backend="dryrun")
        sys_.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        sys_.run(3)
        assert len(sys_.backend.reports) == 3
        dump = sys_.backend.dump_state()
        assert "reports" not in dump

    def test_history_survives_checkpoint_restore(self, tmp_path):
        ckpt = str(tmp_path / "ck")
        sys_ = StreamSystem(
            strategy="signature", backend="dryrun",
            checkpoint_dir=ckpt, report_history=4,
        )
        sys_.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        sys_.run(9)
        want = [(r.step, r.live_tasks, r.cost) for r in sys_.backend.reports]
        sys_.checkpoint()
        restored = StreamSystem.restore(ckpt)
        assert restored.backend.history_limit == 4
        got = [(r.step, r.live_tasks, r.cost) for r in restored.backend.reports]
        assert got == want
        # the restored buffer keeps rolling
        restored.step()
        assert len(restored.backend.reports) == 4
        assert restored.backend.reports[-1].step == 10

    def test_report_history_validation(self):
        with pytest.raises(ValueError, match="report_history"):
            StreamSystem(backend="dryrun", report_history=0)


# -- wave observers + knob plumbing ----------------------------------------------


class TestWaveObserversAndKnobs:
    def test_on_wave_covers_every_segment_once(self):
        from repro.api import ReuseSession

        events = []
        s = ReuseSession(
            strategy="signature", execute=True, backend="dryrun",
            step_mode="concurrent", on_wave=events.append,
        )
        for name, df in _fig1_by_name().items():
            if name in ("A", "B"):
                s.submit(df)
        rep = s.step()
        assert all(isinstance(e, WaveEvent) for e in events)
        assert [e.index for e in events] == list(range(len(events)))
        stepped = [n for e in events for n in e.segments]
        assert sorted(stepped) == sorted(s._system.backend.segments)
        assert sum(e.wave_ms for e in events) == pytest.approx(rep.makespan_ms)
        s.close()

    def test_step_event_exposes_makespan(self):
        from repro.api import ReuseSession

        seen = []
        s = ReuseSession(
            strategy="signature", execute=True, backend="dryrun",
            on_step=seen.append,
        )
        s.submit(chain_df("A", "urban", [("kalman", {"q": 0.1})]))
        rep = s.step()
        assert seen[0].makespan_ms == rep.makespan_ms

    def test_invalid_step_mode_rejected(self):
        from repro.runtime.dryrun import DryRunBackend

        with pytest.raises(ValueError, match="step_mode"):
            DryRunBackend(step_mode="warp")
        with pytest.raises(ValueError, match="step_mode"):
            DryRunBackend().configure_stepping(step_mode="warp")

    def test_control_plane_session_rejects_stepping_knobs(self):
        from repro.api import DataflowError, ReuseSession

        with pytest.raises(DataflowError, match="step_mode"):
            ReuseSession(step_mode="concurrent")
        with pytest.raises(DataflowError, match="report_history"):
            ReuseSession(report_history=8)

    def test_wrapping_a_system_applies_stepping_knobs(self):
        from repro.api import DataflowError, ReuseSession

        system = StreamSystem(strategy="signature", backend="dryrun")
        s = ReuseSession(
            system=system, step_mode="concurrent", max_workers=3,
            report_history=7,
        )
        assert system.backend.step_mode == "concurrent"
        assert system.backend.max_workers == 3
        assert system.backend.history_limit == 7
        s.close()
        # checkpoint wiring belongs to the system — rebinding must fail loudly
        with pytest.raises(DataflowError, match="checkpoint_dir"):
            ReuseSession(system=system, checkpoint_dir="/tmp/nope")

    def test_mode_switch_mid_run_preserves_results(self):
        dags = _fig1_by_name()
        _, base_counts, _ = _run_ops("dryrun", dags, FIG1_OPS, "sync")
        sys_ = StreamSystem(strategy="signature", backend="dryrun", step_mode="sync")
        for i, (op, name) in enumerate(FIG1_OPS):
            _apply(sys_, dags, op, name)
            sys_.step()
            sys_.backend.configure_stepping(
                step_mode="concurrent" if i % 2 == 0 else "sync"
            )
        for _ in range(3):
            sys_.step()
        assert _sink_counts(sys_) == base_counts


# -- satellites: EWMA idle-device decay + sticky restore placement ---------------


class _FakePlacedBackend:
    """Built lazily in tests: PlacedBackendMixin over the dry-run backend
    with synthetic per-segment step times — deterministic EWMA dynamics,
    no jit compiles, no worker processes."""

    @staticmethod
    def make(n_slots=2, ewma_decay=0.6, seg_ms=None, placement="ewma_aware"):
        from repro.runtime.dryrun import DryRunBackend
        from repro.runtime.scheduler import PlacedBackendMixin

        class Fake(PlacedBackendMixin, DryRunBackend):
            concurrent_dispatch = False

            def __init__(self):
                super().__init__()
                self._n = n_slots
                self._init_placement(placement, ewma_decay=ewma_decay)
                self.moves = []
                self.seg_ms_of = dict(seg_ms or {})

            def _n_slots(self):
                return self._n

            def _move_segment(self, seg, old, new):
                self.moves.append((seg.name, old, new))

            def _build(self, spec, dataflow, init_states):
                seg = super()._build(spec, dataflow, init_states)
                self._assign_slot(spec)
                return seg

            def _step_one(self, seg):
                super()._step_one(seg)
                # synthetic speeds keyed by launch order (created_at) — the
                # minted segment/DAG names vary with the control plane
                return self.seg_ms_of.get(seg.spec.created_at, 2.0)

        return Fake()


def _deploy_chains(backend, n=4):
    system = StreamSystem(strategy="none", backend=backend)
    for i in range(n):
        system.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
    return system


class TestEwmaIdleDecay:
    def test_residual_heat_decays_toward_zero_on_idle_device(self):
        """ROADMAP satellite: a device that received no steps (its straggler
        migrated away) cools by ewma_decay per step instead of reading
        stale-hot (or instantly cold) forever."""
        be = _FakePlacedBackend.make(ewma_decay=0.5, seg_ms={0: 200.0})
        sys_ = _deploy_chains(be, n=4)  # launch 0/2 -> slot 0, 1/3 -> slot 1
        sys_.step()  # victim flagged (3 fast peers keep the median low), migrated
        assert be.moves and be.moves[0][1] == 0
        first = be.device_ewma().get(0, 0.0)
        assert first > 0.0  # residual heat left behind
        be.seg_ms_of[0] = 2.0  # device-caused straggler: cured by migration
        decayed = []
        for _ in range(6):
            sys_.step()
            decayed.append(be.device_ewma().get(0, 0.0))
        assert all(b <= a for a, b in zip(decayed, decayed[1:]))
        assert decayed[-1] < 0.1 * first  # → 0, not stale-hot
        sys_.close()

    def test_ewma_decay_validation(self):
        with pytest.raises(ValueError, match="ewma_decay"):
            _FakePlacedBackend.make(ewma_decay=1.0)

    def test_pingpong_migrations_damped(self):
        """The regression the satellite names: a segment-caused straggler on
        2 devices. Without decay the residual vanishes instantly, the old
        device always reads cold, and every flag bounces the segment back;
        with decay the source stays warm and the segment holds position."""
        runs = {}
        for decay in (0.0, 0.9):
            be = _FakePlacedBackend.make(ewma_decay=decay, seg_ms={0: 200.0})
            sys_ = _deploy_chains(be, n=4)
            for _ in range(6):
                sys_.step()
            runs[decay] = list(be.moves)
            sys_.close()
        legacy, damped = runs[0.0], runs[0.9]
        assert len(legacy) >= 3  # ping-pong: migrates on (almost) every flag
        assert len(damped) == 1  # one migration, then holds
        # and specifically no immediate bounce-back right after migrating
        assert not any(
            a[0] == b[0] and a[2] == b[1] and b[2] == a[1]
            for a, b in zip(damped, damped[1:])
        )

    def test_redispatch_improvement_threshold_policy_level(self):
        p = EwmaAwarePlacement()
        # destination retains decayed residual heat -> not substantially
        # cooler -> stay put (the anti-ping-pong half)
        assert p.redispatch(None, current=1, n_devices=2,
                            load={0: 5, 1: 5},
                            ewma={0: 120.0, 1: 202.0}) == 1
        # residual has decayed -> migration pays again
        assert p.redispatch(None, current=1, n_devices=2,
                            load={0: 5, 1: 5},
                            ewma={0: 10.0, 1: 202.0}) == 0
        with pytest.raises(ValueError, match="improvement"):
            EwmaAwarePlacement(improvement=0.0)


class TestStickyPlacement:
    def _spec(self, name):
        from repro.runtime.backend import SegmentSpec

        return SegmentSpec(name=name, dag_name="d", task_ids=[f"{name}.t"],
                           parents={f"{name}.t": []}, publish=set(),
                           batch_of={f"{name}.t": 32})

    def test_registered(self):
        assert resolve_placement("sticky").name == "sticky"

    def test_pins_when_pool_matches(self):
        p = resolve_placement("sticky")
        hints = {"checkpoint_device_of": {"segA": 3}, "checkpoint_n_devices": 4}
        assert p.assign(self._spec("segA"), 4, load={}, hints=hints) == 3

    def test_falls_back_without_hint_or_on_pool_mismatch(self):
        p = resolve_placement("sticky")
        # no hint for this segment -> ewma_aware fallback (least pressure)
        hints = {"checkpoint_device_of": {"other": 1}, "checkpoint_n_devices": 2}
        assert p.assign(self._spec("segB"), 2, load={0: 4},
                        ewma={0: 9.0}, hints=hints) == 1
        # pool size changed -> indices no longer name the same hardware
        hints = {"checkpoint_device_of": {"segB": 1}, "checkpoint_n_devices": 4}
        assert p.assign(self._spec("segB"), 2, load={0: 4},
                        ewma={0: 9.0}, hints=hints) == 1  # via fallback
        hints = {"checkpoint_device_of": {"segB": 5}, "checkpoint_n_devices": 2}
        assert p.assign(self._spec("segB"), 2, load={}, hints=hints) in (0, 1)

    def test_redispatch_delegates_to_fallback(self):
        p = resolve_placement("sticky")
        assert p.redispatch(None, current=0, n_devices=3,
                            load={}, ewma={0: 100.0, 1: 9.0, 2: 4.0}) == 2

    def test_sharded_restore_repins_devices(self):
        """Integration: a sharded checkpoint restored with placement="sticky"
        lands every segment back on its checkpointed device, even where the
        ewma_aware fallback would have chosen differently."""
        import jax

        from repro.runtime.sharded import ShardedBackend

        cpu = jax.devices()[0]
        be = ShardedBackend(devices=[cpu, cpu])
        sys_ = StreamSystem(strategy="none", backend=be)
        for i in range(3):
            sys_.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
        sys_.run(2)
        # force a map the fallback would never produce for in-order deploys
        pinned = {name: 1 - idx for name, idx in be.device_of.items()}
        be.device_of = pinned
        payload = sys_.checkpoint_payload()
        sys_.close()

        be2 = ShardedBackend(devices=[cpu, cpu], placement="sticky")
        restored = StreamSystem.from_payload(payload, backend=be2)
        assert be2.device_of == pinned
        restored.run(1)
        restored.close()

    def test_legacy_policy_without_hints_kwarg_still_works(self):
        """Custom pre-hints policies (no ``hints`` parameter) must keep
        working: backends only pass hints to signatures that declare it."""
        import jax

        from repro.runtime.scheduler import PlacementPolicy
        from repro.runtime.sharded import ShardedBackend

        class Legacy(PlacementPolicy):
            name = ""

            def assign(self, spec, n_devices, load, ewma=None):  # old-style
                return n_devices - 1

        cpu = jax.devices()[0]
        be = ShardedBackend(devices=[cpu, cpu], placement=Legacy())
        sys_ = StreamSystem(strategy="none", backend=be)
        sys_.submit(chain_df("L0", "urban", [("kalman", {"q": 0.5})]))
        sys_.step()
        assert set(be.device_of.values()) == {1}
        sys_.close()
