"""Serving: engine generation determinism + multi-tenant reuse-serving
output consistency, merge/unmerge behavior, and cost accounting."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params
from repro.serve import ReuseServing, TenantPipeline
from repro.serve.engine import Request, ServeEngine


def test_engine_greedy_deterministic():
    cfg = configs.get_smoke_config("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(5, dtype=np.int32) + i for i in range(5)]

    def run():
        eng = ServeEngine(cfg, params, slots=2, max_len=64)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid, p, max_new=4))
        return {r.rid: r.tokens for r in eng.run()}

    a, b = run(), run()
    assert a == b
    assert len(a) == 5
    for toks in a.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.padded_vocab for t in toks)


def test_engine_batching_independence():
    """Slot packing must not change a request's output (cache isolation)."""
    cfg = configs.get_smoke_config("granite_20b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.array([3, 1, 4, 1, 5], np.int32)

    def gen(slots, extra):
        eng = ServeEngine(cfg, params, slots=slots, max_len=64)
        eng.submit(Request(0, prompt, max_new=4))
        for rid in range(1, extra + 1):
            eng.submit(Request(rid, prompt[::-1].copy(), max_new=4))
        return {r.rid: r.tokens for r in eng.run()}[0]

    assert gen(1, 0) == gen(4, 3)


@pytest.mark.slow
def test_reuse_serving_matches_default():
    def build(strategy):
        rs = ReuseServing(strategy=strategy, base_batch=4)
        for i in range(5):
            rs.add_tenant(
                TenantPipeline(tenant=f"t{i}", shared_stages=2, n_stages=3, d=32,
                               layers_per_stage=2)
            )
        rs.run(4)
        return rs

    d, r = build("none"), build("signature")
    assert r.running_task_count < d.running_task_count
    for i in range(5):
        assert d.tenant_output(f"t{i}") == r.tenant_output(f"t{i}")


def test_reuse_serving_tenant_isolation_on_remove():
    rs = ReuseServing(strategy="signature", base_batch=4)
    for i in range(4):
        rs.add_tenant(TenantPipeline(tenant=f"t{i}", shared_stages=2, n_stages=3,
                                     d=32, layers_per_stage=2))
    rs.run(2)
    before = {t: rs.tenant_output(t)[f"{t}/sink"]["count"] for t in ("t0", "t2")}
    rs.remove_tenant("t1")
    rs.run(2)
    for t in ("t0", "t2"):
        after = rs.tenant_output(t)[f"{t}/sink"]["count"]
        assert after == before[t] + 2  # kept streaming through the removal


def test_finetuned_stages_not_falsely_merged():
    rs = ReuseServing(strategy="signature", base_batch=4)
    rs.add_tenant(TenantPipeline(tenant="a", shared_stages=3, n_stages=3, d=32,
                                 layers_per_stage=2))
    base = rs.running_task_count
    # tenant with its own fine-tuned top stage: configs differ ⇒ stage2 not shared
    rs.add_tenant(TenantPipeline(tenant="b", shared_stages=2, n_stages=3, d=32,
                                 layers_per_stage=2))
    added = rs.running_task_count - base
    # b reuses src+embed+stage0+stage1, adds its own stage2+head+sink
    assert added == 3, added
