"""ExecutionBackend API tests.

Four layers:
  * a shared conformance suite every backend (inprocess jit / sharded /
    dryrun) must pass — deploy/kill/forward/pause/resume/step/snapshot/
    sink_state/account semantics through StreamSystem;
  * the dry-run ≡ jit contract: identical live/paused/cost trajectories
    for the same OPMW trace (the cost model is the contract; checksums
    are jit-only);
  * reproduction of the stored Fig. 2 running-task series
    (results/benchmarks/fig2_3_4_opmw_rw1.json) on the dry-run backend,
    plus the ≥10× wall-clock advantage over the jit backend;
  * state-preserving defrag edge cases and the churn-leak regression
    (no stale task_batch/ewma_ms/paused entries after submit/remove/
    defrag churn).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import pytest

from repro.api import ReuseSession, available_backends
from repro.runtime.backend import resolve_backend
from repro.runtime.system import StreamSystem

from helpers import chain_df, fig1

BACKENDS = ["inprocess", "sharded", "dryrun"]
JIT_BACKENDS = ["inprocess", "sharded"]

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "benchmarks")


def _system(backend, strategy="signature", **kw):
    # The CI concurrency-stress job re-runs this whole conformance suite
    # through the concurrent stepping pipeline at several pool widths;
    # results must be mode- and width-invariant.
    kw.setdefault("step_mode", os.environ.get("REPRO_TEST_STEP_MODE"))
    if "REPRO_TEST_MAX_WORKERS" in os.environ:
        kw.setdefault("max_workers", int(os.environ["REPRO_TEST_MAX_WORKERS"]))
    return StreamSystem(strategy=strategy, backend=backend, **kw)


def _opmw_subset(n=6):
    from repro.workloads import opmw_workload

    return opmw_workload()[:n]


# -- registry ------------------------------------------------------------------


class TestRegistry:
    def test_builtins_available(self):
        assert {"inprocess", "sharded", "dryrun"} <= set(available_backends())

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown backend"):
            resolve_backend("no-such-backend")
        with pytest.raises(TypeError):
            resolve_backend(42)

    def test_instance_passthrough_and_custom_class(self):
        from repro.runtime.backend import ExecutionBackend, register_backend
        from repro.runtime.dryrun import DryRunBackend

        inst = DryRunBackend()
        assert resolve_backend(inst) is inst

        class MyBackend(DryRunBackend):
            name = "test-custom"

        register_backend(MyBackend)
        try:
            assert "test-custom" in available_backends()
            sys_ = StreamSystem(backend="test-custom")
            assert isinstance(sys_.backend, MyBackend)
            assert isinstance(sys_.backend, ExecutionBackend)
        finally:
            from repro.runtime import backend as backend_mod

            backend_mod._BACKENDS.pop("test-custom", None)

    def test_session_backend_name(self):
        s = ReuseSession(execute=True, backend="dryrun")
        assert s.backend_name == "dryrun"
        assert s.stats().backend == "dryrun"
        assert ReuseSession().backend_name is None


# -- shared conformance suite ---------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
class TestBackendConformance:
    def test_deploy_step_account(self, backend):
        A, B, C, D = fig1()
        sys_ = _system(backend)
        for df in (A, B, C, D):
            sys_.submit(df.copy())
        assert sys_.deployed_task_count == 12
        rep = sys_.step()
        assert rep.live_tasks == 12
        assert rep.paused_tasks == 0
        assert rep.cost > 0
        live, paused, cost = sys_.backend.account()
        assert (live, paused) == (12, 0)
        assert cost == pytest.approx(rep.cost)

    def test_remove_pauses_and_resume(self, backend):
        A, B, C, D = fig1()
        sys_ = _system(backend)
        for df in (A, B, C, D):
            sys_.submit(df.copy())
        before = sys_.step()
        receipt = sys_.remove("D")  # D runs alone: all 4 of its tasks pause
        after = sys_.step()
        assert after.live_tasks == before.live_tasks - 4
        assert after.paused_tasks == 4
        assert after.cost < before.cost
        assert sys_.deployed_task_count == 12  # Storm can't kill a subset
        # ε residue: paused tasks still cost something (drain-phase overhead)
        only_live_cost = sum(
            seg.cost_of[t] * seg.spec.batch_of[t]
            for seg in sys_.backend.segments.values()
            for t in seg.spec.task_ids
            if bool(seg.active[t])
        ) / 320.0
        assert after.cost > only_live_cost
        # resume is the inverse control signal
        sys_.backend.resume(set(receipt.terminated_tasks))
        rep = sys_.step()
        assert rep.live_tasks == before.live_tasks
        assert rep.paused_tasks == 0

    def test_default_kills_topologies(self, backend):
        A, B, *_ = fig1()
        sys_ = _system(backend, strategy="none")
        sys_.submit(A.copy())
        sys_.submit(B.copy())
        assert sys_.deployed_task_count == 9
        sys_.remove("A")
        rep = sys_.step()
        assert sys_.deployed_task_count == 5
        assert rep.paused_tasks == 0  # kill, not pause

    def test_defrag_drops_paused_and_preserves_counts(self, backend):
        A, B, C, D = fig1()
        sys_ = _system(backend)
        for df in (A, B, C, D):
            sys_.submit(df.copy())
        sys_.run(3)
        sys_.remove("B")
        counts_before = {
            name: {s: d["count"] for s, d in sys_.sink_digests(name).items()}
            for name in "ACD"
        }
        sys_.defragment()
        rep = sys_.step()
        assert rep.paused_tasks == 0
        assert sys_.deployed_task_count == 11  # paused task dropped
        for name in "ACD":
            for sink, d in sys_.sink_digests(name).items():
                assert d["count"] == counts_before[name][sink] + 1

    def test_forward_unknown_task_raises(self, backend):
        sys_ = _system(backend)
        with pytest.raises(KeyError):
            sys_.backend.forward("never-deployed")
        with pytest.raises(KeyError):
            sys_.backend.sink_state("never-deployed")

    def test_owner_index_consistent_across_lifecycle(self, backend):
        A, B, C, D = fig1()
        sys_ = _system(backend)
        for df in (A, B, C, D):
            sys_.submit(df.copy())
        sys_.remove("B")
        sys_.defragment()

        backend_obj = sys_.backend
        expected = {
            tid: name
            for name, seg in backend_obj.segments.items()
            for tid in seg.spec.task_ids
        }
        assert backend_obj._owner_of == expected
        for tid, owner in expected.items():
            assert backend_obj._owner(tid) == owner

    def test_snapshot(self, backend):
        A, *_ = fig1()
        sys_ = _system(backend)
        sys_.submit(A.copy())
        sys_.step()
        snap = sys_.backend.snapshot()
        assert snap.backend == backend
        assert snap.step_count == 1
        assert snap.live_tasks == 4
        assert sorted(t for ts in snap.segments.values() for t in ts) == sorted(
            t for seg in sys_.backend.segments.values() for t in seg.spec.task_ids
        )

    def test_session_on_step_hook(self, backend):
        A, *_ = fig1()
        session = ReuseSession(execute=True, backend=backend)
        seen = []
        session.on_step(lambda ev: seen.append((ev.step, ev.live_tasks)))
        session.submit(A.copy())
        session.run(2)
        session.step()
        assert seen == [(1, 4), (2, 4), (3, 4)]


# -- sharded specifics ----------------------------------------------------------


class TestShardedPlacement:
    def test_round_robin_and_least_loaded(self):
        from repro.runtime.backend import SegmentSpec
        from repro.runtime.scheduler import resolve_placement

        def spec(name, n):
            ids = [f"{name}.t{i}" for i in range(n)]
            return SegmentSpec(
                name=name, dag_name="d", task_ids=ids,
                parents={t: [] for t in ids}, publish=set(),
                batch_of={t: 1 for t in ids},
            )

        rr = resolve_placement("round_robin")
        assert [rr.assign(spec(f"s{i}", 1), 3, {}) for i in range(5)] == [0, 1, 2, 0, 1]
        ll = resolve_placement("least_loaded")
        assert ll.assign(spec("a", 2), 2, {0: 10, 1: 3}) == 1
        assert ll.assign(spec("b", 2), 2, {}) == 0

    def test_sharded_tracks_device_assignments(self):
        A, B, *_ = fig1()
        sys_ = _system("sharded")
        sys_.submit(A.copy())
        sys_.submit(B.copy())
        backend = sys_.backend
        assert set(backend.device_of) == set(backend.segments)
        assert all(0 <= i < len(backend.devices) for i in backend.device_of.values())
        load = backend.device_load()
        assert sum(load.values()) == sys_.deployed_task_count
        snap = backend.snapshot()
        assert snap.device_of == backend.device_of

    def test_sharded_outputs_match_inprocess(self):
        A, B, C, D = fig1()
        plain = _system("inprocess")
        shard = _system("sharded")
        for df in (A, B, C, D):
            plain.submit(df.copy())
            shard.submit(df.copy())
        plain.run(5)
        shard.run(5)
        for name in "ABCD":
            assert plain.sink_digests(name) == shard.sink_digests(name)


# -- the dry-run ≡ jit contract -------------------------------------------------


class TestDryRunContract:
    def test_trajectories_match_inprocess_on_opmw_trace(self):
        """live/paused/cost identical event-by-event on an OPMW trace with
        removals (pause accounting) and a defrag (drop accounting)."""
        from repro.workloads import seq_trace

        dags = _opmw_subset(6)
        events = seq_trace(dags, seed=5)
        jit = _system("inprocess")
        dry = _system("dryrun")
        for i, ev in enumerate(events):
            for s in (jit, dry):
                if ev.op == "add":
                    s.submit(next(d for d in dags if d.name == ev.name).copy())
                else:
                    s.remove(ev.name)
            jr, dr = jit.step(), dry.step()
            assert (jr.live_tasks, jr.paused_tasks) == (dr.live_tasks, dr.paused_tasks)
            assert jr.cost == pytest.approx(dr.cost, rel=1e-9)
            if i == len(dags) + 2:  # mid-drain: exercise defrag on both
                jit.defragment()
                dry.defragment()

    def test_sink_counts_match_inprocess(self):
        A, B, *_ = fig1()
        jit = _system("inprocess")
        dry = _system("dryrun")
        for s in (jit, dry):
            s.submit(A.copy())
            s.run(3)
            s.submit(B.copy())
            s.run(4)
        for name in "AB":
            j = jit.sink_digests(name)
            d = dry.sink_digests(name)
            assert set(j) == set(d)
            for sink in j:
                assert j[sink]["count"] == d[sink]["count"]
                assert d[sink]["checksum"] == 0.0  # checksums are jit-only

    def test_dryrun_reproduces_fig2_running_tasks(self):
        """The acceptance contract: DryRunBackend on the OPMW rw1 trace
        reproduces the stored Fig. 2 running-task series exactly."""
        from repro.workloads import opmw_workload, replay, rw_trace

        with open(os.path.join(RESULTS, "fig2_3_4_opmw_rw1.json")) as f:
            stored = json.load(f)["series"]

        dags = opmw_workload()
        events = rw_trace(dags, seed=11)
        session = ReuseSession(strategy="signature", execute=True, backend="dryrun")
        live = []
        for _ in replay(session, dags, events):
            live.append(session._system.backend.account()[0])
        assert live == stored["reuse_tasks"]

    @pytest.mark.slow
    def test_dryrun_at_least_10x_faster_than_jit(self):
        """Same trace prefix on both backends; dry-run must win ≥10×
        (in practice it wins by orders of magnitude — no jit compiles)."""
        from repro.workloads import rw_trace

        dags = _opmw_subset(8)
        events = rw_trace(dags, seed=11)[:10]

        def run(backend):
            sys_ = _system(backend)
            t0 = time.perf_counter()
            for ev in events:
                if ev.op == "add":
                    sys_.submit(next(d for d in dags if d.name == ev.name).copy())
                else:
                    sys_.remove(ev.name)
                sys_.step()
            return time.perf_counter() - t0

        dry_s = run("dryrun")
        jit_s = run("inprocess")
        assert jit_s >= 10 * dry_s, f"dryrun {dry_s:.3f}s vs jit {jit_s:.3f}s"

    def test_dryrun_data_plane_never_imports_jax(self):
        """backend="dryrun" is a JAX-free path end to end (lazy registries)."""
        code = (
            "import sys\n"
            "from repro.api import ReuseSession, flow\n"
            "s = ReuseSession(strategy='signature', execute=True, backend='dryrun')\n"
            "a = flow('A').source('urban').then('senml_parse').then('kalman', q=0.1)"
            ".sink('store').build()\n"
            "b = flow('B').source('urban').then('senml_parse').then('kalman', q=0.1)"
            ".then('avg').sink('store').build()\n"
            "s.submit(a); s.submit(b); s.run(3)\n"
            "s.remove('A'); s.step(); s.defragment(); s.step()\n"
            "assert s.sink_digests('B')\n"
            "assert 'jax' not in sys.modules, 'dryrun path imported jax'\n"
        )
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) + env.get(
            "PYTHONPATH", ""
        )
        proc = subprocess.run(
            [sys.executable, "-c", code], env=env, capture_output=True, text=True
        )
        assert proc.returncode == 0, proc.stderr

    def test_dryrun_checkpoint_restore_never_imports_jax(self, tmp_path):
        """Durable checkpoint + full-system restore on backend="dryrun"
        stays a JAX-free path end to end (checkpoint codec is numpy-only)."""
        ckpt_dir = str(tmp_path / "ckpts")
        code = (
            "import sys\n"
            "from repro.api import ReuseSession, flow\n"
            f"s = ReuseSession(strategy='signature', execute=True, backend='dryrun',\n"
            f"                 checkpoint_dir={ckpt_dir!r}, checkpoint_every=1)\n"
            "a = flow('A').source('urban').then('senml_parse').then('kalman', q=0.1)"
            ".sink('store').build()\n"
            "b = flow('B').source('urban').then('senml_parse').then('kalman', q=0.1)"
            ".then('avg').sink('store').build()\n"
            "s.submit(a); s.submit(b); s.run(3)\n"
            "before = s.sink_digests('B')\n"
            "del s  # crash\n"
            f"r = ReuseSession.restore({ckpt_dir!r})\n"
            "assert r.sink_digests('B') == before\n"
            "r.remove('A'); r.step(); r.defragment(); r.run(2)\n"
            "assert all(d['count'] == 6 for d in r.sink_digests('B').values())\n"
            "assert r.stats().steps_run == 6\n"
            "assert 'jax' not in sys.modules, 'dryrun checkpoint/restore imported jax'\n"
        )
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) + env.get(
            "PYTHONPATH", ""
        )
        proc = subprocess.run(
            [sys.executable, "-c", code], env=env, capture_output=True, text=True
        )
        assert proc.returncode == 0, proc.stderr


# -- defrag edge cases and the churn leak ---------------------------------------


class TestDefragEdgeCases:
    def test_paused_tasks_dropped_during_defrag(self):
        A, B, C, D = fig1()
        sys_ = _system("inprocess")
        for df in (A, B, C, D):
            sys_.submit(df.copy())
        r = sys_.remove("B")
        paused_ids = set(r.terminated_tasks)
        assert paused_ids <= sys_.backend.paused
        sys_.defragment()
        deployed = {
            t for seg in sys_.backend.segments.values() for t in seg.spec.task_ids
        }
        assert not (paused_ids & deployed)
        assert not sys_.backend.paused

    def test_sink_digests_identical_across_defrag(self):
        """Same submissions/removals/steps, with and without a defrag in the
        middle — the jit digests (count AND checksum) must be identical."""
        A, B, C, D = fig1()

        def run(defrag):
            sys_ = _system("inprocess")
            for df in (A, B, C, D):
                sys_.submit(df.copy())
            sys_.run(4)
            sys_.remove("B")
            if defrag:
                sys_.defragment()
            sys_.run(4)
            return {name: sys_.sink_digests(name) for name in "ACD"}

        assert run(defrag=False) == run(defrag=True)

    @pytest.mark.parametrize("backend", ["dryrun", "inprocess"])
    def test_churn_leaves_no_stale_entries(self, backend):
        """submit/remove/defrag churn ×20: task_batch, ewma_ms, paused and
        the owner index must stay bounded by what is actually deployed."""
        n_rounds = 20 if backend == "dryrun" else 4
        sys_ = _system(backend)
        keep = chain_df("keep", "urban", [("parse", {}), ("kalman", {"q": 0.1})])
        sys_.submit(keep)
        for i in range(n_rounds):
            name = f"churn{i}"
            df = chain_df(
                name,
                "urban",
                [("parse", {}), ("kalman", {"q": 0.1}), (f"uniq{i}", {"round": i})],
            )
            sys_.submit(df)
            sys_.run(2)
            sys_.remove(name)
            if i % 2 == 1:
                sys_.defragment()
            sys_.run(1)

        backend_obj = sys_.backend
        deployed = {
            t for seg in backend_obj.segments.values() for t in seg.spec.task_ids
        }
        running = {
            t for df in sys_.manager.running.values() for t in df.tasks
        }
        # task_batch: exactly the running (live) tasks — no terminated ids
        assert set(sys_.task_batch) == running
        # paused ⊆ deployed, and after a final defrag nothing is paused
        assert backend_obj.paused <= deployed
        sys_.defragment()
        assert not sys_.backend.paused
        # ewma entries only for live segments
        assert set(backend_obj.ewma_ms) <= set(backend_obj.segments)
        # owner index exactly mirrors deployment
        assert set(backend_obj._owner_of) == {
            t for seg in backend_obj.segments.values() for t in seg.spec.task_ids
        }
