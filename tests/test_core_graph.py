"""Unit tests for the dataflow graph model (paper §3.1)."""
import pytest

from repro.core import Dataflow, DataflowError, Task, canonical_config
from helpers import chain_df, diamond_df


def test_canonical_config_order_insensitive():
    assert canonical_config({"a": 1, "b": 2}) == canonical_config({"b": 2, "a": 1})
    assert canonical_config("SOURCE") == "SOURCE"
    assert canonical_config({"w": 10}) != canonical_config({"w": 11})


def test_task_similarity():
    t1 = Task.make("x", "kalman", {"q": 0.1})
    t2 = Task.make("y", "kalman", {"q": 0.1})
    t3 = Task.make("z", "kalman", {"q": 0.2})
    t4 = Task.make("w", "parse", {"q": 0.1})
    assert t1.type_similar(t2) and t1.config_similar(t2)
    assert t1.type_similar(t3) and not t1.config_similar(t3)
    assert not t1.type_similar(t4)


def test_source_sink_flags():
    src = Task.make("s", "urban", "SOURCE")
    snk = Task.make("k", "store", "SINK")
    mid = Task.make("m", "parse", {})
    assert src.is_source and not src.is_sink
    assert snk.is_sink and not snk.is_source
    assert not mid.is_source and not mid.is_sink


def test_topological_order_and_cycle_detection():
    d = chain_df("A", "urban", [("a", {}), ("b", {})])
    order = d.topological_order()
    pos = {tid: i for i, tid in enumerate(order)}
    for u, v in d.streams:
        assert pos[u] < pos[v]

    # Introduce a cycle via raw mutation and expect failure.
    d2 = Dataflow("cyc")
    t1 = d2.add_task(Task.make("1", "a", {}))
    t2 = d2.add_task(Task.make("2", "b", {}))
    d2.add_stream("1", "2")
    d2.add_stream("2", "1")
    with pytest.raises(DataflowError):
        d2.topological_order()


def test_validate_rejects_source_with_inputs():
    d = Dataflow("bad")
    d.add_task(Task.make("s", "urban", "SOURCE"))
    d.add_task(Task.make("s2", "meter", "SOURCE"))
    with pytest.raises(DataflowError):
        d.add_stream("s", "s")  # self loop
    d.add_stream("s", "s2")
    with pytest.raises(DataflowError):
        d.validate()


def test_validate_rejects_orphan_task():
    d = Dataflow("orphan")
    d.add_task(Task.make("s", "urban", "SOURCE"))
    d.add_task(Task.make("p", "parse", {}))
    with pytest.raises(DataflowError):
        d.validate()


def test_duplicate_task_id_conflict():
    d = Dataflow("dup")
    d.add_task(Task.make("x", "parse", {}))
    d.add_task(Task.make("x", "parse", {}))  # identical re-add is a no-op
    with pytest.raises(DataflowError):
        d.add_task(Task.make("x", "kalman", {}))


def test_connected_components():
    d = Dataflow("cc")
    for i in range(4):
        d.add_task(Task.make(f"t{i}", "op", {}))
    d.add_stream("t0", "t1")
    d.add_stream("t2", "t3")
    comps = d.connected_components()
    assert sorted(sorted(c) for c in comps) == [["t0", "t1"], ["t2", "t3"]]


def test_subgraph_and_copy():
    d = diamond_df("dia")
    sub = d.subgraph("sub", {f"dia.src", "dia.f1"})
    assert len(sub.tasks) == 2 and len(sub.streams) == 1
    cp = d.copy()
    assert cp.tasks == d.tasks and cp.streams == d.streams
    cp.remove_task("dia.f1")
    assert "dia.f1" in d.tasks  # deep independence


def test_json_roundtrip():
    d = diamond_df("dia")
    d2 = Dataflow.from_json(d.to_json())
    assert d2.tasks == d.tasks
    assert d2.streams == d.streams


def test_remove_task_cleans_streams():
    d = diamond_df("dia")
    d.remove_task("dia.join")
    assert all("dia.join" not in s for s in d.streams)
    assert "dia.join" not in d.tasks
