"""Distribution features that need >1 device: run in fresh subprocesses
with XLA_FLAGS device-count overrides (the pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Every test here spawns a fresh interpreter with a multi-device XLA config —
# seconds each; excluded from the fast sweep (-m "not slow").
pytestmark = pytest.mark.slow


def _run(code: str, devices: int = 4, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=ROOT,
    )


def test_moe_ep_matches_dense_dispatch():
    """Expert-parallel shard_map MoE ≡ GSPMD scatter dispatch (no drops)."""
    code = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import mlp as M
from repro.models import sharding as shd
from repro.models.common import KeyGen

cfg = configs.get_smoke_config("deepseek-v2-236b")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = shd.AxisRules({"data": 2, "model": 2}); rules.mesh = mesh
p = M.moe_params(KeyGen(jax.random.PRNGKey(0)), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
y_dense = M.moe_layer(p, x, cfg)
with mesh:
    M.MOE_IMPL = "ep"
    with shd.use_rules(rules):
        y_ep = jax.jit(lambda p, x: M.moe_layer(p, x, cfg))(p, x)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep), rtol=2e-4, atol=2e-4)
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_dryrun_cell_compiles_multipod():
    """One real dry-run cell on the 512-device multi-pod mesh."""
    r = _run(
        "import repro.launch.dryrun as d; import sys; "
        "sys.exit(d.main(['--arch','seamless-m4t-medium','--shape','train_4k','--multi-pod']))",
        devices=1,  # dryrun sets its own XLA_FLAGS before jax import
        timeout=1800,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert '"status": "ok"' in r.stdout


def test_sharded_train_step_on_mesh():
    """A reduced train step jits with real in_shardings on a 2×2 mesh and
    the loss matches the unsharded step."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import sharding as shd
from repro.train import AdamWConfig, make_train_step, train_state_init

cfg = configs.get_smoke_config("qwen3-4b")
opt = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=4)
step = make_train_step(cfg, opt, accum=2)
state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
batch = {
  "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size),
  "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab_size),
}
_, m_ref = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = shd.AxisRules({"data": 2, "model": 2}); rules.mesh = mesh
pspecs = shd.infer_param_specs(state["params"], rules)
sspecs = {"step": P(), "params": pspecs, "mu": pspecs, "nu": pspecs}
bspecs = {"tokens": P("data", None), "labels": P("data", None)}
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
with mesh:
    with shd.use_rules(rules):
        sharded = jax.jit(step, in_shardings=(ns(sspecs), ns(bspecs)))
        state2, m = sharded(state, batch)
np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]), rtol=1e-4)
print("OK", float(m["loss"]))
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="partial-auto shard_map needs newer JAX; this XLA build rejects it "
    "(UNIMPLEMENTED: PartitionId under SPMD partitioning)",
)
def test_pipeline_parallel_decode_runs():
    """PP decode (shard_map manual-data/auto-model) compiles and runs a
    steady-state round on a 2×2 mesh; logits finite, cache len advances."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch import specs as S
from repro.models import decode as dec
from repro.models import init_params, init_cache

cfg = configs.get_smoke_config("granite-20b")  # 2 layers % 2 stages == 0
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = S.make_rules(mesh); rules.mesh = mesh
params = init_params(cfg, jax.random.PRNGKey(0))
B = 4
cache = dict(init_cache(cfg, B, 32))
cache["len"] = jnp.asarray(8, jnp.int32)
cache["pp_h"] = jnp.zeros((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
with mesh:
    logits, new_cache = jax.jit(
        lambda p, t, c: dec.decode_step_pp(p, cfg, t, c, rules)
    )(params, tokens, cache)
assert logits.shape == (B, cfg.padded_vocab), logits.shape
assert bool(jnp.isfinite(logits).all())
assert int(new_cache["len"]) == 9
assert new_cache["pp_h"].shape == (B, 1, cfg.d_model)
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
