"""Property-based tests (hypothesis) on the paper's §3.3 system invariants:

  C1 (Sink Task Coverage): every submitted sink has an equivalent task in
      the running set.
  C2 (Task & Stream Minimization): running DAGs are disjoint + de-dup and
      contain only tasks/streams in some submitted sink's ancestor graph.

The invariants must hold after EVERY prefix of an arbitrary interleaved
submit/remove sequence, for both merge strategies, and both strategies
must agree on the resulting running-set size (signature ≡ faithful)."""
from __future__ import annotations

from typing import List

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import ReuseManager
from repro.core.graph import Dataflow, Task
from repro.core.invariants import check_all

# -- random de-dup DAG strategy ------------------------------------------------

_TYPES = [f"op{i}" for i in range(6)]
_SOURCES = ["urban", "meter", "taxi"]
_CONFIGS = [{}, {"a": 1}]


@st.composite
def dataflow(draw, name: str) -> Dataflow:
    df = Dataflow(name)
    n_src = draw(st.integers(1, 2))
    srcs = draw(
        st.lists(st.sampled_from(_SOURCES), min_size=n_src, max_size=n_src, unique=True)
    )
    nodes: List[str] = []
    for s in srcs:
        t = df.add_task(Task.make(f"{name}/src/{s}", s, "SOURCE"))
        nodes.append(t.id)
    n_mid = draw(st.integers(1, 6))
    for i in range(n_mid):
        typ = draw(st.sampled_from(_TYPES))
        cfg = draw(st.sampled_from(_CONFIGS))
        t = df.add_task(Task.make(f"{name}/m{i}", typ, cfg))
        # parents: 1-2 existing nodes
        n_par = draw(st.integers(1, min(2, len(nodes))))
        parents = draw(
            st.lists(st.sampled_from(nodes), min_size=n_par, max_size=n_par, unique=True)
        )
        for p in parents:
            df.add_stream(p, t.id)
        nodes.append(t.id)
    # connect weak components (submitted dataflows must be one application)
    comps = df.connected_components()
    if len(comps) > 1:
        reps = [sorted(c)[0] for c in comps]
        join_parents = []
        for rep in reps:
            cands = [tid for tid in sorted(comps[reps.index(rep)])
                     if not df.tasks[tid].is_sink]
            join_parents.append(cands[-1])
        j = df.add_task(Task.make(f"{name}/join", "join", {}))
        for p in join_parents:
            df.add_stream(p, j.id)
    # every leaf gets a sink (submitted DAGs must terminate in sinks)
    leaves = [tid for tid in df.tasks if not df.children(tid) and not df.tasks[tid].is_sink]
    for j2, leaf in enumerate(leaves):
        snk = df.add_task(Task.make(f"{name}/sink{j2}", "store", "SINK"))
        df.add_stream(leaf, snk.id)
    df.validate()
    from repro.core.signatures import dedup_fast

    return dedup_fast(df)


@st.composite
def op_sequence(draw):
    n = draw(st.integers(2, 8))
    dags = [draw(dataflow(f"df{i}")) for i in range(n)]
    # interleaved ops: add all eventually; removes of present ones in between
    ops = []
    present: List[str] = []
    pending = list(range(n))
    while pending or (present and draw(st.booleans())):
        if pending and (not present or draw(st.booleans())):
            i = pending.pop(0)
            ops.append(("add", i))
            present.append(dags[i].name)
        elif present:
            idx = draw(st.integers(0, len(present) - 1))
            ops.append(("remove", present.pop(idx)))
        else:
            break
    return dags, ops


@settings(max_examples=40, deadline=None, suppress_health_check=list(HealthCheck))
@given(op_sequence())
def test_invariants_hold_after_every_op(seq):
    dags, ops = seq
    by_name = {d.name: d for d in dags}
    sig = ReuseManager(strategy="signature", check_invariants=False)
    fai = ReuseManager(strategy="faithful", check_invariants=False)
    for op, arg in ops:
        if op == "add":
            df = dags[arg]
            sig.submit(df.copy())
            fai.submit(df.copy())
        else:
            sig.remove(arg)
            fai.remove(arg)
        # C1 + C2 for both strategies, after every prefix
        check_all(sig.submitted, sig.running, sig.task_maps, sig.phi)
        check_all(fai.submitted, fai.running, fai.task_maps, fai.phi)
        # strategies agree on the minimal running set size
        assert sig.running_task_count == fai.running_task_count


@settings(max_examples=25, deadline=None, suppress_health_check=list(HealthCheck))
@given(op_sequence())
def test_full_drain_empties_running_set(seq):
    dags, ops = seq
    mgr = ReuseManager(strategy="signature")
    present = set()
    for op, arg in ops:
        if op == "add":
            mgr.submit(dags[arg].copy())
            present.add(dags[arg].name)
        else:
            mgr.remove(arg)
            present.discard(arg)
    for name in sorted(present):
        mgr.remove(name)
    assert mgr.running_task_count == 0
    assert not mgr.running


@settings(max_examples=20, deadline=None, suppress_health_check=list(HealthCheck))
@given(op_sequence())
def test_checkpoint_roundtrip_is_fixed_point(seq):
    """snapshot → serialize → restore → snapshot is a fixed point for
    arbitrary submit/remove/defragment sequences (durable data plane).

    Drives a full StreamSystem (dry-run data plane — JAX-free, so
    hypothesis can afford whole-system examples) through the interleaved
    op sequence with a step after every op and a defrag every third op,
    then requires: the checkpoint payload survives a JSON round trip into
    a fresh system unchanged, the BackendSnapshot is equal, and both
    systems keep stepping identically afterwards."""
    import json

    from repro.runtime.system import StreamSystem

    dags, ops = seq
    system = StreamSystem(strategy="signature", backend="dryrun")
    for i, (op, arg) in enumerate(ops):
        if op == "add":
            system.submit(dags[arg].copy())
        else:
            system.remove(arg)
        system.step()
        if i % 3 == 2:
            system.defragment()

    payload = system.checkpoint_payload()
    blob = json.dumps(payload, sort_keys=True)  # "serialize"
    restored = StreamSystem.from_payload(json.loads(blob))
    assert restored.checkpoint_payload() == payload  # fixed point
    assert restored.backend.snapshot() == system.backend.snapshot()
    # and the restored system is behaviorally the same system going forward
    for _ in range(2):
        a, b = system.step(), restored.step()
        assert (a.live_tasks, a.paused_tasks) == (b.live_tasks, b.paused_tasks)
        assert a.cost == b.cost
    assert restored.backend.snapshot() == system.backend.snapshot()


@settings(max_examples=25, deadline=None, suppress_health_check=list(HealthCheck))
@given(op_sequence())
def test_signature_bijection_oracle(seq):
    """sig(t_i) == sig(t_j) ⟺ t_i ↔ t_j (the §5 beyond-paper theorem),
    cross-checked via the faithful EquivalenceChecker on running DAGs."""
    from repro.core.equivalence import EquivalenceChecker
    from repro.core.signatures import compute_signatures

    dags, ops = seq
    mgr = ReuseManager(strategy="signature")
    for op, arg in ops:
        if op == "add":
            mgr.submit(dags[arg].copy())
        else:
            mgr.remove(arg)
    dfs = list(mgr.running.values())
    for df in dfs[:2]:
        sigs = compute_signatures(df)
        checker = EquivalenceChecker(df, df)
        tids = sorted(df.tasks)[:12]
        for a in tids:
            for b in tids:
                assert (sigs[a] == sigs[b]) == checker.equivalent(a, b), (a, b)
