"""Roofline HLO parser: trip-count multiplication, collective wire
factors, slice-aware HBM accounting — on a hand-written HLO fixture."""
import textwrap

from repro.roofline import hlo_parse
from repro.roofline.analysis import HW, kernel_boundary_bytes, model_flops

FIXTURE = textwrap.dedent("""
    HloModule jit_f, num_partitions=8

    %body (param: (s32[], f32[4,64], f32[6,256,64])) -> (s32[], f32[4,64], f32[6,256,64]) {
      %param = (s32[], f32[4,64]{1,0}, f32[6,256,64]{2,1,0}) parameter(0)
      %gte0 = s32[] get-tuple-element(%param), index=0
      %gte1 = f32[4,64]{1,0} get-tuple-element(%param), index=1
      %gte2 = f32[6,256,64]{2,1,0} get-tuple-element(%param), index=2
      %c1 = s32[] constant(1)
      %add = s32[] add(%gte0, %c1)
      %ds = f32[1,256,64]{2,1,0} dynamic-slice(%gte2, %gte0, %c1, %c1), dynamic_slice_sizes={1,256,64}
      %w = f32[256,64]{1,0} bitcast(%ds)
      %ag = f32[4,256]{0,1} all-gather(%gte1), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
      %dot = f32[4,64]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %tup = (s32[], f32[4,64]{1,0}, f32[6,256,64]{2,1,0}) tuple(%add, %dot, %gte2)
    }

    %cond (param.1: (s32[], f32[4,64], f32[6,256,64])) -> pred[] {
      %param.1 = (s32[], f32[4,64]{1,0}, f32[6,256,64]{2,1,0}) parameter(0)
      %g = s32[] get-tuple-element(%param.1), index=0
      %n = s32[] constant(6)
      ROOT %lt = pred[] compare(%g, %n), direction=LT
    }

    ENTRY %main (p0: f32[6,256,64], p1: f32[4,64]) -> f32[4,64] {
      %p0 = f32[6,256,64]{2,1,0} parameter(0)
      %p1 = f32[4,64]{1,0} parameter(1)
      %c0 = s32[] constant(0)
      %tup0 = (s32[], f32[4,64]{1,0}, f32[6,256,64]{2,1,0}) tuple(%c0, %p1, %p0)
      %wh = (s32[], f32[4,64]{1,0}, f32[6,256,64]{2,1,0}) while(%tup0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"6"}}
      ROOT %out = f32[4,64]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_trip_count_flops():
    r = hlo_parse.analyze(FIXTURE)
    # dot: 2 · (4·64) · 256 = 131072 per iter × 6 iters
    assert r["flops"] == 6 * 131072.0


def test_collective_wire_bytes():
    r = hlo_parse.analyze(FIXTURE)
    # all-gather operand f32[4,64] = 1024 B × 6 iters; ring factor (4−1)/4
    assert r["collective_bytes_by_type"]["all-gather"] == 6 * 1024
    assert r["collective_wire_bytes_by_type"]["all-gather"] == 6 * 1024 * 0.75
    assert r["collective_counts_by_type"]["all-gather"] == 6


def test_dynamic_slice_charged_at_slice_size():
    r = hlo_parse.analyze(FIXTURE)
    # the f32[6,256,64] operand must NOT be charged per iteration:
    # hbm ≪ 6 iters × 393 KB
    assert r["hbm_bytes"] < 6 * 65536 * 4 * 2 + 6 * (1024 * 8 + 65536 * 8) + 1e6


def test_group_size_parsing():
    assert hlo_parse._group_size("replica_groups=[2,4]<=[8]") == 4
    assert hlo_parse._group_size("replica_groups={{0,1},{2,3}}") == 2


def test_model_flops_families():
    from repro import configs

    cell_train = configs.shape_cell("train_4k")
    cell_dec = configs.shape_cell("decode_32k")
    for arch in ("qwen3-4b", "deepseek-v2-236b", "seamless-m4t-medium"):
        cfg = configs.get_config(arch)
        ft = model_flops(cfg, cell_train)
        fd = model_flops(cfg, cell_dec)
        assert ft > fd > 0
        _, active = cfg.param_count()
        # train ≈ 6·N_active·tokens within 2× (enc-dec splits params)
        approx = 6.0 * active * cell_train.global_batch * cell_train.seq_len
        assert 0.3 * approx <= ft <= 1.01 * approx


def test_kernel_boundary_positive_for_kernel_archs():
    from repro import configs

    cell = configs.shape_cell("train_4k")
    for arch, scope in (
        ("qwen3-4b", "kernel_flash_attn"),
        ("zamba2-2.7b", "kernel_ssd_scan"),
        ("xlstm-1.3b", "kernel_mlstm_scan"),
    ):
        b = kernel_boundary_bytes(configs.get_config(arch), cell)
        assert b.get(scope, 0) > 0, (arch, b)
