"""Unit tests for ancestor graphs + equivalence (paper §3.2), and the
signature fast path cross-check (beyond-paper)."""
import pytest

from repro.core import (
    Dataflow,
    EquivalenceChecker,
    Task,
    ancestor_graph,
    ancestor_graph_set,
    compute_signatures,
    dataflows_disjoint,
    dedup,
    dedup_fast,
    find_equivalent_tasks,
    is_dedup,
    is_dedup_fast,
    maximal,
    maximal_ancestor_intersection,
)
from helpers import chain_df, diamond_df, fig1, two_source_df


def test_ancestor_graph_chain():
    d = chain_df("A", "urban", [("a", {}), ("b", {})])
    order = d.topological_order()
    ag = ancestor_graph(d, order[-1])  # sink
    assert ag.task_ids == set(d.tasks)
    assert ag.streams == d.streams
    ag0 = ancestor_graph(d, order[0])  # source
    assert ag0.task_ids == {order[0]} and not ag0.streams


def test_ancestor_graph_diamond():
    d = diamond_df("dia")
    ag = ancestor_graph(d, "dia.join")
    assert ag.task_ids == {"dia.src", "dia.f1", "dia.f2", "dia.join"}
    assert len(ag.streams) == 4


def test_maximal_ancestor_set_matches_sink_count():
    # Paper §3.2: |maximal set| == number of sinks.
    for d in (*fig1(), diamond_df("dia"), two_source_df("ts")):
        ags = maximal(ancestor_graph_set(d))
        assert len(ags) == len(d.sink_ids)
        assert {a.root for a in ags} == set(d.sink_ids)


def test_equivalence_prefix_chains():
    A, B, C, D = fig1()
    matches = find_equivalent_tasks(A, B)
    # A's src, parse, kalman are equivalent to B's; A's sink differs (type).
    assert len(matches) == 3
    # D shares types but a different source → disjoint.
    assert dataflows_disjoint(A, D)
    assert not dataflows_disjoint(A, C)


def test_equivalence_requires_config_match():
    A = chain_df("A", "urban", [("kalman", {"q": 0.1})])
    B = chain_df("B", "urban", [("kalman", {"q": 0.2})])
    matches = find_equivalent_tasks(A, B)
    assert len(matches) == 1  # only the source matches


def test_equivalence_requires_ancestry_match():
    # Same type+config but different upstream chain → NOT equivalent.
    A = chain_df("A", "urban", [("parse", {}), ("avg", {})])
    B = chain_df("B", "urban", [("avg", {})])
    ch = EquivalenceChecker(A, B)
    a_avg = "A.1.avg"
    b_avg = "B.0.avg"
    assert not ch.equivalent(a_avg, b_avg)


def test_equivalence_diamond_and_witness():
    d1 = diamond_df("x")
    d2 = diamond_df("y")
    ch = EquivalenceChecker(d1, d2)
    assert ch.equivalent("x.join", "y.join")
    eps = ch.witness("x.join", "y.join")
    assert eps == {
        "x.join": "y.join",
        "x.f1": "y.f1",
        "x.f2": "y.f2",
        "x.src": "y.src",
    }


def test_fork_join_asymmetry_not_equivalent():
    d1 = diamond_df("x", merge_cfg={"mode": "zip"})
    d2 = diamond_df("y", merge_cfg={"mode": "concat"})
    ch = EquivalenceChecker(d1, d2)
    assert not ch.equivalent("x.join", "y.join")
    assert ch.equivalent("x.f1", "y.f1")


def test_maximal_ancestor_intersection_fig1():
    A, B, C, D = fig1()
    inter = maximal_ancestor_intersection(B, C)
    # Frontier of equivalence between B and C is B's win task.
    assert len(inter) == 1
    assert inter[0].root == "B.2.win"
    assert len(inter[0].task_ids) == 4


def test_is_dedup_and_dedup():
    d = Dataflow("dup")
    s = d.add_task(Task.make("s", "urban", "SOURCE"))
    p1 = d.add_task(Task.make("p1", "parse", {}))
    p2 = d.add_task(Task.make("p2", "parse", {}))  # duplicate of p1
    k = d.add_task(Task.make("k", "store", "SINK"))
    k2 = d.add_task(Task.make("k2", "store2", "SINK"))
    d.add_stream("s", "p1")
    d.add_stream("s", "p2")
    d.add_stream("p1", "k")
    d.add_stream("p2", "k2")
    assert not is_dedup(d)
    assert not is_dedup_fast(d)
    dd = dedup(d)
    assert is_dedup(dd)
    assert len(dd.tasks) == 4  # p2 collapsed into p1
    ddf = dedup_fast(d)
    assert {t.type for t in ddf.tasks.values()} == {t.type for t in dd.tasks.values()}
    assert len(ddf.tasks) == 4


def test_signature_theorem_equivalence_iff_equal_sigs():
    """sig(t_i) == sig(t_j) ⟺ t_i ↔ t_j, across several DAG shapes."""
    dfs = [*fig1(), diamond_df("dia"), two_source_df("ts")]
    sigs = {df.name: compute_signatures(df) for df in dfs}
    for da in dfs:
        for db in dfs:
            if da.name == db.name:
                continue
            ch = EquivalenceChecker(da, db)
            for ta in da.tasks:
                for tb in db.tasks:
                    assert ch.equivalent(ta, tb) == (
                        sigs[da.name][ta] == sigs[db.name][tb]
                    ), (da.name, ta, db.name, tb)
