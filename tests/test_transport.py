"""Stream-transport subsystem tests.

Three layers:
  * a conformance suite every registered transport (inproc / shm / tcp)
    must pass — publish/fetch round-trips, per-topic sequencing
    (``fetch_synced``), drop-wake semantics under a blocked synced fetch,
    drop + republish sequence reset, counters (cumulative across drops,
    resettable, restorable), registry/observability surface;
  * cross-process attachment: ``connect_info`` → ``connect_transport`` in
    a spawned worker process publishes batches the parent fetches
    bit-exactly (shm and tcp; inproc refuses with a clear error);
  * the data plane on a non-default transport: the in-process jit backend
    stepped over shm and tcp produces sink digests identical to the
    in-process broker on the fig-1 churn scenario, via the
    ``StreamSystem(transport=...)`` injection point.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time

import numpy as np
import pytest

from repro.runtime.transport import (
    ShmTransport,
    TcpTransport,
    TopicDropped,
    Transport,
    TransportError,
    TransportTimeout,
    available_transports,
    connect_transport,
    register_transport,
    resolve_transport,
)

TRANSPORTS = ["inproc", "shm", "tcp"]
SPANNING = ["shm", "tcp"]  # transports that cross process boundaries


def _batch(fill=1.0, n=4):
    return np.full((n, 8), fill, dtype=np.float32)


@pytest.fixture(params=TRANSPORTS)
def transport(request):
    t = resolve_transport(request.param)
    yield t
    t.close()


class TestRegistry:
    def test_builtins_available(self):
        assert {"inproc", "shm", "tcp"} <= set(available_transports())

    def test_unknown_rejected(self):
        with pytest.raises(ValueError, match="unknown transport"):
            resolve_transport("no-such-transport")
        with pytest.raises(TypeError):
            resolve_transport(42)

    def test_instance_passthrough_and_custom_class(self):
        inst = resolve_transport("inproc")
        assert resolve_transport(inst) is inst

        class MyTransport(ShmTransport):
            name = "test-custom-transport"

        register_transport(MyTransport)
        assert "test-custom-transport" in available_transports()

    def test_duplicate_name_rejected(self):
        with pytest.raises(ValueError, match="already registered"):

            @register_transport
            class Dup(Transport):
                name = "shm"


class TestTransportConformance:
    def test_publish_fetch_roundtrip_bit_exact(self, transport):
        b = np.arange(32, dtype=np.float32).reshape(4, 8) * 0.37
        transport.publish("stream/t1", b)
        got = np.asarray(transport.fetch("stream/t1"))
        assert got.dtype == b.dtype and got.shape == b.shape
        assert np.array_equal(got, b)

    def test_fetch_unknown_topic_raises(self, transport):
        with pytest.raises(KeyError):
            transport.fetch("stream/nope")

    def test_sequence_advances_per_publish(self, transport):
        assert transport.seq("stream/s") == 0
        transport.publish("stream/s", _batch(1.0))
        transport.publish("stream/s", _batch(2.0))
        assert transport.seq("stream/s") == 2
        assert transport.sequences() == {"stream/s": 2}

    def test_fetch_synced_returns_latest_once_reached(self, transport):
        transport.publish("stream/s", _batch(1.0))
        transport.publish("stream/s", _batch(2.0))
        got = np.asarray(transport.fetch_synced("stream/s", 2))
        assert got[0, 0] == 2.0

    def test_fetch_synced_blocks_until_publish(self, transport):
        transport.publish("stream/s", _batch(1.0))
        out = []

        def consumer():
            out.append(np.asarray(transport.fetch_synced("stream/s", 2, timeout=10)))

        th = threading.Thread(target=consumer)
        th.start()
        time.sleep(0.05)
        assert not out  # still blocked on seq 2
        transport.publish("stream/s", _batch(7.0))
        th.join(5)
        assert out and out[0][0, 0] == 7.0

    def test_drop_wakes_blocked_synced_fetch(self, transport):
        transport.publish("stream/s", _batch(1.0))
        err = []

        def consumer():
            try:
                transport.fetch_synced("stream/s", 5, timeout=10)
            except KeyError:
                err.append("woken")

        th = threading.Thread(target=consumer)
        th.start()
        time.sleep(0.05)
        transport.drop("stream/s")
        th.join(5)
        assert err == ["woken"]

    def test_drop_then_republish_resets_sequence(self, transport):
        transport.publish("stream/s", _batch(1.0))
        transport.publish("stream/s", _batch(2.0))
        transport.drop("stream/s")
        assert not transport.has("stream/s")
        transport.publish("stream/s", _batch(3.0))
        assert transport.seq("stream/s") == 1
        assert np.asarray(transport.fetch("stream/s"))[0, 0] == 3.0

    def test_counters_cumulative_across_drops(self, transport):
        b = _batch()
        transport.publish("stream/a", b)
        transport.publish("stream/b", b)
        transport.drop("stream/a")
        c = transport.counters()
        assert c["publishes"] == 2
        assert c["bytes_published"] == 2 * b.nbytes
        assert transport.bytes_published == c["bytes_published"]
        assert transport.publishes == 2

    def test_counters_reset_and_restore(self, transport):
        transport.publish("stream/a", _batch())
        transport.reset_counters()
        assert transport.counters() == {"bytes_published": 0, "publishes": 0}
        transport.restore_counters(1234, 5)
        assert transport.counters() == {"bytes_published": 1234, "publishes": 5}

    def test_len_and_topics_cover_live_topics_only(self, transport):
        transport.publish("stream/a", _batch(1.0))
        transport.publish("stream/b", _batch(2.0))
        transport.drop("stream/a")
        assert len(transport) == 1
        topics = transport.topics()
        assert set(topics) == {"stream/b"}
        assert np.asarray(topics["stream/b"])[0, 0] == 2.0

    def test_ring_overwrites_keep_latest(self, transport):
        for i in range(12):  # laps the shm ring (4 slots) twice
            transport.publish("stream/s", _batch(float(i)))
        assert np.asarray(transport.fetch("stream/s"))[0, 0] == 11.0
        assert transport.seq("stream/s") == 12


class TestErrorTaxonomy:
    """Typed transport errors, uniform across inproc / shm / tcp.

    ``TopicDropped`` doubles as ``KeyError`` and ``TransportTimeout`` as
    ``TimeoutError`` so pre-taxonomy handlers keep working.
    """

    def test_hierarchy(self):
        assert issubclass(TopicDropped, TransportError)
        assert issubclass(TopicDropped, KeyError)
        assert issubclass(TransportTimeout, TransportError)
        assert issubclass(TransportTimeout, TimeoutError)
        assert issubclass(TransportError, RuntimeError)

    def test_topic_dropped_message_not_repr_quoted(self):
        # KeyError.__str__ reprs its arg; the taxonomy must not — the
        # message crosses the tcp wire as text and round-trips verbatim.
        msg = "topic 'stream/x' dropped"
        assert str(TopicDropped(msg)) == msg

    def test_fetch_unknown_topic_typed(self, transport):
        with pytest.raises(TopicDropped):
            transport.fetch("stream/nope")

    def test_fetch_synced_timeout_typed(self, transport):
        transport.publish("stream/s", _batch(1.0))
        with pytest.raises(TransportTimeout):
            transport.fetch_synced("stream/s", 99, timeout=0.05)

    def test_drop_wakes_blocked_fetch_with_typed_error(self, transport):
        transport.publish("stream/s", _batch(1.0))
        err = []

        def consumer():
            try:
                transport.fetch_synced("stream/s", 5, timeout=10)
            except TopicDropped:
                err.append("typed")

        th = threading.Thread(target=consumer)
        th.start()
        time.sleep(0.05)
        transport.drop("stream/s")
        th.join(5)
        assert err == ["typed"]


class TestZeroCopyViews:
    @pytest.mark.parametrize("name", SPANNING)
    def test_fetch_is_readonly_by_default_copy_is_writable(self, name):
        t = resolve_transport(name)
        try:
            b = np.arange(32, dtype=np.float32).reshape(4, 8)
            t.publish("stream/v", b)
            view = t.fetch("stream/v")
            assert not view.flags.writeable
            with pytest.raises(ValueError):
                view[0, 0] = 9.0
            assert np.array_equal(view, b)
            copy = t.fetch("stream/v", copy=True)
            assert copy.flags.writeable
            copy[0, 0] = 9.0  # private — must not corrupt the transport
            assert np.asarray(t.fetch("stream/v"))[0, 0] == 0.0
        finally:
            t.close()

    def test_shm_view_lifetime_and_revalidation(self):
        t = ShmTransport()
        try:
            t.publish("stream/v", _batch(1.0))
            view, seq = t.fetch_view("stream/v")
            assert seq == 1 and not view.flags.writeable
            assert np.array_equal(view, _batch(1.0))
            # valid while the writer stays within nslots-2 further publishes
            t.publish("stream/v", _batch(2.0))
            t.publish("stream/v", _batch(3.0))
            assert t.view_valid("stream/v", seq)
            assert np.array_equal(view, _batch(1.0))  # slot still untouched
            t.publish("stream/v", _batch(4.0))  # writer reaches seq+nslots-1
            assert not t.view_valid("stream/v", seq)
            # the escape hatch: a private copy is always safe
            fresh = t.fetch("stream/v", copy=True)
            assert fresh.flags.writeable and fresh[0, 0] == 4.0
        finally:
            t.close()

    def test_view_valid_unknown_topic_is_false(self):
        t = ShmTransport()
        try:
            assert not t.view_valid("stream/nope", 1)
        finally:
            t.close()

    def test_fetch_view_synced_waits_for_min_seq(self):
        t = ShmTransport()
        try:
            t.publish("stream/v", _batch(1.0))
            t.publish("stream/v", _batch(2.0))
            view, seq = t.fetch_view("stream/v", min_seq=2)
            assert seq == 2 and view[0, 0] == 2.0
            with pytest.raises(TransportTimeout):
                t.fetch_view("stream/v", min_seq=5, timeout=0.05)
        finally:
            t.close()

    def test_inproc_copy_escape_hatch(self):
        t = resolve_transport("inproc")
        b = _batch(3.0)
        t.publish("stream/v", b)
        copy = t.fetch("stream/v", copy=True)
        copy[0, 0] = -1.0
        assert np.asarray(t.fetch("stream/v"))[0, 0] == 3.0


def _stress_writer(spec, topic, rounds, batch):
    t = connect_transport(spec)
    for i in range(rounds):
        t.publish(topic, np.full((batch, 8), float(i + 1), dtype=np.float32))
    t.close()


class TestSeqlockStress:
    def test_reader_never_observes_torn_batch(self):
        """A fast writer laps the 4-slot ring while the reader fetches.

        Every publish is a uniform fill, so any torn read (slot payload
        overwritten mid-copy without the seqlock catching it) shows up as
        a non-uniform batch. The jittered-backoff retry in ``_read_latest``
        must keep this deterministic: no tearing, no spurious lap errors.
        """
        rounds, batch = 1500, 64
        t = ShmTransport()
        try:
            t.publish("stream/hot", np.full((batch, 8), 0.0, np.float32))
            ctx = mp.get_context("spawn")
            proc = ctx.Process(
                target=_stress_writer,
                args=(t.connect_info(), "stream/hot", rounds, batch),
            )
            proc.start()
            last = 0.0
            try:
                while proc.is_alive() or last < float(rounds):
                    got = t.fetch("stream/hot", copy=True)
                    vals = np.unique(got)
                    assert vals.size == 1, f"torn batch: {vals[:8]}"
                    assert vals[0] >= last  # monotone: never a stale slot
                    last = float(vals[0])
                    if last >= float(rounds):
                        break
            finally:
                proc.join(60)
            assert proc.exitcode == 0
            assert last == float(rounds)
        finally:
            t.close()


class TestShmSpecifics:
    def test_slot_overflow_raises_clear_error(self):
        t = ShmTransport(slot_bytes=64)
        try:
            with pytest.raises(TransportError, match="slot_bytes"):
                t.publish("stream/big", np.zeros((64, 8), np.float32))
        finally:
            t.close()

    def test_close_removes_session_dir(self, tmp_path):
        import os

        t = ShmTransport()
        d = t.dir
        t.publish("stream/x", _batch())
        t.close()
        assert not os.path.isdir(d)

    def test_batch_rank_limit(self):
        t = ShmTransport()
        try:
            with pytest.raises(TransportError, match="rank"):
                t.publish("stream/x", np.zeros((1, 1, 1, 1, 1), np.float32))
        finally:
            t.close()


def _child_publish(spec, topic):
    t = connect_transport(spec)
    t.publish(topic, np.full((4, 8), 42.5, dtype=np.float32))
    t.close()


class TestCrossProcess:
    def test_inproc_refuses_to_span(self):
        t = resolve_transport("inproc")
        with pytest.raises(TransportError, match="cannot span"):
            t.connect_info()

    @pytest.mark.parametrize("name", SPANNING)
    def test_child_process_publish_parent_fetch(self, name):
        t = resolve_transport(name)
        try:
            ctx = mp.get_context("spawn")
            proc = ctx.Process(
                target=_child_publish, args=(t.connect_info(), "stream/xp")
            )
            proc.start()
            got = np.asarray(t.fetch_synced("stream/xp", 1, timeout=60))
            proc.join(30)
            assert proc.exitcode == 0
            assert np.array_equal(got, np.full((4, 8), 42.5, dtype=np.float32))
            assert t.counters()["publishes"] == 1
        finally:
            t.close()


# -- the jit data plane on non-default transports -------------------------------


FIG1_OPS = [
    ("add", "A"),
    ("add", "B"),
    ("add", "C"),
    ("remove", "B"),
    ("defrag", ""),
    ("add", "D"),
]


def _run_fig1(transport_name, step_mode="sync"):
    from repro.runtime.system import StreamSystem

    from helpers import fig1

    dags = {d.name: d for d in fig1()}
    system = StreamSystem(
        strategy="signature", backend="inprocess",
        transport=transport_name, step_mode=step_mode,
    )
    for op, name in FIG1_OPS:
        if op == "add":
            system.submit(dags[name].copy())
        elif op == "remove":
            system.remove(name)
        else:
            system.defragment()
        system.step()
    for _ in range(2):
        system.step()
    digests = {
        n: system.sink_digests(n) for n in sorted(system.manager.submitted)
    }
    system.close()
    return digests


class TestJitPlaneOverTransports:
    @pytest.mark.parametrize("name", SPANNING)
    def test_sink_digests_identical_to_inproc(self, name):
        ref = _run_fig1("inproc")
        got = _run_fig1(name)
        assert got == ref  # counts AND checksums — the wire codec is bit-exact

    def test_concurrent_mode_over_shm(self):
        ref = _run_fig1("inproc")
        got = _run_fig1("shm", step_mode="concurrent")
        assert got == ref

    def test_transport_knob_needs_constructible_backend(self):
        from repro.runtime.backend import resolve_backend
        from repro.runtime.system import StreamSystem

        be = resolve_backend("dryrun")
        with pytest.raises(ValueError, match="backend name or"):
            StreamSystem(backend=be, transport="shm")

    def test_checkpoint_restore_preserves_transport_counters(self, tmp_path):
        from repro.runtime.system import StreamSystem

        from helpers import fig1

        A = fig1()[0]
        system = StreamSystem(strategy="signature", backend="inprocess", transport="shm")
        system.submit(A.copy())
        system.submit(fig1()[1].copy())  # creates a boundary stream
        system.run(3)
        payload = system.checkpoint_payload()
        counters = system.backend.transport.counters()
        assert payload["backend_config"]["transport"] == "shm"
        system.close()

        restored = StreamSystem.from_payload(payload)
        assert restored.backend.transport.name == "shm"
        assert restored.backend.transport.counters() == counters
        restored.run(1)
        restored.close()
