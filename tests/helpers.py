"""Shared dataflow builders for the core tests."""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

from repro.core import Dataflow, Task


def chain_df(
    name: str,
    source: str,
    chain: Sequence[Tuple[str, Any]],
    sink: str = "store",
) -> Dataflow:
    """source → chain[0] → … → chain[-1] → sink."""
    d = Dataflow(name)
    prev = d.add_task(Task.make(f"{name}.src.{source}", source, "SOURCE"))
    for i, (typ, cfg) in enumerate(chain):
        t = d.add_task(Task.make(f"{name}.{i}.{typ}", typ, cfg))
        d.add_stream(prev.id, t.id)
        prev = t
    snk = d.add_task(Task.make(f"{name}.sink.{sink}", sink, "SINK"))
    d.add_stream(prev.id, snk.id)
    return d


def fig1() -> Tuple[Dataflow, Dataflow, Dataflow, Dataflow]:
    """The paper's Fig. 1 scenario."""
    A = chain_df("A", "urban", [("parse", {}), ("kalman", {"q": 0.1})], "store_a")
    B = chain_df(
        "B",
        "urban",
        [("parse", {}), ("kalman", {"q": 0.1}), ("win", {"w": 10})],
        "store_b",
    )
    C = chain_df(
        "C",
        "urban",
        [("parse", {}), ("kalman", {"q": 0.1}), ("win", {"w": 10}), ("avg", {})],
        "store_c",
    )
    D = chain_df("D", "meter", [("parse", {}), ("kalman", {"q": 0.1})], "store_d")
    return A, B, C, D


def diamond_df(name: str, source: str = "urban", merge_cfg: Any = None) -> Dataflow:
    """source → (f1, f2) → join → sink — fork/join DAG."""
    d = Dataflow(name)
    src = d.add_task(Task.make(f"{name}.src", source, "SOURCE"))
    f1 = d.add_task(Task.make(f"{name}.f1", "filter", {"sigma": 3}))
    f2 = d.add_task(Task.make(f"{name}.f2", "interp", {"k": 2}))
    j = d.add_task(Task.make(f"{name}.join", "join", merge_cfg or {"mode": "zip"}))
    snk = d.add_task(Task.make(f"{name}.sink", "store", "SINK"))
    d.add_stream(src.id, f1.id)
    d.add_stream(src.id, f2.id)
    d.add_stream(f1.id, j.id)
    d.add_stream(f2.id, j.id)
    d.add_stream(j.id, snk.id)
    return d


def two_source_df(name: str) -> Dataflow:
    """Two sources joined — exercises multi-running-DAG merges."""
    d = Dataflow(name)
    s1 = d.add_task(Task.make(f"{name}.s1", "urban", "SOURCE"))
    s2 = d.add_task(Task.make(f"{name}.s2", "meter", "SOURCE"))
    p1 = d.add_task(Task.make(f"{name}.p1", "parse", {}))
    p2 = d.add_task(Task.make(f"{name}.p2", "parse", {}))
    j = d.add_task(Task.make(f"{name}.j", "join", {"mode": "zip"}))
    snk = d.add_task(Task.make(f"{name}.sink", "store", "SINK"))
    d.add_stream(s1.id, p1.id)
    d.add_stream(s2.id, p2.id)
    d.add_stream(p1.id, j.id)
    d.add_stream(p2.id, j.id)
    d.add_stream(j.id, snk.id)
    return d
