"""Serving front end: slot-based admission, fair share, wire protocol,
ledger durability, and the tcp shutdown-hygiene regression."""
from __future__ import annotations

import json
import os
import socket
import threading
import time

import numpy as np
import pytest

from helpers import chain_df, fig1

from repro.api import ReuseSession
from repro.core import DataflowError
from repro.runtime.transport import TcpBrokerServer, TcpTransport
from repro.serve import (
    ServeClient,
    ServeFrontend,
    SubmitTimeout,
    TenantQuota,
    protocol,
)
from repro.workloads import opmw_workload, tenant_copy, tenant_trace


def frontend(**kwargs) -> ServeFrontend:
    kwargs.setdefault("slots", 32)
    kwargs.setdefault("backend", "dryrun")
    return ServeFrontend(**kwargs)


def cost_df(name: str, kind: str, n: int):
    """A chain costing exactly ``n`` slots, with type-disjoint source,
    stages and sink per ``kind`` so different kinds never reuse each other
    — every submission of a fresh kind charges exactly ``n``."""
    assert n >= 3
    return chain_df(
        name,
        f"{kind}_src",
        [(f"{kind}_op{i}", {"k": i}) for i in range(n - 2)],
        sink=f"{kind}_sink",
    )


# -- preview (admission planning) ------------------------------------------------


class TestPreview:
    def test_preview_matches_submit_and_mutates_nothing(self):
        session = ReuseSession(strategy="signature")
        A, B, C, D = fig1()
        session.submit(A)
        before = (
            dict(session.manager.phi),
            session.manager._task_counter,
            set(session.manager.running),
        )
        plan = session.preview(B)
        assert (
            dict(session.manager.phi),
            session.manager._task_counter,
            set(session.manager.running),
        ) == before
        receipt = session.submit(B)
        assert plan.num_created == receipt.num_created
        assert plan.num_reused == receipt.num_reused

    def test_preview_preserves_minted_ids(self):
        """Interleaving previews must not perturb the ids a later submit
        mints — that determinism is what journal replay (and therefore
        crash recovery) relies on."""
        A, B, C, D = fig1()
        plain = ReuseSession(strategy="signature")
        plain.submit(A)
        expected = plain.submit(B).plan.task_map

        probed = ReuseSession(strategy="signature")
        probed.submit(A)
        for _ in range(3):
            probed.preview(B)
            probed.preview(C)
        assert probed.submit(B).plan.task_map == expected

    def test_preview_rejects_duplicate_name(self):
        session = ReuseSession(strategy="signature")
        A = fig1()[0]
        session.submit(A)
        with pytest.raises(DataflowError):
            session.preview(A)


# -- slot accounting -------------------------------------------------------------


class TestSlotAccounting:
    def test_reused_segments_cost_no_slots(self):
        fe = frontend()
        A, B, C, D = fig1()
        ra = fe.submit("t1", A)
        rb = fe.submit("t2", B)
        assert ra.status == protocol.ADMITTED and ra.slots_charged == len(A.tasks)
        # B shares A's urban→parse→kalman prefix: charged only its new tail.
        assert rb.status == protocol.ADMITTED
        assert rb.slots_charged == len(B.tasks) - rb.reused
        assert rb.reused > 0
        assert fe.slots_used == ra.slots_charged + rb.slots_charged

    def test_identical_resubmission_is_free(self):
        fe = frontend()
        A = fig1()[0]
        fe.submit("t1", A)
        r = fe.submit("t2", A.copy("A2"))
        assert r.status == protocol.ADMITTED
        assert r.slots_charged == 0
        ledger = fe.ledger_for("t2")
        assert ledger.slots_held == 0
        assert ledger.slots_saved == len(A.tasks)

    def test_remove_frees_exactly_what_was_charged(self):
        fe = frontend()
        A, B, _, _ = fig1()
        fe.submit("t1", A)
        rb = fe.submit("t1", B)
        used = fe.slots_used
        out = fe.remove("t1", "B")
        assert out["slots_freed"] == rb.slots_charged
        assert fe.slots_used == used - rb.slots_charged
        assert fe.ledger_for("t1").removed == 1

    def test_effective_capacity_tracks_point_in_time_state(self):
        fe = frontend()
        A = fig1()[0]
        fe.submit("t1", A)
        fe.submit("t2", A.copy("A2"))
        assert fe.stats()["effective_capacity"] == pytest.approx(2.0)
        fe.remove("t2", "A2")
        assert fe.stats()["effective_capacity"] == pytest.approx(1.0)


# -- admission outcomes ----------------------------------------------------------


class TestAdmission:
    def test_quota_exceeded_rejected(self):
        fe = frontend(slots=32, default_quota=TenantQuota(max_slots=5))
        r = fe.submit("t1", cost_df("big", "a", 6))
        assert r.status == protocol.REJECTED
        assert "quota" in r.reason
        assert fe.ledger_for("t1").rejected == 1
        assert fe.slots_used == 0

    def test_cost_beyond_pool_rejected_not_queued(self):
        fe = frontend(slots=4)
        r = fe.submit("t1", cost_df("big", "a", 6))
        assert r.status == protocol.REJECTED
        assert "slot pool" in r.reason

    def test_duplicate_name_rejected(self):
        fe = frontend()
        fe.submit("t1", cost_df("x", "a", 3))
        r = fe.submit("t1", cost_df("x", "b", 3))
        assert r.status == protocol.REJECTED

    def test_retry_after_then_successful_resubmit(self):
        fe = frontend(
            slots=6,
            default_quota=TenantQuota(max_slots=6, max_pending=0),
            retry_after=0.25,
        )
        blocker = fe.submit("t1", cost_df("block", "a", 6))
        assert blocker.status == protocol.ADMITTED
        shed = fe.submit("t2", cost_df("want", "b", 4))
        assert shed.status == protocol.RETRY_AFTER
        assert shed.retry_after == pytest.approx(0.25)
        assert fe.ledger_for("t2").backpressured == 1
        fe.remove("t1", "block")
        again = fe.submit("t2", cost_df("want", "b", 4))
        assert again.status == protocol.ADMITTED

    def test_remove_admits_queued_submission(self):
        fe = frontend(slots=6, default_quota=TenantQuota(max_slots=6, max_pending=4))
        fe.submit("t1", cost_df("block", "a", 6))
        queued = fe.submit("t2", cost_df("next", "b", 4))
        assert queued.status == protocol.QUEUED
        out = fe.remove("t1", "block")
        admitted = [a["name"] for a in out["admitted"]]
        assert admitted == ["next"]
        assert fe.tenant_of["next"] == "t2"

    def test_queued_submission_can_be_cancelled(self):
        fe = frontend(slots=6, default_quota=TenantQuota(max_slots=6, max_pending=4))
        fe.submit("t1", cost_df("block", "a", 6))
        assert fe.submit("t2", cost_df("next", "b", 4)).status == protocol.QUEUED
        out = fe.remove("t2", "next")
        assert out["cancelled"] is True
        assert fe.remove("t1", "block")["admitted"] == []

    def test_zero_cost_submission_admitted_even_when_saturated_queue_empty(self):
        fe = frontend(slots=6)
        A = cost_df("block", "a", 6)
        fe.submit("t1", A)
        r = fe.submit("t2", A.copy("free-rider"))
        assert r.status == protocol.ADMITTED and r.slots_charged == 0

    def test_draining_rejects_new_work(self):
        fe = frontend()
        fe.drain()
        r = fe.submit("t1", cost_df("late", "a", 3))
        assert r.status == protocol.REJECTED
        assert "draining" in r.reason


# -- weighted fair share ---------------------------------------------------------


class TestFairShare:
    def test_greedy_tenant_cannot_starve_light_one(self):
        """A queues 5, B queues 1 behind a blocker; freeing the pool must
        interleave B after A's first admission (vtime order), not drain A
        FIFO-first."""
        fe = frontend(
            slots=9,
            default_quota=TenantQuota(max_slots=9, max_pending=8),
        )
        fe.submit("C", cost_df("block", "c", 9))
        for i in range(5):
            assert fe.submit("A", cost_df(f"a{i}", f"a{i}", 3)).status == protocol.QUEUED
        assert fe.submit("B", cost_df("b0", "b0", 3)).status == protocol.QUEUED
        out = fe.remove("C", "block")
        admitted = [a["name"] for a in out["admitted"]]
        assert admitted == ["a0", "b0", "a1"]

    def test_weights_scale_the_share(self):
        fe = frontend(
            slots=12,
            default_quota=TenantQuota(max_slots=12, max_pending=8),
            quotas={"B": TenantQuota(max_slots=12, max_pending=8, weight=3.0)},
        )
        fe.submit("C", cost_df("block", "c", 12))
        for i in range(3):
            fe.submit("A", cost_df(f"a{i}", f"xa{i}", 3))
        for i in range(3):
            fe.submit("B", cost_df(f"b{i}", f"xb{i}", 3))
        out = fe.remove("C", "block")
        admitted = [a["name"] for a in out["admitted"]]
        # B accrues vtime 3× slower (1 per admission vs A's 3), so of the
        # four admissions that fit, B wins three: only at the initial 0–0
        # tie does arrival order hand A its slot.
        assert admitted == ["a0", "b0", "b1", "b2"]

    def test_small_queued_flow_can_fill_gap_head_cannot(self):
        fe = frontend(slots=8, default_quota=TenantQuota(max_slots=8, max_pending=4))
        fe.submit("t1", cost_df("hold", "h", 5))  # 3 free
        assert fe.submit("t2", cost_df("wide", "w", 4)).status == protocol.QUEUED
        r = fe.submit("t3", cost_df("slim", "s", 3))
        # t3 fits the 3-slot gap even though t2's head-of-line does not.
        assert r.status == protocol.ADMITTED


# -- per-tenant billing ----------------------------------------------------------


class TestBilling:
    def test_shared_tasks_split_evenly(self):
        fe = frontend()
        A = fig1()[0]
        fe.submit("t1", A)
        fe.submit("t2", A.copy("A2"))
        fe.step(5)
        s = fe.stats()
        c1 = s["ledgers"]["t1"]["cost_total"]
        c2 = s["ledgers"]["t2"]["cost_total"]
        assert c1 > 0
        assert c1 == pytest.approx(c2)

    def test_bill_sums_to_step_cost(self):
        fe = frontend()
        A, B, _, _ = fig1()
        fe.submit("t1", A)
        fe.submit("t2", B)
        reports = [fe.step()["cost"] for _ in range(3)]
        s = fe.stats()
        billed = sum(l["cost_total"] for l in s["ledgers"].values())
        assert billed == pytest.approx(sum(reports), rel=1e-6)


# -- wire protocol ---------------------------------------------------------------


class TestWireProtocol:
    def test_two_tenant_socket_session(self, tmp_path):
        fe = frontend(slots=32)
        host, port = fe.start()
        try:
            A, B, _, _ = fig1()
            with ServeClient((host, port)) as alice, ServeClient((host, port)) as bob:
                ra = alice.submit("alice", A)
                rb = bob.submit("bob", B)
                assert ra["status"] == protocol.ADMITTED
                assert rb["status"] == protocol.ADMITTED
                assert rb["slots_charged"] < len(B.tasks)  # reused alice's prefix
                step = bob.step(3)
                assert step["steps"] == 3
                status = alice.status()
                assert status["dataflows"] == 2
                assert status["slots_used"] == ra["slots_charged"] + rb["slots_charged"]
                stats = alice.stats()
                assert stats["effective_capacity"] > 1.0
                assert stats["ledgers"]["bob"]["slots_saved"] > 0
                assert alice.remove("alice", "A")["ok"]
                drained = bob.drain()
                assert drained["ok"]
                assert bob.submit("bob", cost_df("late", "z", 3))["status"] == protocol.REJECTED
        finally:
            fe.close()

    def test_errors_cross_the_wire_as_exceptions(self):
        fe = frontend()
        host, port = fe.start()
        try:
            with ServeClient((host, port)) as c:
                with pytest.raises(protocol.ServeProtocolError, match="not admitted"):
                    c.remove("t1", "ghost")
                # the connection survives an error response
                assert c.ping()
        finally:
            fe.close()

    def test_shutdown_verb_stops_server(self):
        fe = frontend()
        host, port = fe.start()
        try:
            with ServeClient((host, port)) as c:
                assert c.shutdown(checkpoint=False)["ok"]
            deadline = time.monotonic() + 5.0
            while fe._sock is not None and time.monotonic() < deadline:
                time.sleep(0.02)
            assert fe._sock is None
        finally:
            fe.close()

    def test_restart_rebinds_same_port_immediately(self):
        fe1 = frontend()
        host, port = fe1.start()
        # A client that connects and silently dies must not block restart.
        stale = socket.create_connection((host, port))
        fe1.close()
        fe2 = frontend(host=host, port=port)
        h2, p2 = fe2.start()
        try:
            assert (h2, p2) == (host, port)
            with ServeClient.wait_ready((h2, p2), timeout=5.0) as c:
                assert c.ping()
        finally:
            stale.close()
            fe2.close()


# -- client-side backpressure handling -------------------------------------------


class TestClientBackoff:
    def test_wait_rides_out_backpressure_until_admitted(self):
        fe = frontend(slots=6, retry_after=0.1,
                      default_quota=TenantQuota(max_slots=6, max_pending=0))
        host, port = fe.start()
        try:
            with ServeClient((host, port)) as c:
                r = c.submit("t1", cost_df("block", "a", 6))
                assert r["status"] == protocol.ADMITTED

            def free_capacity():
                time.sleep(0.4)
                with ServeClient((host, port)) as c2:
                    c2.remove("t1", "block")

            t = threading.Thread(target=free_capacity)
            t.start()
            with ServeClient((host, port)) as c3:
                r = c3.submit("t2", cost_df("want", "b", 6),
                              wait=True, max_wait=20.0)
            t.join()
            assert r["status"] == protocol.ADMITTED  # never RETRY_AFTER
        finally:
            fe.close()

    def test_wait_timeout_raises_typed_error_with_last_response(self):
        fe = frontend(slots=6, retry_after=0.05,
                      default_quota=TenantQuota(max_slots=6, max_pending=0))
        host, port = fe.start()
        try:
            with ServeClient((host, port)) as c:
                assert c.submit("t1", cost_df("block", "a", 6))["status"] == protocol.ADMITTED
                t0 = time.monotonic()
                with pytest.raises(SubmitTimeout) as ei:
                    c.submit("t2", cost_df("late", "b", 6),
                             wait=True, max_wait=0.4)
                elapsed = time.monotonic() - t0
            assert elapsed < 5.0  # bounded: no hang past the deadline
            assert ei.value.tenant == "t2"
            assert ei.value.last.get("status") == protocol.RETRY_AFTER
        finally:
            fe.close()


# -- tcp broker shutdown hygiene (regression) ------------------------------------


class TestTcpBrokerHygiene:
    def test_killed_client_cannot_strand_handler(self):
        server = TcpBrokerServer(conn_timeout=0.2)
        host, port = server.address
        # Stall mid-message: send half a header, then nothing. The
        # conn_timeout must turn this into a dropped connection, not a
        # stuck thread.
        stalled = socket.create_connection((host, port))
        stalled.sendall(b"\x00\x00")
        time.sleep(0.6)
        with server._conns_lock:
            assert not server._conns
        # Healthy clients still work after the stale one was reaped.
        t = TcpTransport(address=(host, port))
        t.publish("topic", np.arange(3, dtype=np.float32))
        assert t.seq("topic") == 1
        t.close()
        stalled.close()
        server.close()

    def test_restart_rebinds_port_with_live_clients_attached(self):
        server = TcpBrokerServer(conn_timeout=0.2)
        host, port = server.address
        lingering = socket.create_connection((host, port))
        server.close()
        # Rebinding the same port must succeed immediately (SO_REUSEADDR +
        # close() closing tracked conns), not raise EADDRINUSE.
        server2 = TcpBrokerServer(host=host, port=port, conn_timeout=0.2)
        assert server2.address[1] == port
        t = TcpTransport(address=(host, port))
        t.publish("topic", np.ones(2, dtype=np.float32))
        assert t.seq("topic") == 1
        t.close()
        lingering.close()
        server2.close()


# -- durability ------------------------------------------------------------------


class TestDurability:
    def _drive(self, fe: ServeFrontend, steps: int = 4) -> None:
        A, B, C, D = fig1()
        fe.submit("alice", A)
        fe.submit("bob", B)
        fe.submit("bob", D)
        fe.step(steps)
        fe.remove("bob", "D")
        fe.submit("alice", C)
        fe.step(steps)

    def test_restore_preserves_ledgers_and_sink_counts(self, ckpt_dir):
        fe = frontend(checkpoint_dir=ckpt_dir)
        self._drive(fe)
        want = fe.stats()
        fe.checkpoint()
        fe.close()
        del fe  # "kill"

        restored = ServeFrontend.restore(ckpt_dir)
        got = restored.stats()
        assert got["ledgers"] == want["ledgers"]
        assert got["slots_used"] == want["slots_used"]
        assert got["naive_slots"] == want["naive_slots"]
        assert got["effective_capacity"] == pytest.approx(want["effective_capacity"])

        # Sink trajectories must continue exactly as an uninterrupted run.
        uninterrupted = frontend()
        self._drive(uninterrupted)
        for fe2 in (restored, uninterrupted):
            fe2.step(3)
        for name in ("A", "B", "C"):
            assert restored.session.sink_digests(name) == uninterrupted.session.sink_digests(name)
        restored.close()
        uninterrupted.close()

    def test_restored_frontend_keeps_admitting_with_reuse(self, ckpt_dir):
        fe = frontend(checkpoint_dir=ckpt_dir)
        A = fig1()[0]
        fe.submit("alice", A)
        fe.checkpoint()
        fe.close()
        restored = ServeFrontend.restore(ckpt_dir)
        r = restored.submit("bob", A.copy("A2"))
        assert r.status == protocol.ADMITTED
        assert r.slots_charged == 0  # reuse across the restart boundary
        restored.close()

    def test_ledger_sidecar_is_valid_json(self, ckpt_dir):
        fe = frontend(checkpoint_dir=ckpt_dir)
        fe.submit("t1", fig1()[0])
        fe.checkpoint()
        fe.close()
        with open(os.path.join(ckpt_dir, "frontend-ledger.json")) as fh:
            payload = json.load(fh)
        assert payload["version"] == 2
        assert "t1" in payload["ledgers"]
        assert payload["pending"] == []  # v2: the QUEUED queue is durable

    def test_queued_submissions_survive_restart(self, ckpt_dir):
        fe = frontend(slots=6, checkpoint_dir=ckpt_dir,
                      default_quota=TenantQuota(max_slots=6, max_pending=4))
        assert fe.submit("t1", cost_df("block", "a", 6)).status == protocol.ADMITTED
        assert fe.submit("t2", cost_df("next", "b", 4)).status == protocol.QUEUED
        fe.checkpoint()
        fe.close()
        restored = ServeFrontend.restore(ckpt_dir)
        try:
            # still queued (nothing freed), not silently dropped
            assert [p.df.name for p in restored._pending] == ["next"]
            out = restored.remove("t1", "block")
            assert [a["name"] for a in out["admitted"]] == ["next"]
            assert restored.tenant_of["next"] == "t2"
        finally:
            restored.close()

    def test_version1_sidecar_without_pending_is_tolerated(self, ckpt_dir):
        fe = frontend(checkpoint_dir=ckpt_dir)
        fe.submit("t1", fig1()[0])
        fe.checkpoint()
        fe.close()
        sidecar = os.path.join(ckpt_dir, "frontend-ledger.json")
        with open(sidecar) as fh:
            payload = json.load(fh)
        payload.pop("pending")
        payload.pop("pending_seq")
        payload["version"] = 1
        with open(sidecar, "w") as fh:
            json.dump(payload, fh)
        restored = ServeFrontend.restore(ckpt_dir)
        try:
            assert restored._pending == []
            assert restored.submit("t2", fig1()[1]).status == protocol.ADMITTED
        finally:
            restored.close()


# -- tenant workload -------------------------------------------------------------


class TestTenantTrace:
    def test_trace_is_deterministic(self):
        pool = opmw_workload()
        a = list(tenant_trace(pool, ("x", "y"), events=500, seed=3))
        b = list(tenant_trace(pool, ("x", "y"), events=500, seed=3))
        assert a == b
        assert any(e.op == "remove" for e in a)

    def test_trace_names_are_tenant_namespaced_and_consistent(self):
        pool = opmw_workload()
        present: dict = {}
        for ev in tenant_trace(pool, ("x", "y"), events=800, seed=5):
            assert ev.name == f"{ev.tenant}/{ev.pool_name}"
            key = (ev.tenant, ev.name)
            if ev.op == "add":
                assert key not in present
                present[key] = True
            else:
                assert present.pop(key)

    def test_weights_skew_the_draw(self):
        pool = opmw_workload()
        events = list(
            tenant_trace(pool, ("heavy", "light"), events=4000,
                         weights={"heavy": 4.0, "light": 1.0}, seed=9)
        )
        heavy = sum(1 for e in events if e.tenant == "heavy")
        assert heavy / len(events) == pytest.approx(0.8, abs=0.05)

    def test_tenant_copy_keeps_graph_renames_flow(self):
        df = fig1()[0]
        c = tenant_copy(df, "alice")
        assert c.name == "alice/A"
        assert set(c.tasks) == set(df.tasks)
        assert c.streams == df.streams


# -- end-to-end over the trace ---------------------------------------------------


class TestServingCapacity:
    def test_reuse_admits_strictly_more_than_no_reuse(self):
        pool = opmw_workload()
        by_name = {d.name: d for d in pool}
        admitted = {}
        for strategy in ("signature", "none"):
            fe = ServeFrontend(
                slots=64,
                strategy=strategy,
                backend="dryrun",
                default_quota=TenantQuota(max_slots=64, max_pending=4),
                defrag_every=32,
            )
            for ev in tenant_trace(pool, ("a", "b"), events=600, seed=11):
                if ev.op == "add":
                    fe.submit(ev.tenant, tenant_copy(by_name[ev.pool_name], ev.tenant))
                elif ev.name in fe.tenant_of or any(
                    p.df.name == ev.name for p in fe._pending
                ):
                    fe.remove(ev.tenant, ev.name)
            admitted[strategy] = sum(
                l.admitted for l in fe.ledgers.values()
            )
            fe.close()
        assert admitted["signature"] > admitted["none"]
