import os
import re
import shutil
import sys

import pytest

# Make `repro` importable without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep the default 1-device view for smoke tests and benches. The multi-pod
# dry-run (launch/dryrun.py) sets XLA_FLAGS itself in a fresh process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture
def ckpt_dir(tmp_path, request):
    """Checkpoint directory for the recovery tests.

    Defaults to a per-test tmp dir. With ``REPRO_CKPT_ARTIFACT_DIR`` set
    (CI does this), checkpoints land under that root keyed by test id, so
    a failing run's checkpoint files can be uploaded as a CI artifact for
    post-mortem restore."""
    base = os.environ.get("REPRO_CKPT_ARTIFACT_DIR")
    if not base:
        return str(tmp_path / "ckpts")
    safe = re.sub(r"[^A-Za-z0-9_.-]+", "_", request.node.nodeid)[-120:]
    path = os.path.join(base, safe)
    # Hermetic per run: drop checkpoints left by a previous invocation (CI
    # runs the recovery slice and then the full fast sweep against the same
    # root) while keeping this run's files around for post-failure upload.
    if os.path.isdir(path):
        shutil.rmtree(path)
    os.makedirs(path)
    return path
