import os
import sys

# Make `repro` importable without installation (PYTHONPATH=src also works).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep the default 1-device view for smoke tests and benches. The multi-pod
# dry-run (launch/dryrun.py) sets XLA_FLAGS itself in a fresh process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
