"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one
forward + one train step on CPU, asserting output shapes and no NaNs;
prefill/decode consistency is asserted against teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.train import AdamWConfig, make_train_step, train_state_init

ARCHS = list(configs.ARCHS)


def _memory(cfg, B, key=2):
    if cfg.family == "vlm":
        return jax.random.normal(jax.random.PRNGKey(key), (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        return jax.random.normal(jax.random.PRNGKey(key), (B, cfg.encoder_seq, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits = forward(params, cfg, tokens, memory=_memory(cfg, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt, accum=2))
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
    }
    mem = _memory(cfg, B)
    if mem is not None:
        batch["memory"] = mem.astype(jnp.dtype(cfg.dtype))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_prefill_decode_consistency(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    mem = _memory(cfg, B)
    mem_len = mem.shape[1] if mem is not None else 0
    full = forward(params, cfg, tokens, memory=mem)
    cache = init_cache(cfg, B, S + 4, memory_len=mem_len)
    plogits, cache = prefill(params, cfg, tokens, cache, memory=mem)
    np.testing.assert_allclose(
        np.asarray(plogits), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2
    )
    # decode continues without NaNs and changes with the token fed
    tok = jnp.argmax(plogits, -1)[:, None].astype(jnp.int32)
    dlogits, cache = decode_step(params, cfg, tok, cache)
    assert not bool(jnp.isnan(dlogits).any())
    assert int(cache["len"]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact(arch):
    """The full (non-smoke) config matches the assigned sizes exactly."""
    cfg = configs.get_config(arch)
    assigned = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[cfg.name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == assigned, (cfg.name, got, assigned)


def test_arch_extras():
    """Family-specific details named in the assignment."""
    dv2 = configs.get_config("deepseek-v2-236b")
    assert dv2.mla.kv_lora_rank == 512 and dv2.moe.num_experts == 160
    assert dv2.moe.top_k == 6 and dv2.moe.num_shared == 2
    mx = configs.get_config("mixtral-8x22b")
    assert mx.moe.num_experts == 8 and mx.moe.top_k == 2 and mx.swa_window > 0
    q3 = configs.get_config("qwen3-4b")
    assert q3.qk_norm
    q15 = configs.get_config("qwen1.5-110b")
    assert q15.qkv_bias
    z2 = configs.get_config("zamba2-2.7b")
    assert z2.ssm.d_state == 64 and z2.shared_attn_every > 0
    sm = configs.get_config("seamless-m4t-medium")
    assert sm.is_enc_dec
