"""Unit tests for the merge (§4.1) and unmerge (§4.2) algorithms through the
ReuseManager, for both equivalence strategies."""
import pytest

from repro.core import Dataflow, DataflowError, ReuseManager, Task
from helpers import chain_df, diamond_df, fig1, two_source_df

STRATEGIES = ("faithful", "signature")


@pytest.fixture(params=STRATEGIES)
def mgr(request):
    return ReuseManager(strategy=request.param, check_invariants=True)


def test_fig1_merge_counts(mgr):
    A, B, C, D = fig1()
    assert mgr.submit(A).num_created == 4
    rB = mgr.submit(B)
    assert (rB.num_reused, rB.num_created) == (3, 2)
    rC = mgr.submit(C)
    assert (rC.num_reused, rC.num_created) == (4, 2)
    rD = mgr.submit(D)
    assert (rD.num_reused, rD.num_created) == (0, 4)
    assert mgr.running_task_count == 12
    assert mgr.submitted_task_count == 19
    # A, B, C share one running DAG; D runs alone.
    assert len(mgr.running) == 2
    assert mgr.phi["A"] == mgr.phi["B"] == mgr.phi["C"]
    assert mgr.phi["D"] != mgr.phi["A"]


def test_full_containment_creates_nothing(mgr):
    A, B, C, D = fig1()
    mgr.submit(C)  # C contains B's and A's prefixes
    rA = mgr.submit(A)
    assert rA.num_created == 1  # only A's sink is new
    assert rA.num_reused == 3


def test_sink_map_points_to_running_tasks(mgr):
    A, B, _, _ = fig1()
    mgr.submit(A)
    r = mgr.submit(B)
    run_df = mgr.running[r.running_dag]
    for sink_id, run_id in r.sink_map.items():
        assert run_id in run_df.tasks
        assert run_df.tasks[run_id].is_sink


def test_merge_joins_two_running_dags(mgr):
    """A submitted DAG with two sources merges two disjoint running DAGs."""
    A = chain_df("A", "urban", [("parse", {})], "sa")
    B = chain_df("B", "meter", [("parse", {})], "sb")
    mgr.submit(A)
    mgr.submit(B)
    assert len(mgr.running) == 2
    ts = two_source_df("TS")
    r = mgr.submit(ts)
    assert len(mgr.running) == 1  # merged into one running DAG
    assert r.num_reused == 4  # both sources + both parses
    assert mgr.phi["A"] == mgr.phi["B"] == mgr.phi["TS"]


def test_unmerge_splits_running_dag(mgr):
    A = chain_df("A", "urban", [("parse", {})], "sa")
    B = chain_df("B", "meter", [("parse", {})], "sb")
    mgr.submit(A)
    mgr.submit(B)
    ts = two_source_df("TS")
    mgr.submit(ts)
    assert len(mgr.running) == 1
    r = mgr.remove("TS")
    # The join+sink die; the running DAG splits back into two components.
    assert len(mgr.running) == 2
    assert len(r.terminated_tasks) == 2
    assert mgr.phi["A"] != mgr.phi["B"]
    assert mgr.running_task_count == 6


def test_remove_keeps_shared_prefix(mgr):
    A, B, C, D = fig1()
    for df in (A, B, C, D):
        mgr.submit(df)
    r = mgr.remove("B")
    # win task survives (C needs it); only B's sink dies.
    assert len(r.terminated_tasks) == 1
    assert mgr.running_task_count == 11
    r = mgr.remove("C")
    # C's sink + avg + win die now.
    assert len(r.terminated_tasks) == 3
    assert mgr.running_task_count == 8


def test_remove_in_any_order_drains_to_zero(mgr):
    import itertools

    for order in itertools.permutations("ABCD"):
        m = ReuseManager(strategy=mgr.strategy, check_invariants=True)
        dfs = dict(zip("ABCD", fig1()))
        for name in "ABCD":
            m.submit(dfs[name])
        for name in order:
            m.remove(name)
        assert m.running_task_count == 0
        assert not m.running and not m.submitted


def test_resubmission_after_removal_reuses(mgr):
    A, B, _, _ = fig1()
    mgr.submit(A)
    mgr.submit(B)
    mgr.remove("B")
    B2 = chain_df(
        "B2", "urban", [("parse", {}), ("kalman", {"q": 0.1}), ("win", {"w": 10})], "store_b"
    )
    r = mgr.submit(B2)
    assert r.num_reused == 3  # prefix still running under A... plus nothing else
    assert r.num_created == 2


def test_duplicate_submit_rejected(mgr):
    A, *_ = fig1()
    mgr.submit(A)
    with pytest.raises(DataflowError):
        mgr.submit(chain_df("A", "urban", [("x", {})]))


def test_non_dedup_submission_rejected(mgr):
    d = Dataflow("dup")
    d.add_task(Task.make("s", "urban", "SOURCE"))
    d.add_task(Task.make("p1", "parse", {}))
    d.add_task(Task.make("p2", "parse", {}))
    d.add_task(Task.make("k1", "store", "SINK"))
    d.add_task(Task.make("k2", "store", "SINK"))
    d.add_stream("s", "p1")
    d.add_stream("s", "p2")
    d.add_stream("p1", "k1")
    d.add_stream("p2", "k2")
    with pytest.raises(DataflowError):
        mgr.submit(d)


def test_non_sink_leaf_rejected(mgr):
    d = Dataflow("leaf")
    d.add_task(Task.make("s", "urban", "SOURCE"))
    d.add_task(Task.make("p", "parse", {}))
    d.add_stream("s", "p")
    with pytest.raises(DataflowError):
        mgr.submit(d)


def test_default_strategy_never_reuses():
    mgr = ReuseManager(strategy="none", check_invariants=False)
    A, B, C, D = fig1()
    for df in (A, B, C, D):
        assert mgr.submit(df).num_reused == 0
    assert mgr.running_task_count == mgr.submitted_task_count == 19
    mgr.remove("B")
    # B has 5 tasks (src, parse, kalman, win, sink): 19 - 5 = 14.
    assert mgr.running_task_count == 14


def test_reuse_counts_fig1():
    mgr = ReuseManager(strategy="signature")
    A, B, C, D = fig1()
    for df in (A, B, C, D):
        mgr.submit(df)
    counts = mgr.reuse_counts()
    by_reuse = sorted(counts.values(), reverse=True)
    # src, parse, kalman used by A+B+C = 3; win by B+C = 2; rest 1.
    assert by_reuse[:4] == [3, 3, 3, 2]
    assert all(c >= 1 for c in counts.values())


def test_strategies_agree_on_plans():
    """Faithful and signature strategies must produce identical structure."""
    results = {}
    for strategy in STRATEGIES:
        m = ReuseManager(strategy=strategy, check_invariants=True)
        dfs = [*fig1(), diamond_df("dia"), two_source_df("ts")]
        recs = [m.submit(df) for df in dfs]
        m.remove("B")
        m.remove("dia")
        results[strategy] = (
            [(r.num_reused, r.num_created) for r in recs],
            m.running_task_count,
            sorted(len(df.tasks) for df in m.running.values()),
        )
    assert results["faithful"] == results["signature"]


def test_journal_replay_reconstructs_state():
    mgr = ReuseManager(strategy="signature")
    for df in fig1():
        mgr.submit(df)
    mgr.remove("B")
    clone = ReuseManager.replay(mgr.journal)
    assert clone.running_task_count == mgr.running_task_count
    assert set(clone.submitted) == set(mgr.submitted)
    assert sorted(len(d.tasks) for d in clone.running.values()) == sorted(
        len(d.tasks) for d in mgr.running.values()
    )
    clone.verify()


def test_journal_file_restore(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    mgr = ReuseManager(strategy="signature", journal_path=path)
    for df in fig1():
        mgr.submit(df)
    mgr.remove("C")
    restored = ReuseManager.restore(path)
    restored.verify()
    assert restored.running_task_count == mgr.running_task_count
