"""Training substrate: loss decreases, AdamW semantics, schedules,
grad-accum equivalence, checkpoint save/restore (incl. async + resume)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import TokenStream
from repro.train import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    cross_entropy_loss,
    make_train_step,
    train_state_init,
)
from repro.train import checkpoint as ckpt


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -1, -1]])
    loss, n = cross_entropy_loss(logits, labels)
    assert int(n) == 2
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_cosine_schedule_shape():
    opt = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lr = cosine_schedule(opt)
    # warmup from step 1 so the first update is non-zero
    np.testing.assert_allclose(float(lr(jnp.asarray(0))), 0.1, rtol=1e-5)
    np.testing.assert_allclose(float(lr(jnp.asarray(9))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert 0.09 < float(lr(jnp.asarray(100))) < 0.11


def test_adamw_moves_towards_gradient():
    opt = AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=10, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    mu, nu = adamw_init(params, opt)
    p2, _, _, gnorm = adamw_update(grads, params, mu, nu, jnp.asarray(0), opt)
    assert float(gnorm) == pytest.approx(2.0)
    assert np.all(np.asarray(p2["w"]) < 1.0)


@pytest.mark.slow
def test_loss_decreases_small_model():
    cfg = configs.get_smoke_config("qwen3-4b")
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=1)
    losses = []
    for i in range(30):
        b = stream.batch(i % 4)  # few batches → memorizable
        state, m = step(state, {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.slow
def test_grad_accum_equivalence():
    cfg = configs.get_smoke_config("granite_20b")
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=0.0)
    s1 = make_train_step(cfg, opt, accum=1)
    s4 = make_train_step(cfg, opt, accum=4)
    state_a = train_state_init(cfg, opt, jax.random.PRNGKey(3))
    state_b = jax.tree.map(lambda x: x, state_a)
    stream = TokenStream(cfg.vocab_size, 16, 8, seed=2)
    b = stream.batch(0)
    batch = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
    a2, ma = jax.jit(s1)(state_a, batch)
    b2, mb = jax.jit(s4)(state_b, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-5)
    for la, lb in zip(jax.tree.leaves(a2["params"]), jax.tree.leaves(b2["params"])):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_smoke_config("xlstm_1_3b")
    opt = AdamWConfig()
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    assert ckpt.latest_step(d) == 7
    target = jax.eval_shape(lambda: train_state_init(cfg, opt, jax.random.PRNGKey(0)))
    restored = ckpt.restore(d, target=target)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    state = {"w": jnp.arange(8.0), "step": jnp.asarray(1)}
    saver = ckpt.AsyncCheckpointer(d)
    for s in (1, 2, 3, 4, 5):
        state["step"] = jnp.asarray(s)
        saver.save_async(s, state)
    saver.wait()
    assert ckpt.latest_step(d) == 5
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) <= 3  # gc keeps 3


def test_checkpoint_resume_exact(tmp_path):
    """Crash-resume: training N steps straight == train k, restore, train N−k."""
    cfg = configs.get_smoke_config("granite_20b")
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=20)
    step = jax.jit(make_train_step(cfg, opt))
    stream = TokenStream(cfg.vocab_size, 16, 4, seed=5)

    def batch(i):
        b = stream.batch(i)
        return {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}

    ref = train_state_init(cfg, opt, jax.random.PRNGKey(1))
    for i in range(6):
        ref, _ = step(ref, batch(i))

    d = str(tmp_path / "ck")
    st = train_state_init(cfg, opt, jax.random.PRNGKey(1))
    for i in range(3):
        st, _ = step(st, batch(i))
    ckpt.save(d, 3, st)
    target = jax.eval_shape(lambda: train_state_init(cfg, opt, jax.random.PRNGKey(1)))
    st = jax.tree.map(jnp.asarray, ckpt.restore(d, target=target))
    for i in range(int(st["step"]), 6):
        st, _ = step(st, batch(i))
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(st["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6)


def test_token_stream_determinism_and_sharding():
    g = TokenStream(1000, 32, 8, seed=9)
    h0 = TokenStream(1000, 32, 8, seed=9, host_id=0, num_hosts=2)
    h1 = TokenStream(1000, 32, 8, seed=9, host_id=1, num_hosts=2)
    full = g.batch(5)["tokens"]
    np.testing.assert_array_equal(full[:4], h0.batch(5)["tokens"])
    np.testing.assert_array_equal(full[4:], h1.batch(5)["tokens"])
    np.testing.assert_array_equal(full, g.batch(5)["tokens"])  # pure fn of index


def test_checkpoint_restore_with_mesh_resharding(tmp_path):
    """Elastic restore: checkpoint with specs, restore onto a live mesh
    (the 512→256 pod-loss path; here a 1×1 mesh stands in — the spec
    resolution/axis-dropping logic is what is under test)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    state = {"w": jnp.arange(32.0).reshape(4, 8), "step": jnp.asarray(3)}
    specs = {"w": P("data", "model"), "step": P()}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state, specs=specs)
    mesh = make_host_mesh()
    restored = ckpt.restore(d, mesh=mesh, target=jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding.mesh.shape["data"] == 1  # resharded onto live mesh


def test_checkpoint_restore_drops_missing_axes(tmp_path):
    """A checkpoint taken on a ('pod','data','model') mesh restores onto a
    mesh without 'pod' — the spec axis is dropped, not an error."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    state = {"w": jnp.ones((8, 4))}
    specs = {"w": P(("pod", "data"), "model")}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, state, specs=specs)
    restored = ckpt.restore(d, mesh=make_host_mesh(), target=jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
