"""Per-kernel allclose vs. the pure-jnp oracle (ref.py), executing the
Pallas kernel bodies in interpret mode on CPU. Shapes & dtypes swept."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_residual
from repro.kernels.ssd import ssd_scan


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,hd,causal,window",
    [
        (1, 128, 128, 4, 4, 64, True, 0),
        (2, 64, 64, 4, 2, 32, True, 0),      # GQA
        (1, 96, 96, 2, 1, 64, True, 0),       # MQA, ragged seq vs block
        (1, 128, 128, 2, 2, 64, False, 0),    # bidirectional (encoder)
        (1, 256, 256, 2, 2, 64, True, 64),    # sliding window
        (2, 33, 77, 2, 2, 16, False, 0),      # cross-attn-like, unaligned
    ],
)
def test_flash_attention(b, sq, sk, h, kv, hd, causal, window, dtype):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned q/k")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, sq, h, hd), dtype)
    k = _rand(ks[1], (b, sk, kv, hd), dtype)
    v = _rand(ks[2], (b, sk, kv, hd), dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    groups = h // kv
    kr = jnp.repeat(k, groups, axis=2) if groups > 1 else k
    vr = jnp.repeat(v, groups, axis=2) if groups > 1 else v
    got = flash_attention(
        q, kr, vr, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# --------------------------------------------------------------- decode attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,smax,clen,h,kv,hd,window",
    [
        (2, 128, 100, 4, 4, 64, 0),
        (2, 128, 128, 4, 2, 64, 0),    # GQA
        (1, 256, 200, 8, 1, 32, 0),    # MQA
        (1, 256, 250, 4, 2, 64, 64),   # sliding window
        (3, 96, 1, 2, 2, 16, 0),       # first decode step
    ],
)
def test_decode_attention(b, smax, clen, h, kv, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (b, 1, h, hd), dtype)
    kc = _rand(ks[1], (b, smax, kv, hd), dtype)
    vc = _rand(ks[2], (b, smax, kv, hd), dtype)
    cl = jnp.asarray(clen, jnp.int32)
    want = ref.decode_attention_ref(q, kc, vc, cl, window=window)
    got = decode_attention(q, kc, vc, cl, window=window, block_s=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# --------------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (1, 33, 512)])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = _rand(ks[0], shape, dtype)
    scale = 1.0 + 0.1 * _rand(ks[1], shape[-1:], jnp.float32)
    want = ref.rmsnorm_ref(x, scale)
    got = rmsnorm(x, scale, block_rows=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual(dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = _rand(ks[0], (2, 17, 256), dtype)
    r = _rand(ks[1], (2, 17, 256), dtype)
    scale = 1.0 + 0.1 * _rand(ks[2], (256,), jnp.float32)
    want_n, want_a = ref.rmsnorm_residual_ref(x, r, scale)
    got_n, got_a = rmsnorm_residual(x, r, scale, block_rows=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_a, np.float32), np.asarray(want_a, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(got_n, np.float32), np.asarray(want_n, np.float32), **_tol(dtype)
    )


# ------------------------------------------------------------------------- ssd

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nh,p,n,chunk",
    [
        (1, 64, 2, 32, 16, 16),
        (2, 128, 4, 64, 64, 32),
        (1, 256, 2, 64, 128, 128),
    ],
)
def test_ssd_scan(b, s, nh, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    xh = _rand(ks[0], (b, s, nh, p), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (b, s, nh), jnp.float32))
    a = -jnp.exp(0.5 * _rand(ks[2], (nh,), jnp.float32))
    B_ssm = _rand(ks[3], (b, s, n), dtype)
    C_ssm = _rand(jax.random.PRNGKey(5), (b, s, n), dtype)
    want_y, want_h = ref.ssd_scan_ref(xh, dt, a, B_ssm, C_ssm, chunk=chunk)
    got_y, got_h = ssd_scan(xh, dt, a, B_ssm, C_ssm, chunk=chunk, interpret=True)
    # bf16: chunked kernel and sequential reference accumulate in different
    # orders; over s=256 steps the worst-case drift exceeds 5e-2 on a few
    # elements (observed 2/32768 at 0.09), so the absolute floor is 1e-1.
    tol = dict(rtol=5e-2, atol=1e-1) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), **tol)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), **tol)
