"""End-to-end system behaviour tests.

Real integration tests for the data plane / serving / training live in
test_runtime.py, test_serving.py and test_train.py; this file covers the
manager-level end-to-end scenario from the paper's Fig. 1.
"""
from repro.core import ReuseManager

from helpers import fig1


def test_manager_end_to_end_fig1():
    """Fig. 1 scenario: A+B+C merge to one running DAG, D alone; drain to 0."""
    mgr = ReuseManager(strategy="signature", check_invariants=True)
    A, B, C, D = fig1()
    for df in (A, B, C, D):
        mgr.submit(df)
    assert len(mgr.running) == 2
    assert mgr.running_task_count == 12
    for name in ("B", "A", "D", "C"):
        mgr.remove(name)
    assert mgr.running_task_count == 0
