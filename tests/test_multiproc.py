"""Multiprocess worker backend tests.

Five layers:
  * coordinator plumbing: registry, constructor validation, the
    inproc-transport rejection, worker log files, worker-error surfacing;
  * dry worker plane (no jit compiles — scheduler/transport machinery at
    full speed): fig-1 churn conformance against the dry-run backend's
    counters, checkpoint/restore, sticky worker re-placement;
  * jit worker plane: sink digests (counts AND checksums) identical to
    the in-process jit backend on fig-1 churn in both step modes, plus
    checkpoint/restore continuity and cross-backend restores;
  * straggler migration across workers through the shared placement
    machinery;
  * the acceptance bar (slow tier): ``backend="multiproc"`` with
    ``transport="shm"`` is sink-count-identical to ``inprocess`` on the
    full OPMW rw1 trace — live, mid-step churn, and across a
    checkpoint/restore boundary — in both step modes.

The CI multiproc-conformance job re-runs this module with
``REPRO_TEST_STEP_MODE`` sync and concurrent (workers=2); results must be
mode-invariant, and worker logs are uploaded as artifacts on failure.
"""
from __future__ import annotations

import os

import pytest

from repro.runtime.backend import available_backends, resolve_backend
from repro.runtime.system import StreamSystem
from repro.runtime.transport import TransportError
from repro.runtime.worker import MultiprocBackend, RemoteSegment, WorkerError

from helpers import chain_df, fig1

STEP_MODE = os.environ.get("REPRO_TEST_STEP_MODE") or "sync"
MAX_WORKERS = int(os.environ.get("REPRO_TEST_MAX_WORKERS", "4"))

FIG1_OPS = [
    ("add", "A"),
    ("add", "B"),
    ("add", "C"),
    ("add", "D"),
    ("remove", "B"),
    ("defrag", ""),
    ("remove", "A"),
    ("add", "B"),
]


def _apply(system, dags, op, name):
    if op == "add":
        system.submit(dags[name].copy())
    elif op == "remove":
        system.remove(name)
    else:
        system.defragment()


def _counts(system):
    return {
        name: {s: d["count"] for s, d in system.sink_digests(name).items()}
        for name in sorted(system.manager.submitted)
    }


def _digests(system):
    return {
        name: system.sink_digests(name) for name in sorted(system.manager.submitted)
    }


def _run_ops(backend, ops, step_mode=STEP_MODE, tail_steps=2, **kw):
    dags = {d.name: d for d in fig1()}
    system = StreamSystem(
        strategy="signature", backend=backend, step_mode=step_mode,
        max_workers=MAX_WORKERS, **kw,
    )
    for op, name in ops:
        _apply(system, dags, op, name)
        system.step()
    for _ in range(tail_steps):
        system.step()
    digests = _digests(system)
    system.close()
    return digests


class TestCoordinatorPlumbing:
    def test_registered(self):
        assert "multiproc" in available_backends()

    def test_constructor_validation(self):
        with pytest.raises(ValueError, match="workers"):
            MultiprocBackend(workers=0)
        with pytest.raises(ValueError, match="worker_plane"):
            MultiprocBackend(worker_plane="quantum")

    def test_inproc_transport_rejected(self):
        with pytest.raises(TransportError, match="cannot span"):
            MultiprocBackend(workers=1, transport="inproc")

    def test_system_knobs_reach_backend(self):
        system = StreamSystem(backend="multiproc", workers=1,
                              backend_options={"worker_plane": "dry"})
        try:
            assert isinstance(system.backend, MultiprocBackend)
            assert system.backend.n_workers == 1
            assert system.backend.worker_plane == "dry"
            assert system.backend.transport.name == "shm"
        finally:
            system.close()

    def test_worker_error_surfaces_with_log_path(self, tmp_path):
        be = MultiprocBackend(workers=1, worker_plane="dry",
                              log_dir=str(tmp_path))
        try:
            with pytest.raises(WorkerError, match="unknown worker op"):
                be._call(0, {"op": "frobnicate"})
            log = tmp_path / "worker-0.log"
            assert log.exists()
            assert "frobnicate" in log.read_text()
        finally:
            be.close()

    def test_close_shuts_workers_down(self):
        be = MultiprocBackend(workers=2, worker_plane="dry")
        be._ensure_workers()
        procs = list(be._procs)
        assert all(p.is_alive() for p in procs)
        be.close()
        assert all(not p.is_alive() for p in procs)
        be.close()  # idempotent


class TestDryWorkerPlane:
    def test_fig1_counts_match_dryrun_backend(self):
        be = MultiprocBackend(workers=2, worker_plane="dry")
        got = _run_ops(be, FIG1_OPS)
        ref = _run_ops("dryrun", FIG1_OPS)
        assert {n: {s: d["count"] for s, d in v.items()} for n, v in got.items()} == {
            n: {s: d["count"] for s, d in v.items()} for n, v in ref.items()
        }

    def test_segments_spread_across_workers(self):
        be = MultiprocBackend(workers=2, worker_plane="dry")
        system = StreamSystem(strategy="none", backend=be)
        for i in range(4):
            system.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
        system.step()
        assert set(be.device_of.values()) == {0, 1}
        assert isinstance(next(iter(be.segments.values())), RemoteSegment)
        system.close()

    def test_checkpoint_restore_with_sticky_worker_placement(self):
        be = MultiprocBackend(workers=2, worker_plane="dry",
                              placement="least_loaded")
        system = StreamSystem(strategy="none", backend=be)
        for i in range(4):
            system.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
        system.run(3)
        payload = system.checkpoint_payload()
        placed = dict(be.device_of)
        ref = _counts(system)
        system.close()
        assert payload["backend_config"] == {
            "workers": 2, "transport": "shm", "worker_plane": "dry",
            "placement": "least_loaded",
        }
        # sticky re-placement: same worker pool -> checkpointed pinning wins
        be2 = MultiprocBackend(workers=2, worker_plane="dry", placement="sticky")
        restored = StreamSystem.from_payload(payload, backend=be2)
        assert restored.backend.device_of == placed
        assert _counts(restored) == ref
        restored.run(2)
        restored.close()
        # pool mismatch -> sticky falls back (placement still total)
        be3 = MultiprocBackend(workers=3, worker_plane="dry", placement="sticky")
        restored3 = StreamSystem.from_payload(payload, backend=be3)
        assert set(restored3.backend.device_of) == set(placed)
        restored3.run(1)
        restored3.close()

    def test_tcp_transport_spans_workers(self):
        be = MultiprocBackend(workers=2, worker_plane="dry", transport="tcp")
        got = _run_ops(be, FIG1_OPS[:4], tail_steps=1)
        assert all(
            d["count"] > 0 for v in got.values() for d in v.values()
        )


class TestJitWorkerPlane:
    def test_fig1_digests_identical_to_inprocess(self):
        """Counts AND checksums: the jit plane in worker processes is
        bit-identical to the in-process jit plane across churn + defrag."""
        ref = _run_ops("inprocess", FIG1_OPS)
        got = _run_ops(resolve_backend("multiproc", workers=2), FIG1_OPS)
        assert got == ref

    @pytest.mark.slow
    def test_fig1_identical_in_both_modes(self):
        ref = _run_ops("inprocess", FIG1_OPS, step_mode="sync")
        for mode in ("sync", "concurrent"):
            got = _run_ops(
                resolve_backend("multiproc", workers=2), FIG1_OPS, step_mode=mode
            )
            assert got == ref, mode

    @pytest.mark.slow
    def test_checkpoint_restore_continuity_and_cross_backend(self, ckpt_dir):
        dags = {d.name: d for d in fig1()}
        system = StreamSystem(
            strategy="signature",
            backend=resolve_backend("multiproc", workers=2),
            step_mode=STEP_MODE, checkpoint_dir=ckpt_dir,
        )
        system.submit(dags["A"].copy())
        system.submit(dags["B"].copy())
        system.run(3)
        system.remove("B")
        system.step()
        path = system.checkpoint()
        ref = _counts(system)
        system.run(2)
        final = _counts(system)
        system.close()

        # multiproc -> multiproc (worker pool re-spawned from backend_config)
        r1 = StreamSystem.restore(path)
        assert isinstance(r1.backend, MultiprocBackend)
        assert r1.backend.n_workers == 2
        assert _counts(r1) == ref
        r1.run(2)
        assert _counts(r1) == final
        r1.close()

        # multiproc -> inprocess and inprocess -> multiproc
        r2 = StreamSystem.restore(path, backend="inprocess")
        assert _counts(r2) == ref
        r2.run(2)
        assert _counts(r2) == final
        p2 = r2.checkpoint_payload()
        r2.close()
        r3 = StreamSystem.from_payload(
            p2, backend=resolve_backend("multiproc", workers=2)
        )
        assert _counts(r3) == final
        r3.run(1)
        r3.close()


class TestStragglerMigrationAcrossWorkers:
    def test_injected_straggler_moves_to_other_worker(self):
        # chain batching ships one step_chain RPC per worker, so the
        # coordinator-side _step_one hook below would never run — pin the
        # per-segment dispatch path this injection idiom relies on (worker-
        # measured chain timings feed the same EWMAs in the batched path)
        be = MultiprocBackend(workers=2, worker_plane="dry",
                              placement="ewma_aware", straggler_factor=3.0,
                              chain_batching=False)
        system = StreamSystem(strategy="none", backend=be)
        for i in range(4):
            system.submit(chain_df(f"S{i}", "urban", [("kalman", {"q": float(i)})]))
        victim = sorted(be.device_of)[0]
        orig = type(be)._step_one

        def slowed(seg):
            orig(be, seg)
            return 200.0 if seg.spec.name == victim else 2.0

        be._step_one = slowed
        before = be.device_of[victim]
        for _ in range(12):
            system.step()
            if be.redispatches:
                break
        assert be.redispatches, "straggler was never flagged"
        assert be.device_of[victim] != before  # migrated to the other worker
        # the migrated segment still steps (its states moved with it)
        rep = system.step()
        assert rep.live_tasks == be.live_task_count
        system.close()


# -- acceptance bar: full OPMW rw1 conformance (slow tier) -----------------------


def _opmw_events(truncate=None):
    from repro.workloads import opmw_workload, rw_trace

    dags = opmw_workload()
    events = [(ev.op, ev.name) for ev in rw_trace(dags, seed=11)]
    return events[:truncate] if truncate else events


def _run_opmw(backend, events, step_mode, ckpt_boundary=None, ckpt_dir=None):
    """Replay OPMW events (one step per event); optionally checkpoint at
    ``ckpt_boundary`` events, tear the system down, and resume from disk —
    the final counts must be indistinguishable from an uninterrupted run."""
    from repro.workloads import opmw_workload

    dags = {d.name: d for d in opmw_workload()}
    system = StreamSystem(
        strategy="signature", backend=backend, step_mode=step_mode,
        max_workers=MAX_WORKERS,
        **({"checkpoint_dir": ckpt_dir} if ckpt_dir else {}),
    )
    for i, (op, name) in enumerate(events):
        _apply(system, dags, op, name)
        system.step()
        if ckpt_boundary is not None and i + 1 == ckpt_boundary:
            system.checkpoint()
            system.close()
            system = StreamSystem.restore(ckpt_dir)
    counts = _counts(system)
    system.close()
    return counts


@pytest.mark.slow
class TestOpmwConformance:
    def test_rw1_slice_multiproc_vs_inprocess(self):
        events = _opmw_events(truncate=10)
        ref = _run_opmw("inprocess", events, STEP_MODE)
        got = _run_opmw(
            resolve_backend("multiproc", workers=2), events, STEP_MODE
        )
        assert got == ref

    def test_rw1_slice_with_restore_boundary(self, ckpt_dir):
        events = _opmw_events(truncate=10)
        ref = _run_opmw("inprocess", events, STEP_MODE)
        got = _run_opmw(
            resolve_backend("multiproc", workers=2), events, STEP_MODE,
            ckpt_boundary=5, ckpt_dir=ckpt_dir,
        )
        assert got == ref

    def test_rw1_full_trace_acceptance(self, ckpt_dir):
        """The PR's acceptance criterion: multiproc/shm ≡ inprocess on the
        *full* OPMW rw1 trace, across a mid-trace kill + restore."""
        if os.environ.get("REPRO_FULL_OPMW_MULTIPROC") != "1":
            pytest.skip(
                "full-trace acceptance run (~2 min of jit compiles) — set "
                "REPRO_FULL_OPMW_MULTIPROC=1; the CI multiproc-conformance "
                "job runs it in both step modes"
            )
        events = _opmw_events()
        ref = _run_opmw("inprocess", events, STEP_MODE)
        got = _run_opmw(
            resolve_backend("multiproc", workers=2), events, STEP_MODE,
            ckpt_boundary=len(events) // 2, ckpt_dir=ckpt_dir,
        )
        assert got == ref
