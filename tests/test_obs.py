"""Unified telemetry plane tests (``repro.obs`` + its runtime wiring).

Five layers:
  * primitives: counter/gauge/histogram semantics, registry get-or-create,
    snapshot/merge (the multiproc aggregation path), the null twin,
    collectors, Prometheus render/parse round-trips;
  * tracing: span recording, stride sampling, ring-buffer bounds, error
    spans, Chrome trace-event export;
  * system wiring: merge/unmerge/step spans, reuse-savings metrics
    cross-checked against manager/ledger ground truth, ``configure_obs``
    registry swaps, the canonical ``segment_latency_ms()`` accessor vs the
    raw ``StepReport.segment_ms`` history (the double-source fix);
  * cluster/durability: worker-health staleness marking through serving
    ``status()``, the ``report_history`` ring buffer surviving a multiproc
    checkpoint/restore, cross-process span harvest;
  * serving: the ``metrics`` wire verb end-to-end over TCP, serve gauges
    matching the tenant ledgers.

The CI observability job re-runs this module with ``REPRO_TEST_STEP_MODE``
sync and concurrent; results must be mode-invariant.
"""
from __future__ import annotations

import json
import os

import pytest

from repro.obs import (
    DEFAULT_MS_BUCKETS,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
    Tracer,
    chrome_trace_json,
    merge_snapshots,
    parse_prometheus,
    render_prometheus,
    write_chrome_trace,
)
from repro.runtime.system import StreamSystem

from helpers import fig1

STEP_MODE = os.environ.get("REPRO_TEST_STEP_MODE") or "sync"


def sample(families, name, **labels):
    want = {k: str(v) for k, v in labels.items()}
    for lbls, value in families.get(name, []):
        if lbls == want:
            return value
    return None


def snap_value(snapshot, name, **labels):
    """Scalar of one labelset in a registry snapshot, or None."""
    entry = snapshot.get(name)
    if entry is None:
        return None
    want = {k: str(v) for k, v in labels.items()}
    for lbls, value in entry["values"]:
        if lbls == want:
            return value
    return None


# -- primitives -------------------------------------------------------------------


class TestMetricsPrimitives:
    def test_counter_inc_labels_and_clamped_set_total(self):
        m = MetricsRegistry()
        c = m.counter("ops_total", "ops")
        c.inc()
        c.inc(2.5)
        c.inc(1, op="merge")
        assert c.value() == 3.5
        assert c.value(op="merge") == 1.0
        c.set_total(10.0)
        assert c.value() == 10.0
        c.set_total(4.0)  # clamped: counters never decrease
        assert c.value() == 10.0
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        g = MetricsRegistry().gauge("depth", "queue depth")
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value() == 6.0

    def test_histogram_buckets_sum_count(self):
        m = MetricsRegistry()
        h = m.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 50.0, 10.0):  # 10.0 lands in le=10 (inclusive)
            h.observe(v)
        cell = snap_value(m.snapshot(), "lat_ms")
        assert cell["counts"] == [1, 2, 1]
        assert cell["count"] == 4
        assert cell["sum"] == pytest.approx(65.5)
        assert m.histogram("lat_ms").buckets == (1.0, 10.0)
        assert DEFAULT_MS_BUCKETS == tuple(sorted(DEFAULT_MS_BUCKETS))

    def test_registry_get_or_create_and_kind_mismatch(self):
        m = MetricsRegistry()
        assert m.counter("x") is m.counter("x")
        with pytest.raises(ValueError):
            m.gauge("x")

    def test_merge_adds_counters_and_histogram_cells(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        for m, n in ((a, 2), (b, 3)):
            m.counter("steps_total").inc(n)
            m.gauge("live").set(n)
            m.histogram("ms", buckets=(1.0,)).observe(0.5)
        merged = merge_snapshots([a.snapshot(), b.snapshot()])
        assert snap_value(merged, "steps_total") == 5.0
        assert snap_value(merged, "live") == 5.0  # worker gauges sum pool-wide
        cell = snap_value(merged, "ms")
        assert cell["count"] == 2 and cell["counts"] == [2, 0]

    def test_null_registry_is_inert(self):
        assert isinstance(NULL_REGISTRY, NullRegistry)
        NULL_REGISTRY.counter("whatever").inc(5)
        NULL_REGISTRY.add_collector(lambda: 1 / 0)
        assert NULL_REGISTRY.snapshot() == {}

    def test_collectors_run_at_snapshot_and_failures_are_swallowed(self):
        m = MetricsRegistry()
        m.add_collector(lambda: m.gauge("mirrored").set(42))
        m.add_collector(lambda: 1 / 0)  # must not kill the scrape
        assert snap_value(m.snapshot(), "mirrored") == 42.0


class TestPrometheusText:
    def test_render_parse_round_trip(self):
        m = MetricsRegistry()
        m.counter("req_total", "requests").inc(3, tenant="a/b", code="200")
        m.gauge("temp").set(-1.5)
        m.histogram("ms", buckets=(1.0, 5.0)).observe(0.2)
        text = render_prometheus(m.snapshot())
        fams = parse_prometheus(text)
        assert sample(fams, "req_total", tenant="a/b", code="200") == 3.0
        assert sample(fams, "temp") == -1.5
        assert sample(fams, "ms_count") == 1.0
        assert sample(fams, "ms_bucket", le="1") == 1.0
        assert sample(fams, "ms_bucket", le="+Inf") == 1.0

    def test_label_escaping_survives_round_trip(self):
        m = MetricsRegistry()
        m.counter("c").inc(1, topic='we"ird\\label\nx')
        fams = parse_prometheus(render_prometheus(m.snapshot()))
        assert sample(fams, "c", topic='we"ird\\label\nx') == 1.0

    def test_parse_rejects_malformed_lines(self):
        with pytest.raises(ValueError):
            parse_prometheus("this is not exposition format\n")


# -- tracing ----------------------------------------------------------------------


class TestTracer:
    def test_disabled_records_nothing(self):
        t = Tracer(enabled=False)
        with t.span("x"):
            pass
        assert t.drain() == []

    def test_span_shape(self):
        t = Tracer(enabled=True)
        with t.span("step", "step", step=3):
            pass
        (s,) = t.drain()
        assert s["name"] == "step" and s["cat"] == "step" and s["ph"] == "X"
        assert s["dur"] >= 1 and s["args"] == {"step": 3}
        assert s["pid"] == os.getpid()

    def test_stride_sampling_per_name(self):
        t = Tracer(enabled=True, sample_stride=3)
        for _ in range(9):
            with t.span("a"):
                pass
        for _ in range(2):
            with t.span("b"):
                pass
        names = [s["name"] for s in t.drain()]
        assert names.count("a") == 3  # every 3rd
        assert names.count("b") == 1  # stride state is per name

    def test_ring_buffer_drops_oldest(self):
        t = Tracer(enabled=True, capacity=4)
        for i in range(10):
            with t.span("s", i=i):
                pass
        kept = [s["args"]["i"] for s in t.drain()]
        assert kept == [6, 7, 8, 9]

    def test_error_span_recorded_and_raises(self):
        t = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with t.span("boom"):
                raise RuntimeError("x")
        (s,) = t.drain()
        assert s["args"]["error"] == "RuntimeError"

    def test_chrome_trace_export(self, tmp_path):
        t = Tracer(enabled=True)
        with t.span("work", "segment"):
            pass
        doc = chrome_trace_json(t.spans())
        metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert len(metas) == 1 and metas[0]["args"]["name"].startswith("repro pid")
        path = write_chrome_trace(str(tmp_path / "trace.json"), t.drain())
        loaded = json.load(open(path))
        assert any(e["ph"] == "X" for e in loaded["traceEvents"])


# -- system wiring ----------------------------------------------------------------


def _fig1_system(**kw):
    kw.setdefault("strategy", "signature")
    kw.setdefault("backend", "dryrun")
    kw.setdefault("step_mode", STEP_MODE)
    system = StreamSystem(**kw)
    for df in fig1():
        system.submit(df.copy())
    return system


class TestSystemObs:
    def test_control_and_step_spans(self):
        system = _fig1_system()
        system.configure_obs(trace=True)
        system.submit(fig1()[1].copy("B2"))
        system.step()
        system.remove("B2")
        spans = system.drain_spans()
        names = {s["name"] for s in spans}
        assert {"merge", "unmerge", "step"} <= names
        cats = {s["cat"] for s in spans}
        assert {"control", "step", "segment"} <= cats
        system.close()

    def test_reuse_savings_metrics_match_manager_ground_truth(self):
        system = _fig1_system()
        system.run(3)
        system.remove("B")
        snap = system.metrics_snapshot()
        mgr = system.manager
        saved = mgr.submitted_task_count - mgr.running_task_count
        assert snap_value(snap, "repro_reuse_tasks_saved") == saved
        oc = mgr.op_counts
        assert snap_value(snap, "repro_reuse_tasks_submitted_total") == oc["tasks_submitted"]
        assert snap_value(snap, "repro_reuse_tasks_reused_total") == oc["tasks_reused"]
        assert snap_value(snap, "repro_merge_events_total") == oc["merge_events"]
        assert snap_value(snap, "repro_unmerge_events_total") == 1.0
        # tasks_submitted decomposes exactly: reused + created
        assert oc["tasks_submitted"] == oc["tasks_reused"] + oc["tasks_created"]
        # core·steps avoided accrues only while sharing exists
        assert snap_value(snap, "repro_reuse_core_steps_avoided_total") > 0
        system.close()

    def test_op_counts_survive_journal_replay(self, tmp_path):
        from repro.core import ReuseManager

        journal = str(tmp_path / "journal.jsonl")
        system = _fig1_system(journal_path=journal)
        system.remove("A")
        want = dict(system.manager.op_counts)
        system.close()
        replayed = ReuseManager.restore(journal, strategy="signature")
        assert replayed.op_counts == want

    def test_configure_obs_registry_swap_keeps_collectors(self):
        system = _fig1_system()
        assert snap_value(system.metrics_snapshot(), "repro_reuse_tasks_saved") is not None
        system.configure_obs(metrics=False)
        assert system.metrics_snapshot() == {}
        assert system.prometheus_text() == "\n"
        system.configure_obs(metrics=True)  # fresh registry, collector re-wired
        assert snap_value(system.metrics_snapshot(), "repro_reuse_tasks_saved") is not None
        system.close()

    def test_segment_latency_accessor_matches_report_history(self):
        """Satellite: segment_latency_ms() is THE accessor — its digest must
        agree exactly with the raw StepReport.segment_ms history that also
        feeds latency_samples() (no second EWMA-based source)."""
        system = _fig1_system(report_history=64)
        system.run(6)
        stats = system.segment_latency_ms()
        reports = system.backend.reports
        assert stats and reports
        for name, cell in stats.items():
            series = [r.segment_ms[name] for r in reports if name in r.segment_ms]
            assert cell["samples"] == len(series)
            assert cell["mean_ms"] == pytest.approx(sum(series) / len(series))
            assert cell["last_ms"] == pytest.approx(series[-1])
            assert cell["max_ms"] == pytest.approx(max(series))
        # same sample population as the dry-run calibrator feed
        n_samples = sum(c["samples"] for c in stats.values())
        assert len(system.backend.latency_samples()) == n_samples
        system.close()

    def test_checkpoint_metrics_and_spans(self, tmp_path):
        system = _fig1_system(checkpoint_dir=str(tmp_path / "ck"))
        system.configure_obs(trace=True)
        system.run(2)
        system.checkpoint()
        snap = system.metrics_snapshot()
        assert snap_value(snap, "repro_checkpoints_total") == 1.0
        hist = snap_value(snap, "repro_checkpoint_save_ms")
        assert hist["count"] == 1
        names = {s["name"] for s in system.drain_spans() if s["cat"] == "checkpoint"}
        assert {"ckpt_encode", "ckpt_fsync"} <= names
        system.close()

    def test_transport_counters_mirrored(self):
        system = _fig1_system(backend="inprocess")
        system.run(3)
        snap = system.metrics_snapshot()
        transport = system.backend.transport
        assert snap_value(snap, "repro_transport_publishes_total") == transport.counters()["publishes"]
        assert snap_value(snap, "repro_transport_fetches_total") == transport.fetch_count
        assert snap_value(snap, "repro_transport_fetches_total") > 0
        system.close()


# -- cluster / durability ---------------------------------------------------------


class TestWorkerHealthStaleness:
    def test_health_has_monotonic_staleness_fields(self):
        system = _fig1_system(backend="multiproc", workers=2,
                              backend_options={"worker_plane": "dry"})
        try:
            system.run(2)
            health = system.backend.worker_health()
            assert health["stale_after_ms"] > 0
            assert set(health["stale"]) == {"0", "1"}
            for w in ("0", "1"):
                t = health["last_ok_monotonic"][w]
                assert t is not None and t <= health["now_monotonic"]
                assert health["stale"][w] is False  # just replied
            # shrink the window to zero: every worker's last reply is stale
            system.backend.stale_after_ms = 0.0
            assert all(system.backend.worker_health()["stale"].values())
        finally:
            system.close()

    def test_staleness_surfaces_through_serving_status(self):
        from repro.api import ReuseSession
        from repro.serve.frontend import ServeFrontend

        session = ReuseSession(
            strategy="signature", execute=True, backend="multiproc",
            workers=1, step_mode=STEP_MODE,
            backend_options={"worker_plane": "dry"},
        )
        frontend = ServeFrontend(session=session)
        try:
            frontend.submit("alice", fig1()[0].copy("alice/A"))
            frontend.step()
            health = frontend.status()["worker_health"]
            assert health["stale"]["0"] is False
            assert health["last_ok_monotonic"]["0"] is not None
            assert health["stale_after_ms"] > 0
        finally:
            frontend.close()


class TestReportHistoryCheckpoint:
    def test_report_ring_survives_multiproc_checkpoint_restore(self, tmp_path):
        """Satellite: the opt-in StepReport ring buffer is part of the
        durable state — a restored system resumes with the pre-crash
        trajectory, trimmed to the ring limit."""
        limit = 5
        system = _fig1_system(
            backend="multiproc", workers=2,
            backend_options={"worker_plane": "dry"},
            report_history=limit, checkpoint_dir=str(tmp_path / "ck"),
        )
        try:
            system.run(limit + 3)  # overflow the ring before checkpointing
            assert len(system.backend.reports) == limit
            want = [(r.step, r.cost, r.segment_ms) for r in system.backend.reports]
            path = system.checkpoint()
        finally:
            system.close()
        restored = StreamSystem.restore(
            path, backend="multiproc",
            backend_options={"worker_plane": "dry"},
        )
        try:
            assert restored.backend.history_limit == limit
            got = [(r.step, r.cost, r.segment_ms) for r in restored.backend.reports]
            assert got == want
            restored.run(limit)  # ring keeps enforcing the limit post-restore
            assert len(restored.backend.reports) == limit
            assert restored.backend.reports[-1].step > want[-1][0]
        finally:
            restored.close()


class TestMultiprocObsHarvest:
    def test_worker_metrics_and_spans_harvested(self):
        system = _fig1_system(backend="multiproc", workers=2,
                              backend_options={"worker_plane": "dry"})
        try:
            system.configure_obs(trace=True)
            system.run(3)
            snap = system.metrics_snapshot()
            # worker families are distinct from coordinator ones: no
            # double-count on merge
            worker_steps = snap.get("repro_worker_segment_steps_total")
            assert worker_steps is not None
            total = sum(v for _lbls, v in worker_steps["values"])
            assert total > 0
            spans = system.drain_spans()
            seg_pids = {s["pid"] for s in spans if s["cat"] == "segment"}
            assert len(seg_pids) >= 2  # spans from >1 worker process
            assert os.getpid() not in seg_pids  # segments ran in workers
            rpc_spans = [s for s in spans if s["cat"] == "rpc"]
            assert rpc_spans and all(s["pid"] == os.getpid() for s in rpc_spans)
        finally:
            system.close()


# -- serving ----------------------------------------------------------------------


class TestServeMetricsVerb:
    def test_metrics_verb_over_tcp_matches_ledgers(self):
        from repro.serve.client import ServeClient
        from repro.serve.frontend import ServeFrontend

        frontend = ServeFrontend(slots=64, backend="dryrun")
        host, port = frontend.start()
        try:
            with ServeClient((host, port)) as client:
                a, b, *_ = fig1()
                assert client.submit("alice", a.copy("alice/A"))["status"] == "ADMITTED"
                assert client.submit("bob", b.copy("bob/B"))["status"] == "ADMITTED"
                client.step(2)
                out = client.metrics()
                fams = parse_prometheus(out["text"])  # validates the format
                stats = frontend.stats()
                assert sample(fams, "repro_serve_slots") == 64.0
                assert sample(fams, "repro_serve_slots_used") == stats["slots_used"]
                assert sample(fams, "repro_serve_naive_slots") == stats["naive_slots"]
                assert sample(fams, "repro_serve_effective_capacity") == pytest.approx(
                    stats["effective_capacity"]
                )
                for tenant in ("alice", "bob"):
                    ledger = stats["ledgers"][tenant]
                    assert sample(fams, "repro_serve_slots_held", tenant=tenant) == ledger["slots_held"]
                    assert sample(fams, "repro_serve_slots_saved", tenant=tenant) == ledger["slots_saved"]
                    assert sample(fams, "repro_serve_cost_total", tenant=tenant) == pytest.approx(
                        ledger["cost_total"]
                    )
                # snapshot side of the reply carries the raw registry JSON
                assert snap_value(out["snapshot"], "repro_serve_slots") == 64.0
        finally:
            frontend.close()

    def test_metrics_http_listener(self):
        import urllib.request

        from repro.serve.frontend import ServeFrontend

        frontend = ServeFrontend(slots=16, backend="dryrun")
        try:
            frontend.submit("alice", fig1()[0].copy("alice/A"))
            frontend.step()
            host, port = frontend.start_metrics_http(port=0)
            body = urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=10
            ).read().decode("utf-8")
            fams = parse_prometheus(body)
            assert sample(fams, "repro_serve_slots_used") == frontend.slots_used
            with pytest.raises(Exception):
                urllib.request.urlopen(f"http://{host}:{port}/nope", timeout=10)
        finally:
            frontend.close()

    def test_control_plane_session_metrics_are_empty(self):
        from repro.api import ReuseSession
        from repro.serve.frontend import ServeFrontend

        frontend = ServeFrontend(session=ReuseSession(execute=False))
        try:
            out = frontend.metrics()
            assert out["ok"] and out["text"] == "" and out["snapshot"] == {}
        finally:
            frontend.close()
