"""Fusion/locality optimizer (PR 9): wave-aware planner scoring,
cross-worker fusion via member migration, the compiled-segment reuse
cache, and the multi-op fused pallas kernels.

  * planner — score_fusion_plan accept/reject model (critical path vs
    slot-load consolidation), fusion_report surfacing, plan_fusion
    hardening against killed segments (merge→fuse→unmerge→fuse cycles);
  * cache — structural signatures, hit/miss/evict counters through
    session.stats(), invalidation on config change, per-backend caches
    (transport change, restore on a fresh backend), digest identity of
    cache-hit segments;
  * cross-worker fusion — members spread over 4 workers are migrated to
    one slot, fused, and sink digests stay bit-identical to unfused in
    both step modes; sync-mode chain batching digest identity;
  * kernels — fused affine→rmsnorm / map-chain ops are bit-identical to
    the op-by-op ref path and allclose in pallas interpret mode.
"""
from __future__ import annotations

import numpy as np
import pytest

from helpers import chain_df, fig1


# -- structural signatures ------------------------------------------------------


def _spec(name, tids, parents, batch=8, fused=False, publish=()):
    from repro.runtime.backend import SegmentSpec

    return SegmentSpec(
        name=name,
        dag_name="d",
        task_ids=list(tids),
        parents={t: list(parents.get(t, [])) for t in tids},
        publish=set(publish),
        batch_of={t: batch for t in tids},
        fused=fused,
    )


def _df(tasks):
    from repro.core.graph import Dataflow, Task

    df = Dataflow("d")
    for tid, typ, cfg in tasks:
        df.add_task(Task.make(tid, typ, cfg))
    return df


class TestStructuralSignature:
    def sig(self, tids, parents, cfgs, **kw):
        from repro.runtime.compile_cache import structural_signature

        df = _df([(t, typ, cfg) for t, (typ, cfg) in zip(tids, cfgs.values())])
        return structural_signature(_spec("s", tids, parents, **kw), df)

    def test_names_and_topics_are_erased(self):
        cfgs_a = {"a.k": ("kalman", {"q": 0.1}), "a.s": ("store", "SINK")}
        cfgs_b = {"b.k2": ("kalman", {"q": 0.1}), "b.s9": ("store", "SINK")}
        sa = self.sig(["a.k", "a.s"], {"a.k": ["up.x"], "a.s": ["a.k"]}, cfgs_a)
        sb = self.sig(["b.k2", "b.s9"], {"b.k2": ["up.y"], "b.s9": ["b.k2"]}, cfgs_b)
        assert sa == sb  # different task ids AND different boundary parent

    def test_config_change_invalidates(self):
        base = {"t": ("kalman", {"q": 0.1})}
        changed = {"t": ("kalman", {"q": 0.2})}
        assert self.sig(["t"], {"t": ["x"]}, base) != self.sig(
            ["t"], {"t": ["x"]}, changed
        )

    def test_batch_fused_and_wiring_matter(self):
        cfgs = {"t": ("kalman", {"q": 0.1}), "u": ("win", {"w": 4})}
        p_chain = {"t": ["x"], "u": ["t"]}
        p_split = {"t": ["x"], "u": ["x"]}
        s = self.sig(["t", "u"], p_chain, cfgs)
        assert s != self.sig(["t", "u"], p_split, cfgs)
        assert s != self.sig(["t", "u"], p_chain, cfgs, batch=16)
        assert s != self.sig(["t", "u"], p_chain, cfgs, fused=True)

    def test_publish_is_not_part_of_the_key(self):
        cfgs = {"t": ("kalman", {"q": 0.1})}
        assert self.sig(["t"], {"t": ["x"]}, cfgs) == self.sig(
            ["t"], {"t": ["x"]}, cfgs, publish=("t",)
        )


# -- compile cache --------------------------------------------------------------


def _linear(name, stages):
    return chain_df(name, "urban", stages)


STAGES = [("senml_parse", {"scale": 2.0, "offset": 0.5}), ("kalman", {"q": 0.1})]


class TestCompileCache:
    def test_identical_resubmissions_hit(self):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(strategy="none", backend="inprocess")
        for i in range(3):  # Default strategy: each copy deploys its own segment
            system.submit(_linear(f"c{i}", STAGES))
        system.run(2)
        stats = system.backend.compile_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 2
        assert stats["entries"] == 1
        # cache-hit segments step through the shared executable with
        # renamed keys — outputs must be identical across the copies
        d = [system.sink_digests(f"c{i}") for i in range(3)]
        assert list(d[0].values()) == list(d[1].values()) == list(d[2].values())
        system.close()

    def test_config_change_misses(self):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(strategy="none", backend="inprocess")
        system.submit(_linear("a", STAGES))
        system.submit(_linear("b", [("senml_parse", {"scale": 3.0}), ("kalman", {"q": 0.1})]))
        system.step()
        stats = system.backend.compile_cache_stats()
        assert stats["misses"] == 2 and stats["hits"] == 0
        system.close()

    def test_caches_are_per_backend(self):
        # the key is structural, but executables never leak across
        # backends/transports — a fresh backend starts cold
        from repro.runtime.system import StreamSystem

        for transport in ("inproc", "shm"):
            system = StreamSystem(
                strategy="none", backend="inprocess", transport=transport
            )
            system.submit(_linear("a", STAGES))
            system.step()
            stats = system.backend.compile_cache_stats()
            assert stats["hits"] == 0 and stats["misses"] == 1
            system.close()

    def test_restore_compiles_on_the_fresh_backend_then_hits(self, tmp_path):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(
            strategy="none", backend="inprocess", checkpoint_dir=str(tmp_path)
        )
        system.submit(_linear("a", STAGES))
        system.run(3)
        ref = system.sink_digests("a")
        system.checkpoint()
        system.close()

        restored = StreamSystem.restore(str(tmp_path))
        stats = restored.backend.compile_cache_stats()
        assert stats["hits"] == 0 and stats["misses"] >= 1  # cold cache
        assert restored.sink_digests("a") == ref
        restored.submit(_linear("b", STAGES))  # same structure — warm now
        restored.step()
        assert restored.backend.compile_cache_stats()["hits"] >= 1
        restored.close()

    def test_lru_eviction_counter(self):
        from repro.runtime.compile_cache import CompileCache
        from repro.runtime.segment import build_segment

        cache = CompileCache(capacity=1)
        for q in (0.1, 0.2, 0.3):
            df = _df([("t", "kalman", {"q": q})])
            spec = _spec("s", ["t"], {"t": ["x"]})
            build_segment(spec, df, cache=cache)
        stats = cache.stats()
        assert stats == {"hits": 0, "misses": 3, "evictions": 2, "entries": 1}

    def test_session_stats_surface(self):
        from repro.api import ReuseSession

        session = ReuseSession(strategy="none", execute=True, backend="inprocess")
        session.submit(_linear("a", STAGES))
        session.submit(_linear("b", STAGES))
        session.step()
        st = session.stats()
        assert st.compile_cache_misses == 1
        assert st.compile_cache_hits == 1
        assert st.compile_cache_entries == 1
        assert st.compile_cache_evictions == 0
        session.close()

    def test_control_plane_session_reports_zeros(self):
        from repro.api import ReuseSession

        st = ReuseSession(strategy="signature").stats()
        assert st.compile_cache_hits == st.compile_cache_misses == 0


# -- wave-aware planner scoring -------------------------------------------------


def _chain_plan(*chains):
    from repro.core.defrag import FusionChain, FusionPlan

    return FusionPlan(chains=[FusionChain(dag_name="d", members=list(c)) for c in chains])


class TestFusionPlannerScoring:
    def test_single_slot_always_accepts(self):
        from repro.core.defrag import score_fusion_plan

        deps = {"a": set(), "b": {"a"}, "c": {"b"}}
        report = score_fusion_plan(
            _chain_plan(["a", "b", "c"]), deps, {"a": 5.0, "b": 5.0, "c": 5.0},
            slot_of=None, n_slots=1,
        )
        (d,) = report.decisions
        assert d.accepted and d.est_penalty_ms == pytest.approx(0.0)
        assert report.accepted and not report.rejected

    def test_deep_chain_across_workers_accepted(self):
        # a 12-deep serial chain spread over 4 slots: the critical path IS
        # the whole chain, so consolidating onto one slot can't stretch
        # the makespan — fuse it
        from repro.core.defrag import score_fusion_plan

        members = [f"s{i}" for i in range(12)]
        deps = {m: ({members[i - 1]} if i else set()) for i, m in enumerate(members)}
        report = score_fusion_plan(
            _chain_plan(members), deps, {m: 1.0 for m in members},
            slot_of={m: i % 4 for i, m in enumerate(members)}, n_slots=4,
        )
        (d,) = report.decisions
        assert d.accepted
        assert d.est_penalty_ms == pytest.approx(0.0)

    def test_wide_wave_consolidation_rejected(self):
        # 4 independent 2-deep chains, one per slot-pair, on a balanced
        # 4-slot pool: every fusion targets the same cheapest slot and
        # would pile work there — makespan stretch >> dispatch saving
        from repro.core.defrag import score_fusion_plan

        deps, slot_of, chains = {}, {}, []
        for c in range(4):
            a, b = f"a{c}", f"b{c}"
            deps[a], deps[b] = set(), {a}
            slot_of[a], slot_of[b] = c, (c + 1) % 4
            chains.append([a, b])
        report = score_fusion_plan(
            _chain_plan(*chains), deps, {n: 10.0 for n in deps},
            slot_of=slot_of, n_slots=4, overhead_ms=0.25,
        )
        rejected = report.rejected
        assert rejected  # at least the later chains must be refused
        assert all("wide" in d.reason for d in rejected)
        assert all(d.est_penalty_ms > d.est_benefit_ms for d in rejected)

    def test_accepted_chains_update_the_load_picture(self):
        # two chains on an empty 2-slot pool: both would pick slot 0 in
        # isolation; greedy accounting must spread them
        from repro.core.defrag import score_fusion_plan

        deps = {"a": set(), "b": {"a"}, "c": set(), "d": {"c"}}
        report = score_fusion_plan(
            _chain_plan(["a", "b"], ["c", "d"]), deps,
            {n: 1.0 for n in deps},
            slot_of={"a": 0, "b": 1, "c": 0, "d": 1}, n_slots=2,
            overhead_ms=10.0,  # make both worth fusing
        )
        assert [d.accepted for d in report.decisions] == [True, True]
        assert report.decisions[0].target_slot != report.decisions[1].target_slot

    def test_report_to_dict_explains_every_verdict(self):
        from repro.core.defrag import score_fusion_plan

        deps = {"a": set(), "b": {"a"}}
        report = score_fusion_plan(_chain_plan(["a", "b"]), deps, {"a": 1.0, "b": 1.0})
        out = report.to_dict()
        assert set(out) == {"accepted", "rejected"}
        assert out["accepted"][0]["members"] == ["a", "b"]
        assert out["accepted"][0]["reason"]


# -- plan_fusion hardening (satellite: killed segments / idempotency) ----------


class TestPlanFusionHardening:
    def test_killed_segments_never_proposed(self):
        from repro.core.defrag import plan_fusion

        # seg_deps still holds a stale edge onto killed segment "dead",
        # and "ghost" appears in deps but was killed from dag_of
        seg_deps = {"a": set(), "b": {"a"}, "c": {"b", "dead"}, "ghost": {"c"}}
        dag_of = {"a": "d", "b": "d", "c": "d"}
        plan = plan_fusion(seg_deps, dag_of)
        for chain in plan.chains:
            assert "dead" not in chain.members
            assert "ghost" not in chain.members

    def test_merge_fuse_unmerge_fuse_cycle(self):
        from repro.runtime.system import StreamSystem

        dags = {d.name: d for d in fig1()}
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(dags["A"].copy())
        system.submit(dags["B"].copy())  # merges onto A's chain
        system.run(2)
        first = system.fuse()
        assert first  # B's suffix fused
        system.run(1)
        system.remove("B")  # unmerge — pauses B-only tasks
        system.step()
        # the re-run must be safe and never reference killed members
        second = system.fuse()
        alive = set(system.backend.segments)
        for members in second.values():
            assert set(members) <= alive | set(second)
        assert system.fuse() == {}  # idempotent once nothing linear remains
        system.close()

    def test_fuse_after_defragment(self):
        from repro.runtime.system import StreamSystem

        dags = {d.name: d for d in fig1()}
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(dags["A"].copy())
        system.submit(dags["C"].copy())
        system.run(2)
        system.fuse()
        system.remove("A")
        system.defragment()  # kills everything, relaunches fused-per-DAG
        system.step()
        system.fuse()  # must not touch killed segment names
        ref = system.sink_digests("C")
        system.run(2)
        sink = "C.sink.store_c"
        assert system.sink_digests("C")[sink]["count"] > ref[sink]["count"]
        system.close()


# -- cross-worker fusion + sync chains (multiproc) ------------------------------


def _stacked(depth):
    dags = []
    for k in range(1, depth + 1):
        stages = [("kalman", {"q": 0.1, "stage": i}) for i in range(k)]
        dags.append(chain_df(f"deep{k:02d}", "urban", stages))
    return dags


def _run_stacked(step_mode, fuse, chain_batching=True, workers=4, depth=4):
    from repro.runtime.system import StreamSystem

    system = StreamSystem(
        strategy="signature", backend="multiproc", workers=workers,
        transport="shm", step_mode=step_mode,
        backend_options={"chain_batching": chain_batching},
    )
    for df in _stacked(depth):
        system.submit(df.copy())
    system.run(2)
    spread = set(system.backend.device_of.values())
    if fuse:
        fused = system.fuse()
        assert fused, "the stacked chain must fuse"
        assert len(spread) > 1, "members should start spread across workers"
        # all members were consolidated: the fused segment occupies ONE slot
        assert len(set(system.backend.device_of.values())) == 1
        assert system.fusion_report is not None and system.fusion_report.accepted
    system.run(3)
    digests = {n: system.sink_digests(n) for n in sorted(system.manager.submitted)}
    system.close()
    return digests


@pytest.mark.slow
class TestCrossWorkerFusion:
    @pytest.mark.parametrize("step_mode", ["sync", "concurrent"])
    def test_fused_identical_to_unfused_across_workers(self, step_mode):
        ref = _run_stacked(step_mode, fuse=False)
        got = _run_stacked(step_mode, fuse=True)
        assert got == ref  # migration + recompile is bit-exact

    def test_worker_cache_counters_aggregate(self):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(
            strategy="none", backend="multiproc", workers=2, transport="shm",
        )
        system.submit(_linear("a", STAGES))
        system.submit(_linear("b", STAGES))  # may land on either worker
        system.step()
        stats = system.backend.compile_cache_stats()
        assert stats["misses"] + stats["hits"] == 2
        assert stats["misses"] >= 1
        system.close()


@pytest.mark.slow
class TestSyncChainBatching:
    def test_sync_chains_on_off_digests_identical(self):
        ref = _run_stacked("sync", fuse=False, chain_batching=False)
        got = _run_stacked("sync", fuse=False, chain_batching=True)
        assert got == ref

    def test_sync_uses_chains_when_enabled(self):
        from repro.runtime.system import StreamSystem

        system = StreamSystem(
            strategy="signature", backend="multiproc", workers=1,
            step_mode="sync",
        )
        assert system.backend._use_chains()
        for df in _stacked(3):
            system.submit(df.copy())
        system.run(2)  # exercises the chain-batched sync sweep
        assert system.backend.step_count == 2
        # worker-measured chain timings must keep feeding the placement
        # EWMAs (straggler detection relies on them in the batched path)
        assert any(v > 0 for v in system.backend.device_ewma().values())
        system.close()


# -- fused multi-op kernels -----------------------------------------------------


class TestFusedKernels:
    def test_ref_composition_is_bit_identical(self):
        import jax.numpy as jnp

        from repro.kernels import ops as kernel_ops

        x = jnp.asarray(
            np.random.default_rng(3).standard_normal((17, 5)), dtype=jnp.float32
        )
        stages = ((2.0, 0.5), (0.7, -0.1))
        scale = jnp.full((5,), 1.5, dtype=jnp.float32)
        # op-by-op, exactly as the unfused operators compute
        y = x
        for s, o in stages:
            y = y * s + o
        want_map = y
        want_norm = kernel_ops.rmsnorm(y, scale, eps=1e-6)
        got_map = kernel_ops.map_chain(x, stages=stages)
        got_norm = kernel_ops.affine_rmsnorm(x, scale, stages=stages, eps=1e-6)
        assert np.array_equal(np.asarray(got_map), np.asarray(want_map))
        assert np.array_equal(np.asarray(got_norm), np.asarray(want_norm))

    def test_interpret_mode_matches_ref(self):
        import jax.numpy as jnp

        from repro.kernels import ops as kernel_ops

        x = jnp.asarray(
            np.random.default_rng(5).standard_normal((33, 8)), dtype=jnp.float32
        )
        stages = ((1.3, 0.2),)
        scale = jnp.ones((8,), dtype=jnp.float32)
        kernel_ops.set_backend("interpret")
        try:
            got_map = kernel_ops.map_chain(x, stages=stages)
            got_norm = kernel_ops.affine_rmsnorm(x, scale, stages=stages)
        finally:
            kernel_ops.set_backend(None)
        np.testing.assert_allclose(
            np.asarray(got_map), np.asarray(x * 1.3 + 0.2), rtol=1e-5, atol=1e-6
        )
        from repro.kernels.ref import affine_rmsnorm_ref

        np.testing.assert_allclose(
            np.asarray(got_norm),
            np.asarray(affine_rmsnorm_ref(x, scale, stages)),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_make_fused_operator_matches_op_sequence(self):
        import jax.numpy as jnp

        from repro.core.graph import Task
        from repro.ops import operator_for_task
        from repro.ops.riot import make_fused_operator

        chain = [
            Task.make("p1", "senml_parse", {"scale": 2.0, "offset": 0.5}),
            Task.make("p2", "senml_parse", {"scale": 0.7, "offset": -0.1}),
            Task.make("n", "rmsnorm", {"gain": 1.5}),
        ]
        fused = make_fused_operator(chain, batch=9)
        assert fused is not None
        assert fused.cost_weight == operator_for_task(chain[-1], batch=9).cost_weight
        x = jnp.asarray(
            np.random.default_rng(7).standard_normal((9, 8)), dtype=jnp.float32
        )
        y = x
        st_unused = fused.init_state(9)
        for t in chain:
            op = operator_for_task(t, batch=9)
            _, y = op.apply(op.init_state(9), y)
        _, got = fused.apply(st_unused, x)
        assert np.array_equal(np.asarray(got), np.asarray(y))

    def test_make_fused_operator_declines_unknown_runs(self):
        from repro.core.graph import Task
        from repro.ops.riot import make_fused_operator

        k = Task.make("k", "kalman", {"q": 0.1})
        n = Task.make("n", "rmsnorm", {})
        assert make_fused_operator([k, n], batch=4) is None
        assert make_fused_operator([n], batch=4) is None

    def test_peephole_rewires_the_tail(self):
        from repro.ops import operator_for_task
        from repro.runtime.segment import _peephole_fused_kernels

        tasks = [
            ("s", "urban", "SOURCE"),
            ("p1", "senml_parse", {"scale": 2.0}),
            ("p2", "senml_parse", {"scale": 0.5}),
            ("n", "rmsnorm", {}),
            ("k", "store", "SINK"),
        ]
        df = _df(tasks)
        spec = _spec(
            "s0", [t for t, _, _ in tasks],
            {"p1": ["s"], "p2": ["p1"], "n": ["p2"], "k": ["n"]},
            fused=True,
        )
        operators = {
            t: operator_for_task(df.tasks[t], batch=spec.batch_of[t])
            for t in spec.task_ids
        }
        parents = {t: list(spec.parents[t]) for t in spec.task_ids}
        _peephole_fused_kernels(spec, df, operators, parents)
        assert parents["n"] == ["s"]  # tail consumes the run head's input
        assert parents["p1"] == ["s"] and parents["p2"] == ["p1"]  # interiors keep
        assert spec.parents["n"] == ["p2"]  # spec untouched

    def test_peephole_skipped_for_unfused_specs(self):
        from repro.ops import operator_for_task
        from repro.runtime.segment import _peephole_fused_kernels

        tasks = [("p1", "senml_parse", {"scale": 2.0}), ("n", "rmsnorm", {})]
        df = _df(tasks)
        spec = _spec("s0", ["p1", "n"], {"p1": ["x"], "n": ["p1"]}, fused=False)
        operators = {
            t: operator_for_task(df.tasks[t], batch=8) for t in spec.task_ids
        }
        parents = {t: list(spec.parents[t]) for t in spec.task_ids}
        _peephole_fused_kernels(spec, df, operators, parents)
        assert parents["n"] == ["p1"]


class TestFusedKernelDigestIdentity:
    """Session-level: a fused chain whose tail dispatches the multi-op
    pallas path must keep sink digests bit-identical to unfused."""

    def _run(self, fuse):
        from repro.runtime.system import StreamSystem

        stages = [
            ("senml_parse", {"scale": 2.0, "offset": 0.5}),
            ("senml_parse", {"scale": 0.7, "offset": -0.1}),
            ("rmsnorm", {"gain": 1.5}),
            ("kalman", {"q": 0.1}),
        ]
        A = chain_df("FA", "urban", stages[:2])
        B = chain_df("FB", "urban", stages)
        system = StreamSystem(strategy="signature", backend="inprocess")
        system.submit(A.copy())
        system.submit(B.copy())
        system.run(2)
        if fuse:
            assert system.fuse()
        system.run(4)
        out = {n: system.sink_digests(n) for n in ("FA", "FB")}
        system.close()
        return out

    def test_fused_equals_unfused(self):
        assert self._run(True) == self._run(False)
