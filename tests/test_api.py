"""Tests for the `repro.api` facade: fluent builder round-trips,
ReuseSession parity with direct StreamSystem use, batched submit
equivalence, lifecycle hooks, and the strategy registry."""
import pytest

from repro.api import (
    DataflowError,
    MergeStrategy,
    ReuseSession,
    available_strategies,
    flow,
)
from repro.core import ReuseManager
from repro.core.signatures import compute_signatures, is_dedup_fast
from repro.runtime.system import StreamSystem
from repro.workloads import replay, riot_workload, seq_trace


def _linear(name, extra="win"):
    return (
        flow(name)
        .source("urban")
        .then("senml_parse", schema="urban")
        .then("kalman", q=0.1)
        .then(extra, w=8)
        .sink("store")
    )


# -- builder ------------------------------------------------------------------


def test_builder_linear_roundtrip():
    df = _linear("alice").build()
    df.validate()
    assert len(df.tasks) == 5
    assert len(df.streams) == 4
    assert df.source_ids and df.sink_ids
    assert is_dedup_fast(df)
    # id scheme is deterministic and name-prefixed
    assert all(tid.startswith("alice/") for tid in df.tasks)


def test_builder_branch_and_fanin():
    df = (
        flow("fan")
        .source("urban")
        .then("parse", label="p")
        .then("win", w=4, label="w")
        .at("p")
        .then("avg", label="a")
        .then("join", after=["w", "a"])
        .sink("store")
        .build()
    )
    df.validate()
    join_id = next(tid for tid, t in df.tasks.items() if t.type == "join")
    assert len(df.parents(join_id)) == 2
    # both branches hang off the same parse task
    parse_id = next(tid for tid, t in df.tasks.items() if t.type == "parse")
    assert len(df.children(parse_id)) == 2


def test_builder_coalesces_duplicate_steps():
    # two identical kalman branches (type, config, ancestry) → one task
    df = (
        flow("dup")
        .source("urban")
        .then("parse", label="p")
        .then("kalman", q=1).sink("store")
        .at("p")
        .then("kalman", q=1).sink("store")
        .build()
    )
    assert is_dedup_fast(df)
    assert sum(1 for t in df.tasks.values() if t.type == "kalman") == 1


def test_builder_errors():
    with pytest.raises(DataflowError):
        flow("x").then("parse")  # no source yet
    with pytest.raises(DataflowError):
        flow("x").source("urban").at("nope")
    with pytest.raises(DataflowError):
        flow("x").source("urban", label="s").then("p", label="s")  # dup label
    with pytest.raises(DataflowError):
        flow("x").source("urban").then("parse").build()  # non-sink leaf fails validate


def test_builder_submits_directly():
    session = ReuseSession()
    r = session.submit(_linear("alice"))  # builder, not built Dataflow
    assert r.num_created == 5
    assert session.names == ["alice"]


# -- session ≡ StreamSystem parity -------------------------------------------


def test_session_parity_with_stream_system():
    dags = [d for d in riot_workload() if d.name.startswith("urban")]
    direct = StreamSystem(strategy="signature", base_batch=8)
    session = ReuseSession(strategy="signature", execute=True, base_batch=8)
    for d in dags:
        direct.submit(d.copy())
        session.submit(d.copy())
    assert session.running_task_count == direct.running_task_count
    direct.run(3)
    session.run(3)
    for d in dags:
        assert session.sink_digests(d.name) == direct.sink_digests(d.name)
    # removal + defrag parity
    direct.remove(dags[0].name)
    session.remove(dags[0].name)
    assert session.running_task_count == direct.running_task_count
    direct.defragment()
    ev = session.defragment()
    assert ev.segments_after == len(direct.executor.segments)
    direct.run(2)
    session.run(2)
    for d in dags[1:]:
        assert session.sink_digests(d.name) == direct.sink_digests(d.name)


def test_control_plane_session_rejects_data_plane_ops():
    session = ReuseSession()
    session.submit(_linear("a"))
    with pytest.raises(DataflowError):
        session.run(1)
    with pytest.raises(DataflowError):
        session.defragment()


def test_session_stats_and_hooks():
    session = ReuseSession()
    merges, unmerges = [], []
    session.on_merge(merges.append)
    session.on_unmerge(unmerges.append)
    session.submit(_linear("a"))
    session.submit(_linear("b", extra="avg"))
    st = session.stats()
    assert st.submitted_task_count == 10
    assert st.running_task_count == 7
    assert 0.29 < st.task_reduction < 0.31
    assert st.reuse_histogram.get(2) == 3  # shared prefix used by both
    assert [m.name for m in merges] == ["a", "b"]
    assert merges[1].num_reused == 3 and not merges[1].batched
    session.remove("a")
    assert len(unmerges) == 1 and unmerges[0].name == "a"
    assert unmerges[0].terminated_tasks  # a's win + sink die


# -- batched submission --------------------------------------------------------


@pytest.mark.parametrize("preload", [0, 7])
def test_submit_many_equals_sequential(preload):
    """Batch submit ≡ sequential submits: running task count, full running
    state, Δ/Φ, and (with the data plane) sink digests."""
    dags = riot_workload()
    seq = ReuseManager(strategy="signature", check_invariants=True)
    bat = ReuseManager(strategy="signature", check_invariants=True)
    for d in dags[:preload]:
        seq.submit(d.copy())
        bat.submit(d.copy())
    for d in dags[preload:]:
        seq.submit(d.copy())
    receipts = bat.submit_many([d.copy() for d in dags[preload:]])
    assert len(receipts) == len(dags) - preload
    assert bat.running_task_count == seq.running_task_count
    assert {n: sorted(d.tasks) for n, d in bat.running.items()} == {
        n: sorted(d.tasks) for n, d in seq.running.items()
    }
    assert bat.phi == seq.phi and bat.delta == seq.delta
    assert bat.task_maps == seq.task_maps
    # drains identically
    for d in dags:
        bat.remove(d.name)
    assert bat.running_task_count == 0


def test_submit_many_sink_digests_match_sequential():
    dags = [d for d in riot_workload() if d.name.startswith("meter")]
    seq = ReuseSession(execute=True, base_batch=8)
    bat = ReuseSession(execute=True, base_batch=8)
    for d in dags:
        seq.submit(d.copy())
    bat.submit_many([d.copy() for d in dags])
    seq.run(3)
    bat.run(3)
    for d in dags:
        assert bat.sink_digests(d.name) == seq.sink_digests(d.name)


def test_submit_many_interleaved_groups_match_sequential():
    """Members of different source groups interleaved in one batch still
    mint the same dag names / task ids as sequential submits."""
    def mk(name, src):
        return flow(name).source(src).then("p").then("q").sink("s").build()

    batch = [mk("a", "urban"), mk("b", "meter"), mk("c", "urban"), mk("d", "meter")]
    seq = ReuseManager(strategy="signature", check_invariants=True)
    for d in batch:
        seq.submit(d.copy())
    bat = ReuseManager(strategy="signature", check_invariants=True)
    receipts = bat.submit_many([d.copy() for d in batch])
    assert {n: sorted(d.tasks) for n, d in bat.running.items()} == {
        n: sorted(d.tasks) for n, d in seq.running.items()
    }
    assert bat.phi == seq.phi and bat.task_maps == seq.task_maps
    assert list(bat.running) == list(seq.running)  # same insertion order
    # journal entries land in batch order
    assert [e["dataflow"]["name"] for e in bat.journal] == ["a", "b", "c", "d"]
    # receipts (incl. their plans) name the group's FINAL running DAG
    for r in receipts:
        assert r.running_dag in bat.running
        assert r.plan.merged_name == r.running_dag


def test_custom_batch_strategy_must_implement_batch_match():
    class HalfBatch(MergeStrategy):
        name = "half-batch"
        supports_batch = True  # opts in but forgets batch_match

        def plan(self, mgr, df, merged_name, sigs=None):
            raise AssertionError("unused")

    mgr = ReuseManager(strategy=HalfBatch())
    a = flow("a").source("urban").then("p").sink("s").build()
    b = flow("b").source("urban").then("p").sink("s").build()
    with pytest.raises(NotImplementedError, match="batch_match"):
        mgr.submit_many([a, b])


def test_submit_many_cross_batch_dedup():
    """Identical flows inside one batch: the second creates nothing."""
    session = ReuseSession()
    batch = session.submit_many([_linear("t1"), _linear("t2")])
    assert batch.receipts[0].num_created == 5
    assert batch.receipts[1].num_created == 0
    assert batch.receipts[1].num_reused == 5
    assert session.running_task_count == 5
    assert all(ev.running_dag == batch.running_dags[0] for ev in batch.receipts)


def test_submit_many_disjoint_and_duplicate_names():
    session = ReuseSession()
    a = flow("a").source("urban").then("p").sink("s")
    b = flow("b").source("meter").then("p").sink("s")
    batch = session.submit_many([a, b])
    assert len(batch.running_dags) == 2  # no shared sources → separate DAGs
    with pytest.raises(DataflowError):
        session.submit_many([flow("c").source("taxi").sink("s")] * 2)


def test_submit_many_journal_replays():
    mgr = ReuseManager(strategy="signature")
    mgr.submit_many([d.copy() for d in riot_workload()[:6]])
    clone = ReuseManager.replay(mgr.journal)
    clone.verify()
    assert clone.running_task_count == mgr.running_task_count


def test_submit_many_none_strategy_falls_back():
    mgr = ReuseManager(strategy="none")
    receipts = mgr.submit_many([_linear("a").build(), _linear("b").build()])
    assert all(r.num_reused == 0 for r in receipts)
    assert mgr.running_task_count == 10


# -- trace replay over the API -------------------------------------------------


def test_trace_replay_through_session():
    dags = riot_workload()
    session = ReuseSession(check_invariants=True)
    events = seq_trace(dags, seed=3)
    seen = [ev.name for ev, _ in replay(session, dags, events)]
    assert len(seen) == len(events)
    assert session.running_task_count == 0  # seq trace fully drains


# -- strategy registry ---------------------------------------------------------


def test_registry_lists_builtins_and_rejects_unknown():
    assert {"signature", "faithful", "none"} <= set(available_strategies())
    with pytest.raises(ValueError, match="unknown strategy"):
        ReuseManager(strategy="nope")


def test_custom_strategy_pluggable():
    class GreedyNone(MergeStrategy):
        """A custom engine (here: clone of no-reuse) used without registration."""

        name = "greedy-none"
        reuses = False

        def plan(self, mgr, df, merged_name, sigs=None):
            from repro.core.merge import MergePlan

            plan = MergePlan(submitted_name=df.name, merged_name=merged_name, overlapping=[])
            for tid in df.topological_order():
                plan.created[tid] = mgr._mint_task_id(df.tasks[tid].type)
            for s_up, s_down in df.streams:
                plan.new_streams_internal.append((plan.created[s_up], plan.created[s_down]))
            return plan

    session = ReuseSession(strategy=GreedyNone())
    assert session.strategy == "greedy-none"
    session.submit(_linear("a"))
    session.submit(_linear("b"))
    assert session.running_task_count == 10  # never reuses
    # (no verify(): like "none", a no-reuse engine deliberately violates C2)


def test_session_restore_from_journal(tmp_path):
    path = str(tmp_path / "j.jsonl")
    session = ReuseSession(journal_path=path)
    session.submit(_linear("a"))
    session.submit(_linear("b", extra="avg"))
    session.remove("a")
    n_lines = sum(1 for _ in open(path))
    restored = ReuseSession.restore(path)
    restored.verify()
    assert restored.running_task_count == session.running_task_count
    # the satellite fix: restoring must not duplicate the journal file
    assert sum(1 for _ in open(path)) == n_lines
