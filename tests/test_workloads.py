"""Workload generators match the paper's §5.1 statistics, and the trace
benchmark lands inside the paper's reported reduction bands."""
import pytest

from repro.workloads import opmw_workload, riot_workload, rw_trace, seq_trace
from repro.workloads.opmw import workload_stats


def test_opmw_stats_match_paper():
    s = workload_stats(opmw_workload())
    assert s["dags"] == 35
    assert s["total_tasks"] == 471          # published: 471
    assert 200 <= s["unique_abstract"] <= 235   # published: 219
    assert 255 <= s["equiv_classes"] <= 295     # Reuse peak ≈ 274
    assert s["min_size"] >= 2 and s["max_size"] <= 38


def test_riot_stats_match_paper():
    dags = riot_workload()
    s = workload_stats(dags)
    assert s["dags"] == 21
    assert s["total_tasks"] == 138          # published: 138
    assert 4 <= s["min_size"] and s["max_size"] <= 8
    types = {t.type for d in dags for t in d.tasks.values()}
    assert len(types) == 19                  # published: 19 distinct
    srcs = {t.type for d in dags for t in d.tasks.values() if t.is_source}
    assert len(srcs) == 3


def test_traces_well_formed():
    dags = riot_workload()
    names = {d.name for d in dags}
    for events in (seq_trace(dags, 0), rw_trace(dags, 1)):
        present = set()
        for ev in events:
            assert ev.name in names
            if ev.op == "add":
                assert ev.name not in present
                present.add(ev.name)
            else:
                assert ev.name in present
                present.discard(ev.name)
        assert not present  # both traces fully drain


@pytest.mark.slow
def test_reduction_bands():
    """Peak task reduction within the paper's 38–46 % (±4 % tolerance)."""
    from benchmarks.workload_traces import run_trace_with_pause, summarize

    for dags in (opmw_workload(), riot_workload()):
        events = seq_trace(dags, seed=3)
        s = summarize(run_trace_with_pause(dags, events), drain_start=len(dags))
        assert 0.34 <= s["peak_task_reduction"] <= 0.50, s
        assert s["peak_core_reduction"] >= 0.30, s
        assert s["frac_tasks_shared"] >= 0.08, s
        # the §5.3 pause crossover exists in the drain phase
        assert s["crossover_steps"] >= 1
