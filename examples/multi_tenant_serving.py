"""Multi-tenant LM serving with collaborative reuse — the paper's merge
algorithms as a first-class serving feature.

Six tenants serve adapters of the same base model over three request
streams. With reuse, each shared backbone prefix runs ONCE per stream;
tenants keep their own fine-tuned stages/adapters. Removal unmerges
without touching the surviving tenants.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
from repro.serve import ReuseServing, TenantPipeline


def main():
    for strategy in ("none", "signature"):
        rs = ReuseServing(strategy=strategy, base_batch=4)
        for i in range(6):
            rs.add_tenant(
                TenantPipeline(
                    tenant=f"tenant{i}",
                    stream=("urban", "meter", "taxi")[i % 3],
                    model="base-7b@v1",
                    shared_stages=3,     # lower 3 stage groups from the base ckpt
                    n_stages=4,          # top stage is tenant-fine-tuned
                    d=64,
                    layers_per_stage=4,
                    adapter=f"adapter-{i}",
                )
            )
        rs.run(5)
        s = rs.stats()
        label = "Default (no reuse)" if strategy == "none" else "Reuse    "
        print(f"{label}: running_tasks={s['running_tasks']:3d} "
              f"deployed_cost={s['deployed_cost']:.1f}")
        if strategy == "signature":
            print("\nper-tenant outputs (identical to the Default run):")
            for t in rs.tenants:
                print(" ", t, rs.tenant_output(t))
            rs.remove_tenant("tenant3")
            rs.run(2)
            print(f"\nafter removing tenant3: running_tasks="
                  f"{rs.stats()['running_tasks']}, others keep streaming")


if __name__ == "__main__":
    main()
