"""Multi-tenant serving with collaborative reuse — the paper's merge
algorithms as an admission-control superpower.

Starts a ServeFrontend (slot-based admission over one ReuseSession) on a
local socket and drives it with ServeClient exactly as external tenants
would: alice and bob submit overlapping RIoT dataflows, and because a
submission that merges into running work is charged only its *new*
segments, the same slot pool carries far more than its nominal capacity.
The run ends with a removal freeing slots that immediately admit queued
work in weighted fair-share order.

    PYTHONPATH=src python examples/multi_tenant_serving.py

(The older library-level integration — ReuseServing/TenantPipeline, which
merges LM adapter pipelines in-process without a server — is still there:
``from repro.serve import ReuseServing``.)
"""
from repro.serve import ServeClient, ServeFrontend, TenantQuota
from repro.workloads import riot_workload, tenant_copy


def main():
    pool = riot_workload()
    frontend = ServeFrontend(
        slots=48,
        strategy="signature",
        backend="dryrun",
        default_quota=TenantQuota(max_slots=48, max_pending=8),
    )
    host, port = frontend.start()
    print(f"frontend serving on {host}:{port} with {frontend.slots} slots\n")

    with frontend, ServeClient((host, port)) as alice, ServeClient((host, port)) as bob:
        # The two tenants submit the same first six RIoT dataflows — bob's
        # copies merge into alice's running work and cost (almost) nothing.
        for df in pool[:6]:
            ra = alice.submit("alice", tenant_copy(df, "alice"))
            rb = bob.submit("bob", tenant_copy(df, "bob"))
            print(
                f"{df.name:>10}:  alice {ra['status']} ({ra.get('slots_charged', '-')} slots)"
                f"   bob {rb['status']} ({rb.get('slots_charged', '-')} slots, "
                f"{rb.get('reused', 0)} reused)"
            )

        alice.step(5)  # stream some batches; cost is billed per tenant
        stats = alice.stats()
        print(
            f"\npool: {stats['slots_used']}/{stats['slots']} slots used, "
            f"naive (no-reuse) demand {stats['naive_slots']} slots "
            f"→ effective capacity {stats['effective_capacity']:.2f}×"
        )
        for tenant, ledger in sorted(stats["ledgers"].items()):
            print(
                f"  {tenant}: holds {ledger['slots_held']} slots, "
                f"saved {ledger['slots_saved']} by reuse, "
                f"billed {ledger['cost_total']:.3f} core·steps"
            )

        # Removal unmerges without touching the other tenant, frees the
        # removed submission's slots, and admits queued work fair-share.
        out = bob.remove("bob", f"bob/{pool[0].name}")
        print(
            f"\nremoved bob/{pool[0].name}: freed {out['slots_freed']} slots; "
            f"alice/{pool[0].name} keeps streaming"
        )
        print(f"final: {alice.status()['dataflows']} dataflows on the pool")


if __name__ == "__main__":
    main()
