"""Batched generation with the serving engine (prefill + slot-based
continuous decode) on a reduced config of any assigned architecture.

    PYTHONPATH=src python examples/generate.py --arch zamba2-2.7b
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=sorted(configs.ALIASES))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mem_len = {"vlm": cfg.num_image_tokens, "audio": cfg.encoder_seq}.get(cfg.family, 0)
    eng = ServeEngine(cfg, params, slots=3, max_len=128)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16))).astype(np.int32)
        mem = rng.standard_normal((mem_len, cfg.d_model)).astype(np.float32) if mem_len else None
        eng.submit(Request(rid, prompt, max_new=args.max_new, memory=mem))

    for r in sorted(eng.run(), key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{r.prompt_len} toks] → {r.tokens}")


if __name__ == "__main__":
    main()
