"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the deterministic token pipeline, with async
checkpointing and crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the assignment's "train ~100M model for a few hundred steps"
example; the same launch path scales to the production mesh (see
repro/launch/train.py --help).
"""
import argparse

from repro import configs
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quick_ckpt")
    args = ap.parse_args()

    # ~100M params: qwen3 family at width 512 / 8 layers, full vocab
    import repro.configs.qwen3_4b as q3

    cfg = q3.CONFIG.replace(
        name="qwen3-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        dtype="float32",
        param_dtype="float32",
    )
    total, _ = cfg.param_count()
    print(f"training {cfg.name}: {total/1e6:.0f}M params")

    # reuse the production train loop with an inline config
    import repro.launch.train as T

    class _Cfgs:
        @staticmethod
        def get_smoke_config(_):
            return cfg

        @staticmethod
        def get_config(_):
            return cfg

    T.configs = _Cfgs  # inject
    T.main([
        "--arch", "inline", "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "1e-3", "--warmup", "30",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
