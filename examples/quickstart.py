"""Quickstart: the paper's technique in 40 lines.

Two IoT dataflows sharing a preprocessing prefix are submitted; the
Reuse manager merges them so the shared prefix runs once; removing one
unmerges without disturbing the other. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.graph import Dataflow, Task
from repro.runtime.system import StreamSystem


def make_dataflow(name: str, extra_op: str) -> Dataflow:
    """urban sensor → parse → kalman → <extra_op> → store"""
    df = Dataflow(name)
    src = df.add_task(Task.make(f"{name}/src", "urban", "SOURCE"))
    parse = df.add_task(Task.make(f"{name}/parse", "senml_parse", {"schema": "urban"}))
    kalman = df.add_task(Task.make(f"{name}/kalman", "kalman", {"q": 0.1}))
    extra = df.add_task(Task.make(f"{name}/{extra_op}", extra_op, {"w": 8}))
    sink = df.add_task(Task.make(f"{name}/sink", "store", "SINK"))
    df.add_stream(src.id, parse.id)
    df.add_stream(parse.id, kalman.id)
    df.add_stream(kalman.id, extra.id)
    df.add_stream(extra.id, sink.id)
    return df


def main():
    system = StreamSystem(strategy="signature", base_batch=8)

    a = system.submit(make_dataflow("alice", "win"))
    print(f"alice: created {a.num_created} tasks, reused {a.num_reused}")

    b = system.submit(make_dataflow("bob", "avg"))
    print(f"bob:   created {b.num_created} tasks, reused {b.num_reused} "
          f"(the urban→parse→kalman prefix)")

    print(f"running tasks: {system.running_task_count} "
          f"(two 5-task dataflows would be 10 without reuse)")

    system.run(5)
    print("alice output:", system.sink_digests("alice"))
    print("bob   output:", system.sink_digests("bob"))

    system.remove("alice")
    system.run(2)
    print("after removing alice, bob still streams:", system.sink_digests("bob"))


if __name__ == "__main__":
    main()
