"""Quickstart: the paper's technique through the `repro.api` facade.

Two IoT dataflows sharing a preprocessing prefix are submitted; the
Reuse manager merges them so the shared prefix runs once; removing one
unmerges without disturbing the other. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ReuseSession, flow


def make_flow(name: str, extra_op: str):
    """urban sensor → parse → kalman → <extra_op> → store"""
    return (
        flow(name)
        .source("urban")
        .then("senml_parse", schema="urban")
        .then("kalman", q=0.1)
        .then(extra_op, w=8)
        .sink("store")
    )


def main():
    session = ReuseSession(strategy="signature", execute=True, base_batch=8)
    session.on_merge(
        lambda ev: print(f"  [hook] {ev.name} merged into {ev.running_dag} "
                         f"(reused {ev.num_reused}, created {ev.num_created})")
    )

    a = session.submit(make_flow("alice", "win"))
    print(f"alice: created {a.num_created} tasks, reused {a.num_reused}")

    b = session.submit(make_flow("bob", "avg"))
    print(f"bob:   created {b.num_created} tasks, reused {b.num_reused} "
          f"(the urban→parse→kalman prefix)")

    stats = session.stats()
    print(f"running tasks: {stats.running_task_count} "
          f"(two 5-task dataflows would be 10 without reuse — "
          f"{stats.task_reduction:.0%} saved)")

    session.run(5)
    print("alice output:", session.sink_digests("alice"))
    print("bob   output:", session.sink_digests("bob"))

    session.remove("alice")
    session.run(2)
    print("after removing alice, bob still streams:", session.sink_digests("bob"))

    # Batched arrivals: overlapping submissions are planned together —
    # one signature pass, one merged-DAG rebuild (§4.1 at scale).
    batch = session.submit_many(
        [make_flow(f"tenant{i}", "win") for i in range(3)]
    )
    print(f"batch of 3 tenants: created {batch.num_created}, "
          f"reused {batch.num_reused}, running DAGs {batch.running_dags}")


if __name__ == "__main__":
    main()
