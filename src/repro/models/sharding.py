"""Logical-axis sharding: MaxText-style rule tables + divisibility-aware
parameter-spec inference.

Two halves:

* **Activation constraints** — model code calls ``constrain(x, "hidden")``
  with a *logical* name; a rule table active in context maps it to a
  ``PartitionSpec``. With no rules active (CPU unit tests) it is identity,
  so the same model code runs everywhere.

* **Parameter specs** — ``infer_param_specs`` walks a params pytree and
  assigns a spec per leaf from its *role* (trailing path key: ``wq``,
  ``embed``…) and its shape. Every mesh-axis assignment is divisibility-
  checked; a dim that does not divide is replicated instead of erroring,
  so one rule table covers all 10 architectures (e.g. granite's kv=1 head
  cannot take the 16-way model axis — its head_dim can).

Stacked layers (``lax.scan`` pytrees with a leading ``L`` dim) are handled
by indexing roles from the *end* of the shape.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any

# Axis *kinds* used by role tables; resolved to concrete mesh axes by rules.
MODEL = "model"    # tensor-parallel axis
FSDP = "fsdp"      # fully-sharded-data-parallel axis (weights over data)
DATA = "data"      # batch axis (activations)
NONE = None


class AxisRules:
    """Maps axis kinds → concrete mesh axis names (+ sizes for checks)."""

    def __init__(
        self,
        mesh_sizes: Dict[str, int],
        *,
        model: Optional[str] = "model",
        fsdp: Optional[str] = "data",
        data: Sequence[str] = ("data",),
        extra_activation_rules: Optional[Dict[str, P]] = None,
    ):
        self.mesh_sizes = dict(mesh_sizes)
        self.model = model
        self.fsdp = fsdp
        self.data = tuple(a for a in data if a in mesh_sizes)
        # batch axes: pod (if present) + data
        if "pod" in mesh_sizes and "pod" not in self.data:
            self.data = ("pod",) + self.data
        self.activation_rules: Dict[str, P] = {
            "hidden": P(self.data, None, None),          # (B, S, D)
            "logits": P(self.data, None, self.model),    # (B, S, V)
            "logits_last": P(self.data, self.model),     # (B, V)
            "decode_hidden": P(self.data, None, None),   # (B, 1, D)
        }
        if extra_activation_rules:
            self.activation_rules.update(extra_activation_rules)
        # per-role table overrides for §Perf experiments; keys may be
        # "role" or "role#ndim" (ndim-specific, e.g. stacked MoE experts)
        self.role_overrides: Dict[str, RoleTable] = {}
        # the live mesh (set by launch.specs.make_rules) — needed by
        # shard_map-based layers (expert-parallel MoE)
        self.mesh = None

    def size(self, kind: Optional[str]) -> int:
        if kind is None:
            return 1
        axis = {"model": self.model, "fsdp": self.fsdp}.get(kind, kind)
        if axis is None:
            return 1
        return self.mesh_sizes.get(axis, 1)

    def axis(self, kind: Optional[str]) -> Optional[str]:
        if kind is None:
            return None
        return {"model": self.model, "fsdp": self.fsdp}.get(kind, kind)


_state = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x: jnp.ndarray, logical: str) -> jnp.ndarray:
    """Apply a sharding constraint if a rule table is active; else identity."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.activation_rules.get(logical)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ======================================================================
# Parameter-spec inference
# ======================================================================
#
# Role tables: per trailing-dim position (negative index), an ordered list
# of candidate axis kinds. The first candidate whose size divides the dim
# and whose mesh axis is not already used in this spec wins; otherwise the
# dim is replicated.
RoleTable = Dict[int, List[Optional[str]]]

_ROLES: Dict[str, RoleTable] = {
    # embeddings / head
    "embed":   {-2: [MODEL], -1: [FSDP]},           # (V, D) vocab-parallel
    "head":    {-2: [FSDP], -1: [MODEL]},           # (D, V)
    # GQA attention
    "wq":      {-3: [FSDP], -2: [MODEL], -1: [NONE]},       # (D, H, hd)
    "wk":      {-3: [FSDP], -2: [MODEL], -1: [MODEL]},      # (D, KV, hd); hd fallback
    "wv":      {-3: [FSDP], -2: [MODEL], -1: [MODEL]},
    "wo":      {-3: [MODEL], -2: [NONE], -1: [FSDP]},       # (H, hd, D)
    "bq":      {-2: [MODEL], -1: [NONE]},
    "bk":      {-2: [MODEL], -1: [MODEL]},
    "bv":      {-2: [MODEL], -1: [MODEL]},
    # dense FFN
    "w_gate":  {-2: [FSDP], -1: [MODEL]},           # (D, F)
    "w_in":    {-2: [FSDP], -1: [MODEL]},
    "w_out":   {-2: [MODEL], -1: [FSDP]},           # (F, D)
    # MoE experts (E, D, F) / (E, F, D); router (D, E)
    "we_gate": {-3: [NONE], -2: [FSDP], -1: [MODEL]},
    "we_in":   {-3: [NONE], -2: [FSDP], -1: [MODEL]},
    "we_out":  {-3: [NONE], -2: [MODEL], -1: [FSDP]},
    "router":  {-2: [FSDP], -1: [NONE]},
    # MLA (DeepSeek-V2)
    "w_dq":    {-2: [FSDP], -1: [MODEL]},           # (D, r_q)
    "w_uq":    {-3: [FSDP], -2: [MODEL], -1: [NONE]},  # (r_q, H, hd)
    "w_dkv":   {-2: [FSDP], -1: [NONE]},            # (D, r_kv) — latent replicated
    "w_krope": {-2: [FSDP], -1: [NONE]},
    "w_uk":    {-3: [NONE], -2: [MODEL], -1: [NONE]},  # (r_kv, H, hd)
    "w_uv":    {-3: [NONE], -2: [MODEL], -1: [NONE]},
    # Mamba2
    "conv_w":   {-1: [MODEL]},                      # (d_conv, channels)
    # xLSTM
    "w_qkv":    {-2: [FSDP], -1: [MODEL]},
    "w_up":     {-2: [FSDP], -1: [MODEL]},
    "w_down":   {-2: [MODEL], -1: [FSDP]},
    "w_gates":  {-2: [FSDP], -1: [MODEL]},
    "r_gates":  {-2: [NONE], -1: [NONE]},
}

# path keys whose subtree is always replicated (tiny tensors)
_REPLICATED = re.compile(
    r"(norm|scale|bias|^gate$|^b_|_b$|alpha|a_log|d_skip|^gn$|^len$)"
)


def _leaf_role(path: Tuple[Any, ...]) -> str:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    return str(keys[-1]) if keys else ""


def _spec_for(role: str, shape: Tuple[int, ...], rules: AxisRules) -> P:
    ndim = len(shape)
    table = rules.role_overrides.get(f"{role}#{ndim}") or rules.role_overrides.get(role)
    if table is None:
        table = _ROLES.get(role)
    out: List[Optional[str]] = [None] * ndim
    used: set = set()
    if table is None:
        # generic fallback: last dim model, second-to-last fsdp (≥2D only)
        table = {-1: [MODEL], -2: [FSDP]} if ndim >= 2 else {}
    for rel, candidates in sorted(table.items()):
        idx = ndim + rel
        if idx < 0:
            continue
        for kind in candidates:
            if kind is None:
                break
            axis = rules.axis(kind)
            size = rules.size(kind)
            if axis is None or axis in used or size <= 1:
                continue
            if shape[idx] % size == 0:
                out[idx] = axis
                used.add(axis)
                break
    return P(*out)


def infer_param_specs(params: PyTree, rules: AxisRules) -> PyTree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""

    def leaf_spec(path, leaf):
        role = _leaf_role(path)
        shape = tuple(leaf.shape)
        if len(shape) == 0 or _REPLICATED.search(role):
            return P()
        return _spec_for(role, shape, rules)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def cache_specs(cache: PyTree, rules: AxisRules) -> PyTree:
    """Specs for serve-time KV/state caches.

    Caches carry a batch dim at position -4/-3/-2 depending on family; we
    shard the *batch* dim over the data axes and the kv-head/head dim over
    model when divisible. Identified by shape heuristics: the first dim
    whose size equals a multiple of the data-axis product is batch-like.
    Conservative rule: shard dim 1 (batch for stacked (L,B,...) caches, or
    dim 0 for unstacked) over data; the kv-head dim (ndim-2) over model
    when divisible, else the trailing head_dim.
    """
    data_axes = rules.data
    dsize = 1
    for a in data_axes:
        dsize *= rules.mesh_sizes.get(a, 1)
    msize = rules.size(MODEL)
    maxis = rules.axis(MODEL)

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        role = _leaf_role(path)
        if len(shape) == 0:
            return P()
        out: List[Any] = [None] * len(shape)
        # batch dim: first dim (unstacked) or second (stacked (L,B,...))
        bdim = 1 if len(shape) >= 3 else 0
        if shape[bdim] % dsize == 0 and dsize > 1:
            out[bdim] = data_axes if len(data_axes) > 1 else data_axes[0]
        if maxis and msize > 1 and len(shape) >= 3 and role not in ("len",):
            for cand in (len(shape) - 2, len(shape) - 1):
                if cand > bdim and shape[cand] % msize == 0:
                    out[cand] = maxis
                    break
        return P(*out)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def named_shardings(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def bytes_per_device(tree: PyTree, spec_tree: PyTree, rules: AxisRules) -> int:
    """Napkin-math per-device bytes for a sharded pytree (planning aid)."""

    def leaf_bytes(leaf, spec):
        n = 1
        for i, d in enumerate(leaf.shape):
            axes = spec[i] if i < len(spec) else None
            if axes is None:
                sz = 1
            elif isinstance(axes, tuple):
                sz = 1
                for a in axes:
                    sz *= rules.mesh_sizes.get(a, 1)
            else:
                sz = rules.mesh_sizes.get(axes, 1)
            n *= -(-d // sz)
        return n * jnp.dtype(leaf.dtype).itemsize

    leaves = jax.tree.leaves(
        jax.tree.map(leaf_bytes, tree, spec_tree, is_leaf=lambda x: hasattr(x, "shape"))
    )
    return int(sum(leaves))
