"""Model zoo covering the 10 assigned architectures (6 families)."""
from .config import MLAConfig, MoEConfig, ModelConfig, SSMConfig, XLSTMConfig
from .transformer import abstract_params, forward, init_params
from .decode import abstract_cache, decode_step, encode, init_cache, prefill

__all__ = [
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "XLSTMConfig",
    "abstract_cache",
    "abstract_params",
    "decode_step",
    "encode",
    "forward",
    "init_cache",
    "init_params",
    "prefill",
]
