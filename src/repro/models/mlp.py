"""Feed-forward blocks: SwiGLU / squared-ReLU / GeLU MLPs and Mixture of
Experts with scatter-based dispatch.

MoE dispatch deliberately avoids the GShard one-hot einsum ('td,tec->ecd'),
whose FLOPs (T·E·C·D) dwarf the expert compute for large E (DeepSeek: 160
experts ⇒ ~1000× the useful FLOPs). Instead tokens are scattered into a
static (E·C, D) buffer by their (expert, position-in-expert) slot and
gathered back — O(T·k·D) data movement, zero matmul overhead, static
shapes, and a clean expert-sharded layout for pjit.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import sharding
from .common import dense_init, shard_map_compat


def act_fn(name: str):
    if name == "swiglu":
        return None  # handled structurally (gate * up)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def mlp_params(key_gen, d_model: int, d_ff: int, activation: str, dtype) -> Dict[str, Any]:
    p = {
        "w_up": dense_init(key_gen(), (d_model, d_ff), dtype),
        "w_down": dense_init(key_gen(), (d_ff, d_model), dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = dense_init(key_gen(), (d_model, d_ff), dtype)
    return p


def mlp(p: Dict[str, Any], x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"])) * jnp.einsum(
            "...d,df->...f", x, p["w_up"]
        )
    else:
        h = act_fn(activation)(jnp.einsum("...d,df->...f", x, p["w_up"]))
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# -- Mixture of Experts -----------------------------------------------------------

def moe_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    m = cfg.moe
    D, F, E = cfg.d_model, m.expert_ff, m.num_experts
    p: Dict[str, Any] = {
        "router": dense_init(key_gen(), (D, E), dtype),
        "w_up": dense_init(key_gen(), (E, D, F), dtype, fan_in=D),
        "w_down": dense_init(key_gen(), (E, F, D), dtype, fan_in=F),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(key_gen(), (E, D, F), dtype, fan_in=D)
    if m.num_shared:
        p["shared"] = mlp_params(
            key_gen, D, F * m.num_shared, cfg.activation, dtype
        )
    return p


def _positions_within_group(flat_e: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """pos[i] = #{j < i : flat_e[j] == flat_e[i]} — the capacity slot rank.

    Sort-based: O(N log N) compute, O(N) memory. The one-hot+cumsum
    formulation materializes an (N, E) tensor — 4 TB at 1M tokens × 160
    experts — which dominated the MoE prefill footprint.
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_groups,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def _expert_ffn(p: Dict[str, Any], xe: jnp.ndarray, activation: str) -> jnp.ndarray:
    """xe: (E, C, D) -> (E, C, D), batched over experts."""
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", xe, p["w_up"]
        )
    else:
        h = act_fn(activation)(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_layer(p: Dict[str, Any], x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). Scatter-based top-k dispatch with capacity;
    dispatches to the expert-parallel shard_map path when MOE_IMPL == "ep"
    and a mesh is active (§Perf hillclimb)."""
    rules = sharding.current_rules()
    if MOE_IMPL == "ep" and rules is not None and rules.mesh is not None:
        return moe_layer_ep(p, x, cfg, rules)
    m = cfg.moe
    if MOE_DECODE == "sparse" and x.shape[0] * x.shape[1] * m.top_k <= m.num_experts:
        B, S, D = x.shape
        return _moe_decode_sparse(p, x.reshape(B * S, D), cfg).reshape(B, S, D)
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    C = max(int(T * K / E * m.capacity_factor), 4)

    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert (sort-based —
    # no (T·K, E) one-hot materialization)
    flat_e = idx.reshape(-1)  # (T*K,)
    pos = _positions_within_group(flat_e, E)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)  # dropped → trash row

    token_id = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[token_id])
    expert_in = buf[: E * C].reshape(E, C, D)
    expert_in = sharding.constrain(expert_in, "moe_experts")
    expert_out = _expert_ffn(p, expert_in, cfg.activation)
    expert_out = sharding.constrain(expert_out, "moe_experts")
    flat_out = jnp.concatenate(
        [expert_out.reshape(E * C, D), jnp.zeros((1, D), expert_out.dtype)], axis=0
    )
    y_tk = flat_out[slot] * gate_vals.reshape(-1)[:, None].astype(expert_out.dtype)
    y = y_tk.reshape(T, K, D).sum(axis=1)

    if m.num_shared:
        y = y + mlp(p["shared"], xt, cfg.activation)
    return y.reshape(B, S, D)


def moe_aux_loss(p: Dict[str, Any], x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style): E·Σ f_e·p_e."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), axis=0)
    pmean = probs.mean(axis=0)
    return m.num_experts * jnp.sum(f * pmean)


# -- Expert-parallel MoE (shard_map) -----------------------------------------------
#
# §Perf hillclimb (EXPERIMENTS.md): the GSPMD scatter dispatch cross-shards
# the (E·C, D) buffer, inserting all-reduces over the data axis that
# dominate the collective term at 236B scale. Expert parallelism makes the
# dispatch *local*: experts shard over the "data" axis (each shard owns
# E/n_ep experts whole), tokens move via one all_to_all each way, and the
# F-dim stays sharded over "model" with a single psum after w_down.
# Traffic per layer ≈ T·K·cf·D each way vs. re-gathering E·3DF weights.

MOE_IMPL = "dense"  # "dense" (GSPMD scatter) | "ep" (shard_map all_to_all)


def _moe_ep_body(xt, router, w_gate, w_up, w_down, shared, cfg, n_ep, axis):
    """Per-shard body under shard_map. xt: (T_loc, D) local tokens."""
    m = cfg.moe
    T, D = xt.shape
    E, K = m.num_experts, m.top_k
    E_loc = E // n_ep
    c_send = max(int(T * K / n_ep * m.capacity_factor), 4)
    c_loc = max(int(T * K / E_loc * m.capacity_factor), 4)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)          # (T, K) global expert ids
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- outbound: pack per-destination-shard send buffers ---------------
    dest = (idx // E_loc).reshape(-1)                  # (T·K,) owning shard
    local_e = (idx % E_loc).reshape(-1)
    pos = _positions_within_group(dest, n_ep)
    keep = pos < c_send
    slot = jnp.where(keep, dest * c_send + pos, n_ep * c_send)
    token_id = jnp.repeat(jnp.arange(T), K)
    send_x = jnp.zeros((n_ep * c_send + 1, D), xt.dtype).at[slot].set(xt[token_id])
    send_e = jnp.zeros((n_ep * c_send + 1,), jnp.int32).at[slot].set(local_e + 1)
    recv_x = jax.lax.all_to_all(
        send_x[: n_ep * c_send].reshape(n_ep, c_send, D), axis, 0, 0
    )
    recv_e = jax.lax.all_to_all(
        send_e[: n_ep * c_send].reshape(n_ep, c_send), axis, 0, 0
    )

    # --- local dispatch into the shard's own experts ----------------------
    rows = n_ep * c_send
    rx = recv_x.reshape(rows, D)
    rl = recv_e.reshape(rows) - 1                      # −1 = empty slot
    valid = rl >= 0
    pos2 = _positions_within_group(jnp.where(valid, rl, E_loc), E_loc + 1)
    keep2 = valid & (pos2 < c_loc)
    slot2 = jnp.where(keep2, rl * c_loc + pos2, E_loc * c_loc)
    buf = jnp.zeros((E_loc * c_loc + 1, D), xt.dtype).at[slot2].set(rx)
    expert_in = buf[: E_loc * c_loc].reshape(E_loc, c_loc, D)
    expert_out = _expert_ffn(
        {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        if w_gate is not None
        else {"w_up": w_up, "w_down": w_down},
        expert_in,
        cfg.activation,
    )  # (E_loc, c_loc, D) — PARTIAL over the model axis (w_down F-sharded)

    # --- return path (still partial sums; psum deferred to the end) -------
    back_rows = jnp.concatenate(
        [expert_out.reshape(E_loc * c_loc, D), jnp.zeros((1, D), expert_out.dtype)], 0
    )[slot2]
    back = jax.lax.all_to_all(back_rows.reshape(n_ep, c_send, D), axis, 0, 0)
    y_tk = jnp.concatenate(
        [back.reshape(n_ep * c_send, D), jnp.zeros((1, D), back.dtype)], 0
    )[slot]
    y = (y_tk * gate_vals.reshape(-1)[:, None].astype(y_tk.dtype)).reshape(T, K, D).sum(1)

    if m.num_shared:
        y = y + mlp(shared, xt, cfg.activation)        # also partial over model
    return jax.lax.psum(y, "model")


def moe_layer_ep(p: Dict[str, Any], x: jnp.ndarray, cfg, rules) -> jnp.ndarray:
    """Expert-parallel MoE: dispatch via shard_map over the data axis."""
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    dax = rules.data          # batch axes, e.g. ("data",) or ("pod", "data")
    ep_axis = dax[-1]         # experts shard over the innermost data axis
    n_ep = rules.mesh_sizes[ep_axis]
    B, S, D = x.shape

    w_gate = p.get("w_gate")
    shared = p.get("shared")
    batch_spec = dax if len(dax) > 1 else dax[0]

    def body(xl, router, wg, wu, wd, sh):
        T_loc = xl.shape[0] * xl.shape[1]
        y = _moe_ep_body(
            xl.reshape(T_loc, D), router, wg, wu, wd, sh, cfg, n_ep, ep_axis
        )
        return y.reshape(xl.shape)

    # shared-expert mlp: w_up/w_gate (D, F): F over model; w_down (F, D)
    def _shared_specs(sh):
        return {
            k: (P("model", None) if k == "w_down" else P(None, "model"))
            for k in sh
        }

    in_specs = (
        P(batch_spec, None, None),
        P(None, None),
        P(ep_axis, None, "model") if w_gate is not None else None,
        P(ep_axis, None, "model"),
        P(ep_axis, "model", None),
        _shared_specs(shared) if shared is not None else None,
    )
    args = (x, p["router"], w_gate, p["w_up"], p["w_down"], shared)
    # drop None args (shard_map specs must match the pytree)
    keep = [i for i, a in enumerate(args) if a is not None]
    f_args = tuple(args[i] for i in keep)
    f_specs = tuple(in_specs[i] for i in keep)

    def wrapper(*packed):
        full = [None] * len(args)
        for i, a in zip(keep, packed):
            full[i] = a
        return body(*full)

    return shard_map_compat(
        wrapper,
        mesh=mesh,
        in_specs=f_specs,
        out_specs=P(batch_spec, None, None),
        check_vma=False,
    )(*f_args)


# -- Sparse MoE decode (§Perf hillclimb: mixtral long_500k) --------------------------
#
# H: at tiny decode batches the capacity-buffer path touches ALL E experts'
# weights; gathering only the top-k experts' matrices via dynamic slices
# reads K/E of the weight bytes. Used when T·K ≤ E (else dense wins).

MOE_DECODE = "dense"  # "dense" | "sparse"


def _moe_decode_sparse(p: Dict[str, Any], xt: jnp.ndarray, cfg) -> jnp.ndarray:
    m = cfg.moe
    T, D = xt.shape
    K = m.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def token_out(x_t, idx_t, gv_t):
        ys = []
        for i in range(K):  # K static & tiny
            e = idx_t[i]
            wu = jax.lax.dynamic_index_in_dim(p["w_up"], e, 0, keepdims=False)
            wd = jax.lax.dynamic_index_in_dim(p["w_down"], e, 0, keepdims=False)
            if "w_gate" in p:
                wg = jax.lax.dynamic_index_in_dim(p["w_gate"], e, 0, keepdims=False)
                h = jax.nn.silu(x_t @ wg) * (x_t @ wu)
            else:
                h = act_fn(cfg.activation)(x_t @ wu)
            ys.append(gv_t[i].astype(x_t.dtype) * (h @ wd))
        return sum(ys)

    y = jax.vmap(token_out)(xt, idx, gate_vals)
    if m.num_shared:
        y = y + mlp(p["shared"], xt, cfg.activation)
    return y
