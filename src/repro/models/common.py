"""Shared model primitives: norms, RoPE, initializers.

All functions are pure jnp/lax — they compose under jit/pjit/shard_map and
under ``jax.eval_shape`` (abstract init for the multi-pod dry-run, which
never allocates full-size parameters).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """``jax.shard_map`` across JAX releases.

    Newer JAX exposes it at the top level with ``axis_names``/``check_vma``;
    older releases ship ``jax.experimental.shard_map.shard_map`` whose
    equivalents are ``auto`` (the complement of the manual axes) and
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, **kwargs)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x: jnp.ndarray, p: Dict[str, jnp.ndarray], kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_params(kind: str, dim: int, dtype) -> Dict[str, jnp.ndarray]:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


# -- RoPE ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0
) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32. Split-half convention."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# -- initializers ----------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None) -> jnp.ndarray:
    """Truncated-normal scaled by 1/sqrt(fan_in) (first dim by default)."""
    fi = fan_in if fan_in is not None else shape[0]
    std = fi ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Splits a PRNG key on demand (keeps init code linear)."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
