"""Model assembly for all six families: parameter init (eval_shape-able for
the allocation-free dry-run), training forward, prefill, and single-token
decode — every per-layer loop is a ``lax.scan`` over stacked parameters so
the lowered HLO stays compact at 96+ layers.

Families → block plans:
  dense   [attn + mlp] × L                           (granite/nemotron/qwen*)
  moe     [attn|MLA + moe] × L (first-k dense)        (mixtral/deepseek)
  vlm     [(self ×(k−1)) + cross] × L/k               (llama-3.2-vision)
  ssm     [(mLSTM ×(k−1)) + sLSTM] × L/k              (xlstm)
  hybrid  [mamba2 (+ shared attn every k)] × L        (zamba2)
  audio   encoder [attn+mlp] × Le; decoder [self + cross + mlp] × Ld
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import sharding
from .attention import (
    cross_attention,
    cross_attn_params,
    gqa_attention,
    gqa_decode,
    gqa_params,
    gqa_project_qkv,
    mla_attention,
    mla_decode,
    mla_params,
)
from .common import KeyGen, apply_norm, dense_init, embed_init, norm_params
from .config import ModelConfig
from .mlp import mlp, mlp_params, moe_layer, moe_params
from .ssm import mamba_block, mamba_decode, mamba_init_cache, mamba_params
from .xlstm import (
    mlstm_block,
    mlstm_decode,
    mlstm_init_cache,
    mlstm_params,
    slstm_block,
    slstm_decode,
    slstm_init_cache,
    slstm_params,
)

PyTree = Any


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ======================================================================== init

def _dense_layer_init(key, cfg: ModelConfig, d_ff: int, use_mla: bool) -> PyTree:
    kg = KeyGen(key)
    dtype = _dt(cfg)
    attn = mla_params(kg, cfg, dtype) if use_mla else gqa_params(kg, cfg, dtype)
    return {
        "attn_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "attn": attn,
        "mlp_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_params(kg, cfg.d_model, d_ff, cfg.activation, dtype),
    }


def _moe_layer_init(key, cfg: ModelConfig) -> PyTree:
    kg = KeyGen(key)
    dtype = _dt(cfg)
    attn = mla_params(kg, cfg, dtype) if cfg.mla else gqa_params(kg, cfg, dtype)
    return {
        "attn_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "attn": attn,
        "mlp_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "moe": moe_params(kg, cfg, dtype),
    }


def _cross_layer_init(key, cfg: ModelConfig, gated: bool) -> PyTree:
    kg = KeyGen(key)
    dtype = _dt(cfg)
    return {
        "attn_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "attn": cross_attn_params(kg, cfg, dtype, gated=gated),
        "mlp_norm": norm_params(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_params(kg, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _stack(init_fn, key, n: int) -> PyTree:
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    kg = KeyGen(key)
    dtype = _dt(cfg)
    V, D = cfg.padded_vocab, cfg.d_model
    params: Dict[str, PyTree] = {
        "embed": embed_init(kg(), (V, D), dtype),
        "final_norm": norm_params(cfg.norm, D, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kg(), (D, V), dtype)

    fam = cfg.family
    if fam == "dense":
        params["blocks"] = _stack(
            lambda k: _dense_layer_init(k, cfg, cfg.d_ff, use_mla=False), kg(), cfg.n_layers
        )
    elif fam == "moe":
        m = cfg.moe
        if m.first_k_dense:
            params["dense_blocks"] = _stack(
                lambda k: _dense_layer_init(k, cfg, m.dense_ff, use_mla=cfg.mla is not None),
                kg(),
                m.first_k_dense,
            )
        params["blocks"] = _stack(
            lambda k: _moe_layer_init(k, cfg), kg(), cfg.n_layers - m.first_k_dense
        )
    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        n_self = cfg.n_layers - n_cross
        assert n_self % n_cross == 0
        params["blocks"] = _stack(
            lambda k: _dense_layer_init(k, cfg, cfg.d_ff, use_mla=False), kg(), n_self
        )
        params["cross_blocks"] = _stack(
            lambda k: _cross_layer_init(k, cfg, gated=True), kg(), n_cross
        )
    elif fam == "ssm":
        x = cfg.xlstm
        n_s = cfg.n_layers // x.slstm_every if x.slstm_every else 0
        n_m = cfg.n_layers - n_s
        kgm, kgs = kg(), kg()
        params["mlstm_blocks"] = _stack(
            lambda k: {
                "norm": norm_params(cfg.norm, D, dtype),
                "cell": mlstm_params(KeyGen(k), cfg, dtype),
            },
            kgm,
            n_m,
        )
        if n_s:
            params["slstm_blocks"] = _stack(
                lambda k: {
                    "norm": norm_params(cfg.norm, D, dtype),
                    "cell": slstm_params(KeyGen(k), cfg, dtype),
                },
                kgs,
                n_s,
            )
    elif fam == "hybrid":
        params["mamba_blocks"] = _stack(
            lambda k: {
                "norm": norm_params(cfg.norm, D, dtype),
                "mixer": mamba_params(KeyGen(k), cfg, dtype),
            },
            kg(),
            cfg.n_layers,
        )
        # ONE shared transformer block (weights reused at every application)
        params["shared_attn"] = _dense_layer_init(kg(), cfg, cfg.d_ff, use_mla=False)
    elif fam == "audio":
        params["enc_embed_norm"] = norm_params(cfg.norm, D, dtype)
        params["encoder"] = _stack(
            lambda k: _dense_layer_init(k, cfg, cfg.d_ff, use_mla=False),
            kg(),
            cfg.n_encoder_layers,
        )
        params["enc_final_norm"] = norm_params(cfg.norm, D, dtype)
        params["blocks"] = _stack(
            lambda k: _dense_layer_init(k, cfg, cfg.d_ff, use_mla=False), kg(), cfg.n_layers
        )
        params["cross_blocks"] = _stack(
            lambda k: _cross_layer_init(k, cfg, gated=False), kg(), cfg.n_layers
        )
    else:
        raise ValueError(fam)
    return params


def abstract_params(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.eval_shape(functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# ======================================================================== blocks

def _dense_block(bp: PyTree, h: jnp.ndarray, positions, cfg: ModelConfig, *, causal=True):
    use_mla = cfg.mla is not None and "w_dq" in bp["attn"]
    a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
    if use_mla:
        h = h + mla_attention(bp["attn"], a_in, positions, cfg)
    else:
        h = h + gqa_attention(bp["attn"], a_in, positions, cfg, causal=causal)
    h = sharding.constrain(h, "hidden")
    m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
    if "moe" in bp:
        h = h + moe_layer(bp["moe"], m_in, cfg)
    else:
        h = h + mlp(bp["mlp"], m_in, cfg.activation)
    return sharding.constrain(h, "hidden")


def _cross_block(bp: PyTree, h: jnp.ndarray, memory: jnp.ndarray, cfg: ModelConfig):
    a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
    h = h + cross_attention(bp["attn"], a_in, memory, cfg)
    m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
    h = h + mlp(bp["mlp"], m_in, cfg.activation)
    return sharding.constrain(h, "hidden")


def _remat(fn):
    """Gradient checkpointing on the block body (full recompute in bwd)."""
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ======================================================================== forward

def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S) int32
    *,
    memory: Optional[jnp.ndarray] = None,  # vlm vision / audio frames (B, Sm, D)
) -> jnp.ndarray:
    """Teacher-forcing forward → logits (B, S, V)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(_dt(cfg))
    h = sharding.constrain(h, "hidden")
    positions = jnp.arange(S)[None, :]
    fam = cfg.family

    if fam in ("dense", "moe"):
        block = _remat(lambda bp, h: _dense_block(bp, h, positions, cfg))
        if fam == "moe" and cfg.moe.first_k_dense:
            h, _ = jax.lax.scan(lambda h, bp: (block(bp, h), None), h, params["dense_blocks"])
        h, _ = jax.lax.scan(lambda h, bp: (block(bp, h), None), h, params["blocks"])

    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        n_self_per = k_every - 1
        self_grouped = jax.tree.map(
            lambda x: x.reshape(n_cross, n_self_per, *x.shape[1:]), params["blocks"]
        )
        mem = memory.astype(_dt(cfg))
        self_block = _remat(lambda bp, h: _dense_block(bp, h, positions, cfg))
        cross_block = _remat(lambda bp, h, mem: _cross_block(bp, h, mem, cfg))

        def super_body(h, bps):
            selfs, cross = bps
            h, _ = jax.lax.scan(lambda h, bp: (self_block(bp, h), None), h, selfs)
            h = cross_block(cross, h, mem)
            return h, None

        h, _ = jax.lax.scan(super_body, h, (self_grouped, params["cross_blocks"]))

    elif fam == "ssm":
        x = cfg.xlstm
        m_block = _remat(
            lambda bp, h: h + mlstm_block(bp["cell"], apply_norm(h, bp["norm"], cfg.norm), cfg)
        )
        if x.slstm_every:
            groups = cfg.n_layers // x.slstm_every
            per = x.slstm_every - 1
            m_grouped = jax.tree.map(
                lambda a: a.reshape(groups, per, *a.shape[1:]), params["mlstm_blocks"]
            )
            s_block = _remat(
                lambda bp, h: h
                + slstm_block(bp["cell"], apply_norm(h, bp["norm"], cfg.norm), cfg)
            )

            def super_body(h, bps):
                ms, sl = bps
                h, _ = jax.lax.scan(lambda h, bp: (m_block(bp, h), None), h, ms)
                h = s_block(sl, h)
                return sharding.constrain(h, "hidden"), None

            h, _ = jax.lax.scan(super_body, h, (m_grouped, params["slstm_blocks"]))
        else:
            h, _ = jax.lax.scan(
                lambda h, bp: (m_block(bp, h), None), h, params["mlstm_blocks"]
            )

    elif fam == "hybrid":
        every = cfg.shared_attn_every
        shared = params["shared_attn"]
        mamba = _remat(
            lambda bp, h: h + mamba_block(bp["mixer"], apply_norm(h, bp["norm"], cfg.norm), cfg)
        )
        shared_block = _remat(lambda h: _dense_block(shared, h, positions, cfg))

        def body(h, xs):
            bp, idx = xs
            h = mamba(bp, h)
            if every:
                h = jax.lax.cond(
                    (idx % every) == (every - 1), shared_block, lambda h: h, h
                )
            return sharding.constrain(h, "hidden"), None

        idxs = jnp.arange(cfg.n_layers)
        h, _ = jax.lax.scan(body, h, (params["mamba_blocks"], idxs))

    elif fam == "audio":
        # encoder over frame embeddings (bidirectional)
        mem = apply_norm(memory.astype(_dt(cfg)), params["enc_embed_norm"], cfg.norm)
        enc_pos = jnp.arange(mem.shape[1])[None, :]
        enc_block = _remat(lambda bp, m: _dense_block(bp, m, enc_pos, cfg, causal=False))
        mem, _ = jax.lax.scan(lambda m, bp: (enc_block(bp, m), None), mem, params["encoder"])
        mem = apply_norm(mem, params["enc_final_norm"], cfg.norm)

        self_block = _remat(lambda bp, h: _dense_block(bp, h, positions, cfg))
        cross_block = _remat(lambda bp, h, mem: _cross_block(bp, h, mem, cfg))

        def dec_body(h, bps):
            bp_self, bp_cross = bps
            h = self_block(bp_self, h)
            h = cross_block(bp_cross, h, mem)
            return h, None

        h, _ = jax.lax.scan(dec_body, h, (params["blocks"], params["cross_blocks"]))
    else:
        raise ValueError(fam)

    h = apply_norm(h, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return sharding.constrain(logits, "logits")
