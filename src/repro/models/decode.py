"""Serving paths for all six families: KV/state cache layout, prefill
(fills the cache, returns last-token logits) and single-token decode.

Cache layout is *stacked per layer* (leading ``L`` dim) so both prefill
and decode run a ``lax.scan`` over ``(block_params, cache_layer)`` — the
lowered HLO is one block body regardless of depth, which keeps the 512-
device dry-run compile tractable.

Sliding-window attention uses a **ring buffer** of size ``window``: slot
for absolute position ``p`` is ``p % window`` (matches
:func:`repro.models.attention.gqa_decode`). A 500k-context decode for a
SWA/SSM arch therefore holds O(window)/O(1) state, not O(S) — this is
what makes the ``long_500k`` cells runnable for sub-quadratic archs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import sharding
from .attention import (
    _mla_q,
    chunked_attention,
    gqa_decode,
    gqa_project_qkv,
    mla_decode,
)
from .common import KeyGen, apply_norm, apply_rope, rms_norm, shard_map_compat
from .config import ModelConfig
from .mlp import mlp, moe_layer
from .ssm import _causal_conv as mamba_conv
from .ssm import _split_in, mamba_decode, mamba_init_cache, ssd_chunked
from .xlstm import (
    _slstm_cell,
    mlstm_chunked,
    mlstm_decode,
    mlstm_init_cache,
    slstm_decode,
    slstm_init_cache,
)
from .xlstm import _causal_conv as xlstm_conv

PyTree = Any


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _ring(cfg: ModelConfig, max_len: int) -> int:
    """Effective cache length: ring of size `window` under SWA."""
    return min(max_len, cfg.swa_window) if cfg.swa_window else max_len


def _stack_zeros(n: int, shape, dtype):
    return jnp.zeros((n, *shape), dtype)


# ===================================================================== caches

def _attn_cache_stack(cfg: ModelConfig, n: int, batch: int, m: int, use_mla: bool):
    dt = _dt(cfg)
    if use_mla:
        a = cfg.mla
        return {
            "c_kv": _stack_zeros(n, (batch, m, a.kv_lora_rank), dt),
            "k_rope": _stack_zeros(n, (batch, m, a.qk_rope_head_dim), dt),
        }
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": _stack_zeros(n, (batch, m, kv, hd), dt),
        "v": _stack_zeros(n, (batch, m, kv, hd), dt),
    }


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, memory_len: int = 0
) -> PyTree:
    """Empty cache for a serving session of ≤ max_len absolute positions."""
    m = _ring(cfg, max_len)
    dt = _dt(cfg)
    fam = cfg.family
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if fam == "dense":
        cache["layers"] = _attn_cache_stack(cfg, cfg.n_layers, batch, m, False)
    elif fam == "moe":
        k = cfg.moe.first_k_dense
        use_mla = cfg.mla is not None
        if k:
            cache["dense_layers"] = _attn_cache_stack(cfg, k, batch, m, use_mla)
        cache["layers"] = _attn_cache_stack(cfg, cfg.n_layers - k, batch, m, use_mla)
    elif fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        cache["layers"] = _attn_cache_stack(cfg, n_self, batch, m, False)
        cache["cross"] = {
            "k": _stack_zeros(n_cross, (batch, memory_len, kv, hd), dt),
            "v": _stack_zeros(n_cross, (batch, memory_len, kv, hd), dt),
        }
    elif fam == "audio":
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        cache["layers"] = _attn_cache_stack(cfg, cfg.n_layers, batch, m, False)
        cache["cross"] = {
            "k": _stack_zeros(cfg.n_layers, (batch, memory_len, kv, hd), dt),
            "v": _stack_zeros(cfg.n_layers, (batch, memory_len, kv, hd), dt),
        }
    elif fam == "ssm":
        x = cfg.xlstm
        n_s = cfg.n_layers // x.slstm_every if x.slstm_every else 0
        n_m = cfg.n_layers - n_s
        one_m = mlstm_init_cache(cfg, batch)
        cache["mlstm"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_m, *a.shape)), one_m)
        if n_s:
            one_s = slstm_init_cache(cfg, batch)
            cache["slstm"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_s, *a.shape)), one_s
            )
    elif fam == "hybrid":
        one = mamba_init_cache(cfg, batch, dt)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one
        )
        every = cfg.shared_attn_every
        if every:
            n_sh = cfg.n_layers // every
            cache["shared"] = _attn_cache_stack(cfg, n_sh, batch, m, False)
    else:
        raise ValueError(fam)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, memory_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, memory_len=memory_len)
    )


# ============================================================ cache writers

def _write_linear(cache_arr: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    """Prefill fill from position 0 (cache assumed fresh)."""
    return jax.lax.dynamic_update_slice_in_dim(cache_arr, new.astype(cache_arr.dtype), 0, axis=1)


def _write_ring(cache_arr: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    """Fill a ring buffer of size M with the last ≤M of S new entries.

    For S ≥ M the kept positions p ∈ [S−M, S) map bijectively onto slots
    p % M — a roll by (S−M) % M.  For S < M it is a plain prefix write.
    """
    m = cache_arr.shape[1]
    s = new.shape[1]
    if s < m:
        return _write_linear(cache_arr, new)
    tail = new[:, s - m :]
    rolled = jnp.roll(tail, shift=(s - m) % m, axis=1)
    return rolled.astype(cache_arr.dtype)


def _write(cfg: ModelConfig, cache_arr: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    return _write_ring(cache_arr, new) if cfg.swa_window else _write_linear(cache_arr, new)


# ====================================================== cross-attention K/V

def _cross_kv(p: PyTree, memory: jnp.ndarray, cfg: ModelConfig):
    mem = rms_norm(memory, p["k_input_norm"])
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


def _cross_apply(p: PyTree, x: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    out = chunked_attention(q, k, v, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(y.dtype) * y
    return y


def _cross_block_cached(bp: PyTree, h, k, v, cfg):
    a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
    h = h + _cross_apply(bp["attn"], a_in, k, v, cfg)
    m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
    return h + mlp(bp["mlp"], m_in, cfg.activation)


# ==================================================== dense-family prefill

def _gqa_prefill_layer(bp, h, positions, cfg, cl):
    """One attn+ffn layer: returns (h, filled cache layer)."""
    a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
    q, k, v = gqa_project_qkv(bp["attn"], a_in, positions, cfg)
    out = chunked_attention(q, k, v, causal=True, window=cfg.swa_window)
    h = h + jnp.einsum("bshk,hkd->bsd", out, bp["attn"]["wo"])
    new_cl = {"k": _write(cfg, cl["k"], k), "v": _write(cfg, cl["v"], v)}
    m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
    if "moe" in bp:
        h = h + moe_layer(bp["moe"], m_in, cfg)
    else:
        h = h + mlp(bp["mlp"], m_in, cfg.activation)
    return sharding.constrain(h, "hidden"), new_cl


def _mla_prefill_layer(bp, h, positions, cfg, cl):
    m = cfg.mla
    p = bp["attn"]
    a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
    q_nope, q_rope = _mla_q(p, a_in, positions, cfg)
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", a_in, p["w_dkv"]), p["kv_norm"])
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", a_in, p["w_krope"])[:, :, None, :],
        positions,
        cfg.rope_theta,
    )
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = chunked_attention(q, k, v, causal=True, scale=scale)
    h = h + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cl = {
        "c_kv": _write(cfg, cl["c_kv"], c_kv),
        "k_rope": _write(cfg, cl["k_rope"], k_rope[:, :, 0, :]),
    }
    m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
    if "moe" in bp:
        h = h + moe_layer(bp["moe"], m_in, cfg)
    else:
        h = h + mlp(bp["mlp"], m_in, cfg.activation)
    return sharding.constrain(h, "hidden"), new_cl


def _attn_prefill_scan(blocks, cache_layers, h, positions, cfg, use_mla):
    layer = _mla_prefill_layer if use_mla else _gqa_prefill_layer
    return jax.lax.scan(
        lambda h, xs: layer(xs[0], h, positions, cfg, xs[1]), h, (blocks, cache_layers)
    )


def _attn_decode_scan(blocks, cache_layers, h, pos, cfg, use_mla):
    if CACHE_LAYOUT == "carry":
        return _attn_decode_carry(blocks, cache_layers, h, pos, cfg, use_mla)

    def body(h, xs):
        bp, cl = xs
        a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
        dec = mla_decode if use_mla else gqa_decode
        y, new_cl = dec(bp["attn"], a_in, {**cl, "len": pos}, cfg)
        h = h + y
        m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
        if "moe" in bp:
            h = h + moe_layer(bp["moe"], m_in, cfg)
        else:
            h = h + mlp(bp["mlp"], m_in, cfg.activation)
        del new_cl["len"]
        return sharding.constrain(h, "decode_hidden"), new_cl

    return jax.lax.scan(body, h, (blocks, cache_layers))


# ==================================================== ssm / hybrid helpers

def _mamba_prefill(p, x, cfg):
    """Like mamba_block but returns (y, cache layer) with the final state."""
    s = cfg.ssm
    D = cfg.d_model
    di, nh, N = s.d_inner(D), s.n_heads(D), s.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_in(proj, di, N, nh)
    conv_tail = xbc[:, -(s.d_conv - 1) :, :]
    xbc = jax.nn.silu(mamba_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, B_ssm, C_ssm = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(*xin.shape[:2], nh, s.head_dim)
    y, h_final = ssd_chunked(xh, dt, a, B_ssm, C_ssm, chunk=s.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"conv": conv_tail.astype(x.dtype), "h": h_final}


def _mlstm_prefill(p, x, cfg):
    D = cfg.d_model
    nh = cfg.n_heads
    inner = int(cfg.xlstm.mlstm_proj_factor * D)
    Pd = inner // nh
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xg, xc = up[..., :inner], up[..., inner:]
    conv_tail = xc[:, -3:, :].astype(jnp.float32)
    xconv = jax.nn.silu(xlstm_conv(xc, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bse,ef->bsf", xconv, p["wq"]).reshape(*x.shape[:2], nh, Pd)
    k = jnp.einsum("bse,ef->bsf", xconv, p["wk"]).reshape(*x.shape[:2], nh, Pd)
    v = jnp.einsum("bse,ef->bsf", xc, p["wv"]).reshape(*x.shape[:2], nh, Pd)
    gates = jnp.einsum("bse,eg->bsg", xconv, p["w_if"])
    i_gate, f_gate = gates[..., :nh], gates[..., nh:]
    y, (C, n, m) = mlstm_chunked(q, k, v, i_gate, f_gate, chunk=cfg.xlstm.chunk)
    y = y.reshape(*x.shape[:2], inner).astype(x.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(xg)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    return out, {"conv": conv_tail, "C": C, "n": n, "m": m}


def _slstm_prefill(p, x, cfg):
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    B, S, _ = x.shape
    conv_tail = x[:, -3:, :].astype(jnp.float32)
    xconv = jax.nn.silu(xlstm_conv(x, p["conv_w"], p["conv_b"]))
    xg = jnp.einsum("bsd,dg->bsg", xconv, p["w_gates"])
    state0 = (
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.full((B, nh), -1e30, jnp.float32),
    )

    def step(state, xg_t):
        new = _slstm_cell(p, xg_t, state)
        return new, new[0]

    (hf, cf, nf, mf), hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["gn"])
    ff = jax.nn.silu(jnp.einsum("bsd,df->bsf", y, p["ff_gate"])) * jnp.einsum(
        "bsd,df->bsf", y, p["ff_up"]
    )
    out = jnp.einsum("bsf,fd->bsd", ff, p["ff_down"])
    return out, {"conv": conv_tail, "h": hf, "c": cf, "n": nf, "m": mf}


# =============================================================== prefill

def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S) int32
    cache: PyTree,
    *,
    memory: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """Process a fresh prompt; returns (last-token logits (B, V), cache)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(_dt(cfg))
    h = sharding.constrain(h, "hidden")
    positions = jnp.arange(S)[None, :]
    fam = cfg.family
    new_cache: Dict[str, Any] = {"len": jnp.full((), S, jnp.int32)}

    if fam in ("dense", "moe"):
        use_mla = cfg.mla is not None
        if fam == "moe" and cfg.moe.first_k_dense:
            h, dl = _attn_prefill_scan(
                params["dense_blocks"], cache["dense_layers"], h, positions, cfg, use_mla
            )
            new_cache["dense_layers"] = dl
        h, layers = _attn_prefill_scan(
            params["blocks"], cache["layers"], h, positions, cfg, use_mla
        )
        new_cache["layers"] = layers

    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        n_self_per = k_every - 1
        self_grouped = jax.tree.map(
            lambda x: x.reshape(n_cross, n_self_per, *x.shape[1:]), params["blocks"]
        )
        cache_grouped = jax.tree.map(
            lambda x: x.reshape(n_cross, n_self_per, *x.shape[1:]), cache["layers"]
        )
        mem = memory.astype(_dt(cfg))

        def super_body(h, xs):
            selfs, cls, cross_bp = xs
            h, new_cls = _attn_prefill_scan(selfs, cls, h, positions, cfg, False)
            ck, cv = _cross_kv(cross_bp["attn"], mem, cfg)
            h = _cross_block_cached(cross_bp, h, ck, cv, cfg)
            return sharding.constrain(h, "hidden"), (new_cls, ck, cv)

        h, (cls, cks, cvs) = jax.lax.scan(
            super_body, h, (self_grouped, cache_grouped, params["cross_blocks"])
        )
        new_cache["layers"] = jax.tree.map(
            lambda x: x.reshape(n_cross * n_self_per, *x.shape[2:]), cls
        )
        new_cache["cross"] = {"k": cks.astype(_dt(cfg)), "v": cvs.astype(_dt(cfg))}

    elif fam == "audio":
        mem = encode(params, cfg, memory)

        def dec_body(h, xs):
            bp_self, bp_cross, cl = xs
            h, new_cl = _gqa_prefill_layer(bp_self, h, positions, cfg, cl)
            ck, cv = _cross_kv(bp_cross["attn"], mem, cfg)
            h = _cross_block_cached(bp_cross, h, ck, cv, cfg)
            return sharding.constrain(h, "hidden"), (new_cl, ck, cv)

        h, (cls, cks, cvs) = jax.lax.scan(
            dec_body, h, (params["blocks"], params["cross_blocks"], cache["layers"])
        )
        new_cache["layers"] = cls
        new_cache["cross"] = {"k": cks.astype(_dt(cfg)), "v": cvs.astype(_dt(cfg))}

    elif fam == "ssm":
        x = cfg.xlstm

        def m_body(h, xs):
            bp, _cl = xs
            y, new_cl = _mlstm_prefill(bp["cell"], apply_norm(h, bp["norm"], cfg.norm), cfg)
            return sharding.constrain(h + y, "hidden"), new_cl

        if x.slstm_every:
            groups = cfg.n_layers // x.slstm_every
            per = x.slstm_every - 1
            m_grouped = jax.tree.map(
                lambda a: a.reshape(groups, per, *a.shape[1:]), params["mlstm_blocks"]
            )
            mc_grouped = jax.tree.map(
                lambda a: a.reshape(groups, per, *a.shape[1:]), cache["mlstm"]
            )

            def super_body(h, xs):
                ms, mcs, sl, _sc = xs
                h, new_mc = jax.lax.scan(m_body, h, (ms, mcs))
                y, new_sc = _slstm_prefill(
                    sl["cell"], apply_norm(h, sl["norm"], cfg.norm), cfg
                )
                return sharding.constrain(h + y, "hidden"), (new_mc, new_sc)

            h, (mcs, scs) = jax.lax.scan(
                super_body,
                h,
                (m_grouped, mc_grouped, params["slstm_blocks"], cache["slstm"]),
            )
            new_cache["mlstm"] = jax.tree.map(
                lambda a: a.reshape(groups * per, *a.shape[2:]), mcs
            )
            new_cache["slstm"] = scs
        else:
            h, mcs = jax.lax.scan(m_body, h, (params["mlstm_blocks"], cache["mlstm"]))
            new_cache["mlstm"] = mcs

    elif fam == "hybrid":
        every = cfg.shared_attn_every
        shared = params["shared_attn"]

        def mamba_body(h, xs):
            bp, _cl = xs
            y, new_cl = _mamba_prefill(bp["mixer"], apply_norm(h, bp["norm"], cfg.norm), cfg)
            return sharding.constrain(h + y, "hidden"), new_cl

        if every:
            groups = cfg.n_layers // every
            g_params = jax.tree.map(
                lambda a: a.reshape(groups, every, *a.shape[1:]), params["mamba_blocks"]
            )
            g_cache = jax.tree.map(
                lambda a: a.reshape(groups, every, *a.shape[1:]), cache["mamba"]
            )

            def super_body(h, xs):
                mb, mc, sc = xs
                h, new_mc = jax.lax.scan(mamba_body, h, (mb, mc))
                h, new_sc = _gqa_prefill_layer(shared, h, positions, cfg, sc)
                return h, (new_mc, new_sc)

            h, (mcs, scs) = jax.lax.scan(super_body, h, (g_params, g_cache, cache["shared"]))
            new_cache["mamba"] = jax.tree.map(
                lambda a: a.reshape(groups * every, *a.shape[2:]), mcs
            )
            new_cache["shared"] = scs
        else:
            h, mcs = jax.lax.scan(mamba_body, h, (params["mamba_blocks"], cache["mamba"]))
            new_cache["mamba"] = mcs
    else:
        raise ValueError(fam)

    h_last = apply_norm(h[:, -1:, :], params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h_last, head)[:, 0]
    return sharding.constrain(logits, "logits_last"), new_cache


def encode(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Audio/enc-dec encoder over stub frame embeddings → memory states."""
    from .transformer import _dense_block  # local import to avoid cycle

    mem = apply_norm(frames.astype(_dt(cfg)), params["enc_embed_norm"], cfg.norm)
    enc_pos = jnp.arange(mem.shape[1])[None, :]

    def enc_body(m, bp):
        return _dense_block(bp, m, enc_pos, cfg, causal=False), None

    mem, _ = jax.lax.scan(enc_body, mem, params["encoder"])
    return apply_norm(mem, params["enc_final_norm"], cfg.norm)


# ================================================================ decode

def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, 1) int32 — the most recent sampled token
    cache: PyTree,
) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step; returns (logits (B, V), updated cache)."""
    pos = cache["len"]
    h = params["embed"][tokens].astype(_dt(cfg))
    fam = cfg.family
    new_cache: Dict[str, Any] = {"len": pos + 1}

    if fam in ("dense", "moe"):
        use_mla = cfg.mla is not None
        if fam == "moe" and cfg.moe.first_k_dense:
            h, dl = _attn_decode_scan(
                params["dense_blocks"], cache["dense_layers"], h, pos, cfg, use_mla
            )
            new_cache["dense_layers"] = dl
        h, layers = _attn_decode_scan(
            params["blocks"], cache["layers"], h, pos, cfg, use_mla
        )
        new_cache["layers"] = layers

    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        n_self_per = k_every - 1
        self_grouped = jax.tree.map(
            lambda x: x.reshape(n_cross, n_self_per, *x.shape[1:]), params["blocks"]
        )
        cache_grouped = jax.tree.map(
            lambda x: x.reshape(n_cross, n_self_per, *x.shape[1:]), cache["layers"]
        )

        def super_body(h, xs):
            selfs, cls, cross_bp, ck, cv = xs
            h, new_cls = _attn_decode_scan(selfs, cls, h, pos, cfg, False)
            h = _cross_block_cached(cross_bp, h, ck, cv, cfg)
            return h, new_cls

        h, cls = jax.lax.scan(
            super_body,
            h,
            (
                self_grouped,
                cache_grouped,
                params["cross_blocks"],
                cache["cross"]["k"],
                cache["cross"]["v"],
            ),
        )
        new_cache["layers"] = jax.tree.map(
            lambda x: x.reshape(n_cross * n_self_per, *x.shape[2:]), cls
        )
        new_cache["cross"] = cache["cross"]

    elif fam == "audio":
        def dec_body(h, xs):
            bp_self, bp_cross, cl, ck, cv = xs
            a_in = apply_norm(h, bp_self["attn_norm"], cfg.norm)
            y, new_cl = gqa_decode(bp_self["attn"], a_in, {**cl, "len": pos}, cfg)
            h = h + y
            m_in = apply_norm(h, bp_self["mlp_norm"], cfg.norm)
            h = h + mlp(bp_self["mlp"], m_in, cfg.activation)
            h = _cross_block_cached(bp_cross, h, ck, cv, cfg)
            del new_cl["len"]
            return h, new_cl

        h, cls = jax.lax.scan(
            dec_body,
            h,
            (
                params["blocks"],
                params["cross_blocks"],
                cache["layers"],
                cache["cross"]["k"],
                cache["cross"]["v"],
            ),
        )
        new_cache["layers"] = cls
        new_cache["cross"] = cache["cross"]

    elif fam == "ssm":
        x = cfg.xlstm

        def m_body(h, xs):
            bp, cl = xs
            y, new_cl = mlstm_decode(bp["cell"], apply_norm(h, bp["norm"], cfg.norm), cl, cfg)
            return h + y, new_cl

        if x.slstm_every:
            groups = cfg.n_layers // x.slstm_every
            per = x.slstm_every - 1
            m_grouped = jax.tree.map(
                lambda a: a.reshape(groups, per, *a.shape[1:]), params["mlstm_blocks"]
            )
            mc_grouped = jax.tree.map(
                lambda a: a.reshape(groups, per, *a.shape[1:]), cache["mlstm"]
            )

            def super_body(h, xs):
                ms, mcs, sl, sc = xs
                h, new_mc = jax.lax.scan(m_body, h, (ms, mcs))
                y, new_sc = slstm_decode(
                    sl["cell"], apply_norm(h, sl["norm"], cfg.norm), sc, cfg
                )
                return h + y, (new_mc, new_sc)

            h, (mcs, scs) = jax.lax.scan(
                super_body,
                h,
                (m_grouped, mc_grouped, params["slstm_blocks"], cache["slstm"]),
            )
            new_cache["mlstm"] = jax.tree.map(
                lambda a: a.reshape(groups * per, *a.shape[2:]), mcs
            )
            new_cache["slstm"] = scs
        else:
            h, mcs = jax.lax.scan(m_body, h, (params["mlstm_blocks"], cache["mlstm"]))
            new_cache["mlstm"] = mcs

    elif fam == "hybrid":
        every = cfg.shared_attn_every
        shared = params["shared_attn"]

        def mamba_body(h, xs):
            bp, cl = xs
            y, new_cl = mamba_decode(bp["mixer"], apply_norm(h, bp["norm"], cfg.norm), cl, cfg)
            return h + y, new_cl

        if every:
            groups = cfg.n_layers // every
            g_params = jax.tree.map(
                lambda a: a.reshape(groups, every, *a.shape[1:]), params["mamba_blocks"]
            )
            g_cache = jax.tree.map(
                lambda a: a.reshape(groups, every, *a.shape[1:]), cache["mamba"]
            )

            def super_body(h, xs):
                mb, mc, sc = xs
                h, new_mc = jax.lax.scan(mamba_body, h, (mb, mc))
                a_in = apply_norm(h, shared["attn_norm"], cfg.norm)
                y, new_sc = gqa_decode(shared["attn"], a_in, {**sc, "len": pos}, cfg)
                h = h + y
                m_in = apply_norm(h, shared["mlp_norm"], cfg.norm)
                h = h + mlp(shared["mlp"], m_in, cfg.activation)
                del new_sc["len"]
                return h, (new_mc, new_sc)

            h, (mcs, scs) = jax.lax.scan(
                super_body, h, (g_params, g_cache, cache["shared"])
            )
            new_cache["mamba"] = jax.tree.map(
                lambda a: a.reshape(groups * every, *a.shape[2:]), mcs
            )
            new_cache["shared"] = scs
        else:
            h, mcs = jax.lax.scan(mamba_body, h, (params["mamba_blocks"], cache["mamba"]))
            new_cache["mamba"] = mcs
    else:
        raise ValueError(fam)

    h = apply_norm(h, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return sharding.constrain(logits, "logits_last"), new_cache


# -- carry-layout decode (§Perf hillclimb: nemotron decode_32k) ----------------------
#
# H: scanning cache layers as xs/ys stacks a full-layer copy per step;
# carrying the stacked cache through the loop and (a) DUS-ing only the new
# token at (layer, :, pos) and (b) slicing the layer for attention keeps
# the write O(token) and the read O(layer) — the bandwidth floor.

CACHE_LAYOUT = "scan"  # "scan" | "carry"


def _gqa_decode_carry(p, x, cache_k, cache_v, li, pos, cfg):
    s_max = cache_k.shape[2]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    from .attention import decode_attention as _dec_attn

    q, k, v = gqa_project_qkv(p, x, positions, cfg)
    slot = (pos % s_max) if cfg.swa_window else pos
    zero = jnp.zeros((), jnp.int32)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k[None].astype(cache_k.dtype), (li, zero, slot, zero, zero)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v[None].astype(cache_v.dtype), (li, zero, slot, zero, zero)
    )
    k_layer = jax.lax.dynamic_index_in_dim(cache_k, li, 0, keepdims=False)
    v_layer = jax.lax.dynamic_index_in_dim(cache_v, li, 0, keepdims=False)
    new_len = pos + 1
    eff = jnp.minimum(new_len, s_max) if cfg.swa_window else new_len
    out = _dec_attn(q, k_layer, v_layer, eff, window=0)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache_k, cache_v


def _mla_decode_carry(p, x, c_kv_all, k_rope_all, li, pos, cfg):
    m = cfg.mla
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_new = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    kr_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_krope"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    zero = jnp.zeros((), jnp.int32)
    c_kv_all = jax.lax.dynamic_update_slice(
        c_kv_all, c_new[None].astype(c_kv_all.dtype), (li, zero, pos, zero)
    )
    k_rope_all = jax.lax.dynamic_update_slice(
        k_rope_all, kr_new[None].astype(k_rope_all.dtype), (li, zero, pos, zero)
    )
    c_kv = jax.lax.dynamic_index_in_dim(c_kv_all, li, 0, keepdims=False)
    k_rope = jax.lax.dynamic_index_in_dim(k_rope_all, li, 0, keepdims=False)
    new_len = pos + 1
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"])
    s_lat = jnp.einsum("bhr,bmr->bhm", q_lat, c_kv)
    s_rope = jnp.einsum("bhk,bmk->bhm", q_rope[:, 0], k_rope)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (s_lat + s_rope) * scale
    valid = jnp.arange(c_kv.shape[1])[None, :] < new_len
    s = jnp.where(valid[:, None, :], s.astype(jnp.float32), -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", prob, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", o_lat.astype(x.dtype), p["w_uv"])
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return y, c_kv_all, k_rope_all


def _attn_decode_carry(blocks, cache_layers, h, pos, cfg, use_mla):
    n_layers = jax.tree.leaves(blocks)[0].shape[0]

    def body(carry, xs):
        h, cache = carry
        bp, li = xs
        a_in = apply_norm(h, bp["attn_norm"], cfg.norm)
        if use_mla:
            y, ck, kr = _mla_decode_carry(
                bp["attn"], a_in, cache["c_kv"], cache["k_rope"], li, pos, cfg
            )
            cache = {"c_kv": ck, "k_rope": kr}
        else:
            y, ck, cv = _gqa_decode_carry(
                bp["attn"], a_in, cache["k"], cache["v"], li, pos, cfg
            )
            cache = {"k": ck, "v": cv}
        h = h + y
        m_in = apply_norm(h, bp["mlp_norm"], cfg.norm)
        if "moe" in bp:
            h = h + moe_layer(bp["moe"], m_in, cfg)
        else:
            h = h + mlp(bp["mlp"], m_in, cfg.activation)
        return (sharding.constrain(h, "decode_hidden"), cache), None

    (h, cache), _ = jax.lax.scan(
        body, (h, cache_layers), (blocks, jnp.arange(n_layers))
    )
    return h, cache


# -- pipeline-parallel decode (§Perf hillclimb: nemotron decode_32k) -----------------
#
# H: with (data × model)-FSDP weights, every decode step re-gathers 42 GB
# of weights per device over the data axis. Pipelining layers over the
# data axis instead makes weights STATIONARY: shard s owns layers
# [s·L/16, (s+1)·L/16) whole (model-TP'd), microbatches flow through
# stages via one tiny collective_permute per round. This function is one
# *steady-state GPipe round*: every stage applies its local layers to its
# resident microbatch and hands it on — per-token throughput cost.
#
# shard_map is manual over "data" only (axis_names); the "model" axis
# stays auto, so the per-layer attention/MLP keep their GSPMD tensor
# parallelism unchanged.

def decode_step_pp(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, 1) — entering microbatch tokens per stage slot
    cache: PyTree,        # {"layers": L-sharded stacks, "pp_h": (B,1,D), "len"}
    rules,
) -> Tuple[jnp.ndarray, PyTree]:
    assert cfg.family == "dense", "PP decode experiment covers the dense family"
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    stage_axis = "data"
    n_stages = rules.mesh_sizes[stage_axis]
    L = cfg.n_layers
    assert L % n_stages == 0
    pos = cache["len"]

    mb = tokens.shape[0] // n_stages  # microbatch per stage slot

    def stage_fn(blocks_local, cache_local, h_in, tok_local, embed, head, final_norm):
        sid = jax.lax.axis_index(stage_axis)
        is_first = sid == 0
        is_last = sid == n_stages - 1
        # stage 0 ingests the entering microbatch
        h_tok = embed[tok_local].astype(_dt(cfg))
        h = jnp.where(is_first, h_tok, h_in)
        # the cache at this stage holds ALL microbatches' KV for its
        # layers; the one resident this round is offset by the stage id
        m_idx = ((n_stages - sid) % n_stages) * mb
        cache_mb = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, m_idx, mb, axis=1),
            cache_local,
        )
        # inside the manual 'data' axis the batch-sharding constraints are
        # meaningless — drop them; the auto 'model' axis propagates via GSPMD
        with sharding.use_rules(None):
            h, new_mb = _attn_decode_scan(blocks_local, cache_mb, h, pos, cfg, False)
        new_cache = jax.tree.map(
            lambda full, upd: jax.lax.dynamic_update_slice_in_dim(
                full, upd.astype(full.dtype), m_idx, axis=1
            ),
            cache_local, new_mb,
        )
        # stage L−1 emits logits for the exiting microbatch
        h_last = apply_norm(h, final_norm, cfg.norm)
        logits = jnp.einsum("bsd,dv->bsv", h_last, head)[:, 0]
        logits = jnp.where(is_last, logits, jnp.zeros_like(logits))
        h_next = jax.lax.ppermute(
            h, stage_axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
        )
        return h_next, logits, new_cache

    blocks = params["blocks"]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    in_specs = (
        jax.tree.map(lambda _: P(stage_axis), blocks),          # L over stages
        jax.tree.map(lambda _: P(stage_axis), cache["layers"]),
        P(stage_axis, None, None),                               # pp_h (B,1,D)
        P(stage_axis, None),                                     # tokens
        P(None, None),                                           # embed
        P(None, None),                                           # head
        jax.tree.map(lambda _: P(None), params["final_norm"]),
    )
    out_specs = (
        P(stage_axis, None, None),
        P(stage_axis, None),
        jax.tree.map(lambda _: P(stage_axis), cache["layers"]),
    )
    h_next, logits, new_layers = shard_map_compat(
        stage_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        axis_names={stage_axis},
        check_vma=False,
    )(
        blocks, cache["layers"], cache["pp_h"], tokens,
        params["embed"], head, params["final_norm"],
    )
    new_cache = {"len": pos + 1, "layers": new_layers, "pp_h": h_next}
    return logits, new_cache
