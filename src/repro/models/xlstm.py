"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential recurrence with block-diagonal recurrent weights).

mLSTM cell (stabilized exponential gating), per head with key/value dim P:
  m_t = max(f̃_t + m_{t−1}, ĩ_t)                     (stabilizer)
  i'_t = exp(ĩ_t − m_t),  f'_t = exp(f̃_t + m_{t−1} − m_t)
  C_t = f'_t C_{t−1} + i'_t v_t k_tᵀ                 (P×P matrix state)
  n_t = f'_t n_{t−1} + i'_t k_t
  h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)

The chunkwise form mirrors the Mamba2 SSD decomposition: intra-chunk masked
quadratic + inter-chunk carried (C, n, m) — the same TPU mapping (MXU
matmuls per chunk, lax.scan across chunks).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm


# ---------------------------------------------------------------- mLSTM ----

def mlstm_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    x = cfg.xlstm
    D = cfg.d_model
    inner = int(x.mlstm_proj_factor * D)
    nh = cfg.n_heads
    return {
        "w_up": dense_init(key_gen(), (D, 2 * inner), dtype),
        "conv_w": dense_init(key_gen(), (4, inner), dtype, fan_in=4),
        "conv_b": jnp.zeros((inner,), dtype),
        "wq": dense_init(key_gen(), (inner, inner), dtype),
        "wk": dense_init(key_gen(), (inner, inner), dtype),
        "wv": dense_init(key_gen(), (inner, inner), dtype),
        "w_if": dense_init(key_gen(), (inner, 2 * nh), dtype),  # input/forget gates
        "out_norm": jnp.ones((inner,), dtype),
        "w_down": dense_init(key_gen(), (inner, D), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def mlstm_chunked(
    q: jnp.ndarray,  # (B, S, nh, P)
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # (B, S, nh) pre-activation ĩ
    f_gate: jnp.ndarray,  # (B, S, nh) pre-activation f̃ (log-sigmoid applied here)
    chunk: int,
    state: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    # named_scope ⇒ roofline-attributable to a chunkwise mLSTM kernel
    # (same VMEM-resident structure as kernels/ssd)
    with jax.named_scope("kernel_mlstm_scan"):
        return _mlstm_chunked_impl(q, k, v, i_gate, f_gate, chunk, state)


def _mlstm_chunked_impl(q, k, v, i_gate, f_gate, chunk, state=None):
    B, S, nh, P = q.shape
    if S % chunk:  # serving prompts: largest divisor ≤ chunk keeps exactness
        chunk = next(c for c in range(min(chunk, S), 0, -1) if S % c == 0)
    nc = S // chunk
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,S,nh)
    i_gate = i_gate.astype(jnp.float32)

    qc = q.reshape(B, nc, chunk, nh, P).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, nh, P).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, nh, P).transpose(1, 0, 2, 3, 4)
    ic = i_gate.reshape(B, nc, chunk, nh).transpose(1, 0, 2, 3)
    fc = logf.reshape(B, nc, chunk, nh).transpose(1, 0, 2, 3)

    if state is None:
        C0 = jnp.zeros((B, nh, P, P), jnp.float32)
        n0 = jnp.zeros((B, nh, P), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    scale = P ** -0.5

    def body(carry, xs):
        C, n, m = carry
        qi, ki, vi, ii, fi = xs
        L = qi.shape[1]
        cumf = jnp.cumsum(fi, axis=1)  # (B,L,nh) Σ log f within chunk
        # log weight of source j seen at target i (j ≤ i):
        #   w_ij = cumf_i − cumf_j + ĩ_j        (decay from j+1..i, gate at j)
        # log weight of carried state at target i: m + cumf_i
        src = ii - cumf  # (B,L,nh) per-source summand
        m_local = jnp.max(src, axis=1)  # (B,nh) running stabilizer candidate
        m_new = jnp.maximum(m + 0.0, m_local)  # chunk-level stabilizer
        # intra-chunk weights (stabilized by m_new per target row via cumf_i)
        idx = jnp.arange(L)
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        logw = cumf[:, :, None, :] + src[:, None, :, :] - m_new[:, None, None, :]
        w = jnp.where(causal, jnp.exp(logw), 0.0)  # (B,Li,Lj,nh)
        qk = jnp.einsum("bihp,bjhp->bijh", qi.astype(jnp.float32), ki.astype(jnp.float32))
        Wg = w * qk * scale
        y_intra = jnp.einsum("bijh,bjhp->bihp", Wg, vi.astype(jnp.float32))
        n_intra = jnp.einsum("bijh,bjhp->bihp", w, ki.astype(jnp.float32))
        # inter-chunk: carried state decayed to each target
        carry_w = jnp.exp(cumf + m[:, None, :] - m_new[:, None, :])  # (B,L,nh)
        y_inter = jnp.einsum(
            "bihp,bhpr->bihr", qi.astype(jnp.float32) * scale, C
        ) * carry_w[..., None]
        n_inter = n[:, None, :, :] * carry_w[..., None]
        num = y_intra + y_inter
        nvec = n_intra + n_inter
        denom = jnp.abs(jnp.einsum("bihp,bihp->bih", nvec, qi.astype(jnp.float32) * scale))
        y = num / jnp.maximum(denom, jnp.exp(-m_new)[:, None, :])[..., None]
        # state update to end of chunk
        last = cumf[:, -1, :]  # (B,nh)
        to_end = jnp.exp(last[:, None, :] - cumf + ii - m_new[:, None, :])  # (B,L,nh)
        C_new = C * jnp.exp(last + m - m_new)[..., None, None] + jnp.einsum(
            "bjh,bjhp,bjhr->bhpr", to_end, vi.astype(jnp.float32), ki.astype(jnp.float32)
        )
        n_new = n * jnp.exp(last + m - m_new)[..., None] + jnp.einsum(
            "bjh,bjhp->bhp", to_end, ki.astype(jnp.float32)
        )
        return (C_new, n_new, m_new), y

    (C, n, m), yc = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, P)
    return y, (C, n, m)


def mlstm_block(p: Dict[str, Any], x: jnp.ndarray, cfg) -> jnp.ndarray:
    """(B,S,D) -> (B,S,D)."""
    D = cfg.d_model
    nh = cfg.n_heads
    inner = int(cfg.xlstm.mlstm_proj_factor * D)
    P = inner // nh
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xg, xc = up[..., :inner], up[..., inner:]
    xconv = jax.nn.silu(_causal_conv(xc, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bse,ef->bsf", xconv, p["wq"]).reshape(*x.shape[:2], nh, P)
    k = jnp.einsum("bse,ef->bsf", xconv, p["wk"]).reshape(*x.shape[:2], nh, P)
    v = jnp.einsum("bse,ef->bsf", xc, p["wv"]).reshape(*x.shape[:2], nh, P)
    gates = jnp.einsum("bse,eg->bsg", xconv, p["w_if"])
    i_gate, f_gate = gates[..., :nh], gates[..., nh:]
    y, _ = mlstm_chunked(q, k, v, i_gate, f_gate, chunk=cfg.xlstm.chunk)
    y = y.reshape(*x.shape[:2], inner).astype(x.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(xg)
    return jnp.einsum("bse,ed->bsd", y, p["w_down"])


def mlstm_init_cache(cfg, batch: int) -> Dict[str, jnp.ndarray]:
    D = cfg.d_model
    nh = cfg.n_heads
    inner = int(cfg.xlstm.mlstm_proj_factor * D)
    P = inner // nh
    return {
        "conv": jnp.zeros((batch, 3, inner), jnp.float32),
        "C": jnp.zeros((batch, nh, P, P), jnp.float32),
        "n": jnp.zeros((batch, nh, P), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_decode(
    p: Dict[str, Any], x: jnp.ndarray, cache: Dict[str, jnp.ndarray], cfg
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    D = cfg.d_model
    nh = cfg.n_heads
    inner = int(cfg.xlstm.mlstm_proj_factor * D)
    P = inner // nh
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xg, xc = up[..., :inner], up[..., inner:]
    win = jnp.concatenate([cache["conv"], xc.astype(jnp.float32)], axis=1)
    xconv = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(jnp.float32)) + p["conv_b"])
    q = (xconv @ p["wq"]).reshape(-1, nh, P).astype(jnp.float32)
    k = (xconv @ p["wk"]).reshape(-1, nh, P).astype(jnp.float32)
    v = jnp.einsum("bse,ef->bsf", xc, p["wv"])[:, 0].reshape(-1, nh, P).astype(jnp.float32)
    gates = xconv @ p["w_if"].astype(jnp.float32)
    i_t, f_t = gates[:, :nh], gates[:, nh:]
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + cache["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(logf + cache["m"] - m_new)
    scale = P ** -0.5
    C = f_p[..., None, None] * cache["C"] + i_p[..., None, None] * jnp.einsum(
        "bhp,bhr->bhpr", v, k
    )
    n = f_p[..., None] * cache["n"] + i_p[..., None] * k
    num = jnp.einsum("bhpr,bhr->bhp", C, q * scale)
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", n, q * scale))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = h.reshape(-1, 1, inner).astype(x.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(xg)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    return out, {"conv": win[:, 1:], "C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------- sLSTM ----

def slstm_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    ff = int(cfg.xlstm.slstm_ff_factor * D)
    return {
        "conv_w": dense_init(key_gen(), (4, D), dtype, fan_in=4),
        "conv_b": jnp.zeros((D,), dtype),
        # input projections for gates z, i, f, o
        "w_gates": dense_init(key_gen(), (D, 4 * D), dtype),
        # block-diagonal recurrent weights per head: (4 gates, nh, hd, hd)
        "r_gates": dense_init(key_gen(), (4, nh, hd, hd), dtype, fan_in=hd),
        "gn": jnp.ones((D,), dtype),
        "ff_gate": dense_init(key_gen(), (D, ff), dtype),
        "ff_up": dense_init(key_gen(), (D, ff), dtype),
        "ff_down": dense_init(key_gen(), (ff, D), dtype),
    }


def _slstm_cell(p, xg, state):
    """One step. xg: (B, 4D) input-gate preactivations; state pytree."""
    h, c, n, m = state  # h,c,n: (B,nh,hd); m: (B,nh)
    B = xg.shape[0]
    nh, hd = h.shape[1], h.shape[2]
    rec = jnp.einsum("bhp,ghpr->bghr", h, p["r_gates"].astype(jnp.float32))
    pre = xg.astype(jnp.float32).reshape(B, 4, nh, hd) + rec
    z_t = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1].mean(-1)  # per-head scalar gates
    f_t = pre[:, 2].mean(-1)
    o_t = jax.nn.sigmoid(pre[:, 3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_p = jnp.exp(i_t - m_new)[..., None]
    f_p = jnp.exp(logf + m - m_new)[..., None]
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(p: Dict[str, Any], x: jnp.ndarray, cfg) -> jnp.ndarray:
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    B, S, _ = x.shape
    xconv = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    xg = jnp.einsum("bsd,dg->bsg", xconv, p["w_gates"])  # (B,S,4D)

    state0 = (
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.zeros((B, nh, hd), jnp.float32),
        jnp.full((B, nh), -1e30, jnp.float32),
    )

    def step(state, xg_t):
        new = _slstm_cell(p, xg_t, state)
        return new, new[0]

    _, hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["gn"])
    # gated FFN (factor 4/3)
    ff = jax.nn.silu(jnp.einsum("bsd,df->bsf", y, p["ff_gate"])) * jnp.einsum(
        "bsd,df->bsf", y, p["ff_up"]
    )
    return jnp.einsum("bsf,fd->bsd", ff, p["ff_down"])


def slstm_init_cache(cfg, batch: int) -> Dict[str, jnp.ndarray]:
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    return {
        "conv": jnp.zeros((batch, 3, D), jnp.float32),
        "h": jnp.zeros((batch, nh, hd), jnp.float32),
        "c": jnp.zeros((batch, nh, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def slstm_decode(
    p: Dict[str, Any], x: jnp.ndarray, cache: Dict[str, jnp.ndarray], cfg
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    D = cfg.d_model
    win = jnp.concatenate([cache["conv"], x[:, 0:1].astype(jnp.float32)], axis=1)
    xconv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    )
    xg = xconv @ p["w_gates"].astype(jnp.float32)
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_cell(p, xg, state)
    B = x.shape[0]
    y = h.reshape(B, 1, D).astype(x.dtype)
    y = rms_norm(y, p["gn"])
    ff = jax.nn.silu(jnp.einsum("bsd,df->bsf", y, p["ff_gate"])) * jnp.einsum(
        "bsd,df->bsf", y, p["ff_up"]
    )
    out = jnp.einsum("bsf,fd->bsd", ff, p["ff_down"])
    return out, {"conv": win[:, 1:], "h": h, "c": c, "n": n, "m": m}
