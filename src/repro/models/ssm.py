"""Mamba2 — chunked SSD (state-space dual) formulation, TPU-adapted.

The GPU reference implements SSD with fused Triton kernels over sequence
chunks. The TPU mapping keeps the same chunk decomposition — intra-chunk
quadratic (MXU-friendly masked matmuls) + inter-chunk recurrent state pass
(lax.scan over chunks) — with chunk length tuned for VMEM (see
kernels/mamba_scan.py for the Pallas version; this module is the pure-jnp
reference and the CPU/dry-run path).

Selective-state dynamics per head h with state N, head dim P:
  α_t = exp(a_h · Δ_t)          (decay; a_h = −exp(A_log_h) < 0)
  H_t = α_t · H_{t−1} + Δ_t · B_t ⊗ x_t     (H: N×P)
  y_t = C_t · H_t + D_h · x_t
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm


def mamba_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.n_heads(D)
    N = s.d_state
    return {
        # in_proj → [z (di), x (di), B (N), C (N), dt (nh)]
        "w_in": dense_init(key_gen(), (D, 2 * di + 2 * N + nh), dtype),
        "conv_w": dense_init(key_gen(), (s.d_conv, di + 2 * N), dtype, fan_in=s.d_conv),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "w_out": dense_init(key_gen(), (di, D), dtype),
    }


def _split_in(proj: jnp.ndarray, di: int, N: int, nh: int):
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * N]
    dt = proj[..., di + di + 2 * N :]
    return z, xbc, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-channel causal conv along S. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K=4: unrolled adds, no gather
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(
    xh: jnp.ndarray,  # (B, S, nh, P) inputs per head
    dt: jnp.ndarray,  # (B, S, nh) softplus'd step sizes
    a: jnp.ndarray,  # (nh,) negative decay rates
    B_ssm: jnp.ndarray,  # (B, S, N)
    C_ssm: jnp.ndarray,  # (B, S, N)
    chunk: int,
    h0: jnp.ndarray = None,  # (B, nh, N, P) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked scan; returns (y (B,S,nh,P), final state (B,nh,N,P)).

    named_scope ⇒ roofline-attributable to kernels/ssd."""
    with jax.named_scope("kernel_ssd_scan"):
        return _ssd_chunked_impl(xh, dt, a, B_ssm, C_ssm, chunk, h0)


def _ssd_chunked_impl(xh, dt, a, B_ssm, C_ssm, chunk, h0=None):
    Bb, S, nh, P = xh.shape
    N = B_ssm.shape[-1]
    if S % chunk:  # serving prompts: largest divisor ≤ chunk keeps exactness
        chunk = next(c for c in range(min(chunk, S), 0, -1) if S % c == 0)
    nc = S // chunk

    xc = xh.reshape(Bb, nc, chunk, nh, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bb, nc, chunk, nh).transpose(1, 0, 2, 3)
    Bc = B_ssm.reshape(Bb, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = C_ssm.reshape(Bb, nc, chunk, N).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((Bb, nh, N, P), jnp.float32)

    def body(h, xs):
        xi, dti, Bi, Ci = xs  # (B,L,nh,P), (B,L,nh), (B,L,N), (B,L,N)
        la = dti * a  # (B,L,nh) log-decay per step (≤0)
        cum = jnp.cumsum(la, axis=1)  # (B,L,nh)
        # intra-chunk: T_ij = exp(cum_i − cum_j) for j ≤ i
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,L,L,nh)
        ii = jnp.arange(xi.shape[1])
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        T = jnp.where(causal, jnp.exp(diff), 0.0)
        CB = jnp.einsum("bin,bjn->bij", Ci, Bi)  # (B,L,L)
        W = T * CB[..., None] * dti[:, None, :, :]  # (B,L_i,L_j,nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xi.astype(jnp.float32))
        # inter-chunk: y_i += C_i · (exp(cum_i) · h_prev)
        y_inter = jnp.einsum(
            "bin,bhnp,bih->bihp", Ci, h, jnp.exp(cum)
        )
        # state update: h ← exp(cum_L)·h + Σ_j exp(cum_L − cum_j)·Δ_j·(B_j ⊗ x_j)
        last = cum[:, -1:, :]  # (B,1,nh)
        to_end = jnp.exp(last - cum) * dti  # (B,L,nh)
        h_add = jnp.einsum("bjh,bjn,bjhp->bhnp", to_end, Bi, xi.astype(jnp.float32))
        h_new = jnp.exp(last[:, 0, :])[:, :, None, None] * h + h_add
        return h_new, (y_intra + y_inter)

    h_final, yc = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, S, nh, P)
    return y, h_final


def mamba_block(
    p: Dict[str, Any], x: jnp.ndarray, cfg
) -> jnp.ndarray:
    """Full Mamba2 mixer: (B,S,D) -> (B,S,D)."""
    s = cfg.ssm
    D = cfg.d_model
    di, nh, N = s.d_inner(D), s.n_heads(D), s.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_in(proj, di, N, nh)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, B_ssm, C_ssm = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(*xin.shape[:2], nh, s.head_dim)
    y, _ = ssd_chunked(xh, dt, a, B_ssm, C_ssm, chunk=s.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    return jnp.einsum("bse,ed->bsd", y, p["w_out"])


# -- decode (single token) ----------------------------------------------------------

def mamba_init_cache(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    D = cfg.d_model
    di, nh, N = s.d_inner(D), s.n_heads(D), s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * N), dtype),
        "h": jnp.zeros((batch, nh, N, s.head_dim), jnp.float32),
    }


def mamba_decode(
    p: Dict[str, Any], x: jnp.ndarray, cache: Dict[str, jnp.ndarray], cfg
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, 1, D) one token; O(1) state update."""
    s = cfg.ssm
    D = cfg.d_model
    di, nh, N = s.d_inner(D), s.n_heads(D), s.d_state
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_in(proj, di, N, nh)
    # conv over [cached K−1 inputs, current]
    win = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    xin, B_ssm, C_ssm = xbc1[..., :di], xbc1[..., di : di + N], xbc1[..., di + N :]
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    a = -jnp.exp(p["a_log"])
    alpha = jnp.exp(dt1 * a)  # (B,nh)
    xh = xin[:, 0].reshape(-1, nh, s.head_dim)  # (B,nh,P)
    h = cache["h"] * alpha[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt1, B_ssm[:, 0], xh.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", C_ssm[:, 0], h)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"conv": win[:, 1:], "h": h}
