"""Attention: chunked online-softmax (flash-style) core, GQA projections with
qk-norm / bias / sliding-window / cross-attention, MLA (DeepSeek) with the
compressed-cache *absorbed* decode path, and single-token decode attention.

The chunked core is the pure-jnp reference the Pallas flash kernel is
validated against (kernels/ref.py imports it); it is also the default
compute path on CPU and for the dry-run — it never materializes an S×S
score matrix, so 32k prefill lowers with bounded memory.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def _gqa_repeat(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*groups, hd) by repeating each kv head."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,  # (B, Sk, KV, hd_v)
    *,
    causal: bool = True,
    window: int = 0,  # sliding window (0 = full)
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    chunk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention, scanning over KV chunks. O(S·chunk) memory.

    Wrapped in a named_scope so the roofline analyzer can attribute this
    region's HBM traffic to the Pallas flash kernel (kernels/flash_attention)
    which keeps the score tiles in VMEM on TPU."""
    with jax.named_scope("kernel_flash_attn"):
        sq = q.shape[1]
        if sq <= chunk:
            return _chunked_attention_impl(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                chunk=chunk, scale=scale,
            )
        # q-tiling: the live score block is (B, H, chunk, chunk) instead of
        # (B, H, Sq, chunk) — bounds prefill/train attention memory in both
        # dims (the Pallas kernel tiles identically in VMEM)
        nq = -(-sq // chunk)
        pad_q = nq * chunk - sq
        qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
        qb = qp.reshape(q.shape[0], nq, chunk, *q.shape[2:])

        def one_block(args):
            q_blk, qi = args
            return _chunked_attention_impl(
                q_blk, k, v, causal=causal, window=window,
                q_offset=q_offset + qi * chunk, chunk=chunk, scale=scale,
            )

        out = jax.lax.map(one_block, (qb.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
        out = out.transpose(1, 0, 2, 3, 4).reshape(
            q.shape[0], nq * chunk, q.shape[2], out.shape[-1]
        )
        return out[:, :sq] if pad_q else out


def _chunked_attention_impl(q, k, v, *, causal, window, q_offset, chunk, scale):
    b, sq, h, hd = q.shape
    _, sk, kv_heads, _ = k.shape
    hd_v = v.shape[-1]
    groups = h // kv_heads
    k = _gqa_repeat(k, groups)
    v = _gqa_repeat(v, groups)
    scale = scale if scale is not None else hd ** -0.5

    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, h, hd_v).transpose(1, 0, 2, 3, 4)

    qs = q * scale
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, ci = xs
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqhd,bkhd->bhqk", qs, kci, preferred_element_type=jnp.float32
        )
        mask = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
            (sq, chunk), jnp.bool_
        )
        mask = mask & (k_pos[None, :] < sk)  # chunk padding
        if window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nchunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, hd_v)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S_max, KV, hd)
    v_cache: jnp.ndarray,  # (B, S_max, KV, hd_v)
    cache_len: jnp.ndarray,  # () or (B,) — number of valid cache entries
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention over a KV cache — O(S) compute, no S×S.

    named_scope ⇒ roofline-attributable to kernels/decode_attention."""
    with jax.named_scope("kernel_decode_attn"):
        return _decode_attention_impl(
            q, k_cache, v_cache, cache_len, window=window, scale=scale
        )


def _decode_attention_impl(q, k_cache, v_cache, cache_len, *, window, scale):
    """GQA-aware: q is regrouped (B, KV, G, hd) and contracted directly
    against the kv-headed cache — the (B, S, H, hd) repeat of the cache is
    never materialized (the decode kernel uses the same kv-major layout)."""
    b, _, h, hd = q.shape
    s_max, kv_heads = k_cache.shape[1], k_cache.shape[2]
    groups = h // kv_heads
    scale = scale if scale is not None else hd ** -0.5
    qg = (q[:, 0] * scale).reshape(b, kv_heads, groups, hd)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(s_max)
    cl = jnp.asarray(cache_len)
    valid = pos[None, :] < (cl[:, None] if cl.ndim else cl[None, None])
    if window:
        lo = (cl if cl.ndim else cl[None]) - window
        valid = valid & (pos[None, :] >= lo[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, hd).astype(q.dtype)  # (B, 1, H, hd_v)


# -- GQA attention block ----------------------------------------------------------

def gqa_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p: Dict[str, Any] = {
        "wq": dense_init(key_gen(), (D, H, hd), dtype),
        "wk": dense_init(key_gen(), (D, KV, hd), dtype),
        "wv": dense_init(key_gen(), (D, KV, hd), dtype),
        "wo": dense_init(key_gen(), (H, hd, D), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def gqa_project_qkv(
    p: Dict[str, Any], x: jnp.ndarray, positions: jnp.ndarray, cfg, *, rope: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(
    p: Dict[str, Any],
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S) or (S,)
    cfg,
    *,
    causal: bool = True,
) -> jnp.ndarray:
    q, k, v = gqa_project_qkv(p, x, positions, cfg)
    out = chunked_attention(q, k, v, causal=causal, window=cfg.swa_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_decode(
    p: Dict[str, Any],
    x: jnp.ndarray,  # (B, 1, D)
    cache: Dict[str, jnp.ndarray],  # {k: (B, S_max, KV, hd), v: ..., len: ()}
    cfg,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    pos = cache["len"]  # scalar current length
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = gqa_project_qkv(p, x, positions, cfg)
    # append to cache (ring-buffer for SWA: wrap position)
    s_max = cache["k"].shape[1]
    slot = (pos % s_max) if cfg.swa_window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_len = pos + 1
    if cfg.swa_window:
        # ring buffer: all s_max entries valid once len ≥ s_max; positions
        # beyond the window are masked by effective length min(len, s_max).
        eff = jnp.minimum(new_len, s_max)
        out = decode_attention(q, k_cache, v_cache, eff)
    else:
        out = decode_attention(q, k_cache, v_cache, new_len)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache, "len": new_len}


# -- Cross-attention (VLM / enc-dec) -----------------------------------------------

def cross_attn_params(key_gen, cfg, dtype, gated: bool = False) -> Dict[str, Any]:
    p = gqa_params(key_gen, cfg, dtype)
    p["k_input_norm"] = jnp.ones((cfg.d_model,), dtype)
    if gated:
        p["gate"] = jnp.zeros((), dtype)
    return p


def cross_attention(
    p: Dict[str, Any],
    x: jnp.ndarray,  # (B, Sq, D) queries
    memory: jnp.ndarray,  # (B, Sm, D) encoder / vision states
    cfg,
) -> jnp.ndarray:
    mem = rms_norm(memory, p["k_input_norm"])
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    out = chunked_attention(q, k, v, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(y.dtype) * y
    return y


# -- MLA (DeepSeek-V2) ---------------------------------------------------------------

def mla_params(key_gen, cfg, dtype) -> Dict[str, Any]:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": dense_init(key_gen(), (D, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(key_gen(), (m.q_lora_rank, H, qk_hd), dtype),
        "w_dkv": dense_init(key_gen(), (D, m.kv_lora_rank), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_krope": dense_init(key_gen(), (D, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(key_gen(), (m.kv_lora_rank, H, m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(key_gen(), (m.kv_lora_rank, H, m.v_head_dim), dtype),
        "wo": dense_init(
            key_gen(), (H, m.v_head_dim, D), dtype, fan_in=H * m.v_head_dim
        ),
    }


def _mla_q(p, x, positions, cfg):
    m = cfg.mla
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(
    p: Dict[str, Any], x: jnp.ndarray, positions: jnp.ndarray, cfg
) -> jnp.ndarray:
    """Prefill/training path: expand K/V per head from the compressed cache.

    Heads are sharded over the model axis, so the expanded K/V is bounded:
    (B, S, H/shards, hd) per device.
    """
    m = cfg.mla
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_krope"])[:, :, None, :], positions, cfg.rope_theta
    )  # (B, S, 1, rope_hd) — shared across heads
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    h = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = chunked_attention(q, k, v, causal=True, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(
    p: Dict[str, Any],
    x: jnp.ndarray,  # (B, 1, D)
    cache: Dict[str, jnp.ndarray],  # {c_kv: (B, S_max, r), k_rope: (B, S_max, rope_hd), len}
    cfg,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed decode: attention runs in the compressed latent space —
    the cache stores only (c_kv, k_rope); W_uk is absorbed into the query
    and W_uv applied after, so per-token work is O(S·r) not O(S·H·hd)."""
    m = cfg.mla
    pos = cache["len"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)  # (B,1,H,·)
    c_new = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    kr_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_krope"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos, axis=1)
    new_len = pos + 1

    # scores: q_nope absorbed through W_uk → latent space
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"])  # (B,H,r)
    s_lat = jnp.einsum("bhr,bmr->bhm", q_lat, c_kv)
    s_rope = jnp.einsum("bhk,bmk->bhm", q_rope[:, 0], k_rope)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (s_lat + s_rope) * scale
    s_max_len = c_kv.shape[1]
    valid = jnp.arange(s_max_len)[None, :] < new_len
    s = jnp.where(valid[:, None, :], s.astype(jnp.float32), NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", prob, c_kv.astype(jnp.float32))  # (B,H,r)
    o = jnp.einsum("bhr,rhk->bhk", o_lat.astype(x.dtype), p["w_uv"])  # (B,H,v_hd)
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return y, {"c_kv": c_kv, "k_rope": k_rope, "len": new_len}
