"""ModelConfig — one declarative config covers all 10 assigned architectures.

Families:
  ``dense``   decoder-only transformer (GQA/MQA, optional qk-norm/bias/SWA)
  ``moe``     dense attention + mixture-of-experts FFN (optional MLA, shared experts)
  ``vlm``     dense backbone with periodic cross-attention layers (vision stub)
  ``ssm``     xLSTM: mLSTM blocks with periodic sLSTM blocks
  ``hybrid``  Mamba2 backbone with a periodic *shared* attention block (Zamba2)
  ``audio``   encoder-decoder transformer (speech frontend stub) — Seamless

All sizes are the exact published configs (see repro/configs/*.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    expert_ff: int = 0  # routed-expert hidden size
    num_shared: int = 0  # shared (always-on) experts (DeepSeek)
    capacity_factor: float = 1.25
    # first k layers use a dense FFN instead of MoE (DeepSeek first_k_dense_replace)
    first_k_dense: int = 0
    dense_ff: int = 0  # hidden size for those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (Zamba2 backbone)."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: mLSTM (matrix-state) + periodic sLSTM (scalar-state) blocks."""

    slstm_every: int = 8  # every k-th block is sLSTM (0 = pure mLSTM)
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 1.333
    chunk: int = 64  # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads (Qwen3 overrides)
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    swa_window: int = 0  # 0 = full attention
    rope_theta: float = 10_000.0
    # norm / activation
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | relu2 | gelu
    # family-specific blocks
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # vlm: every k-th layer is a gated cross-attention layer
    cross_attn_every: int = 0
    num_image_tokens: int = 1024
    # hybrid (Zamba2): shared attention block applied every k mamba layers
    shared_attn_every: int = 0
    # audio / enc-dec
    n_encoder_layers: int = 0  # >0 ⇒ encoder-decoder; n_layers = decoder layers
    encoder_seq: int = 1024  # stub frontend frames
    # embeddings
    tie_embeddings: bool = False
    vocab_pad_to: int = 0  # pad vocab to a multiple (sharding divisibility)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # -- derived --------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to and self.vocab_size % self.vocab_pad_to:
            return self.vocab_size + self.vocab_pad_to - self.vocab_size % self.vocab_pad_to
        return self.vocab_size

    @property
    def is_enc_dec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (sub-quadratic sequence mixing)."""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6·N·D roofline + memory planning) -----------------
    def param_count(self) -> Tuple[int, int]:
        """Returns (total_params, active_params_per_token)."""
        D, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim_
        V = self.padded_vocab
        embed = V * D * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.mla:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                return (
                    D * m.q_lora_rank
                    + m.q_lora_rank * H * qk_hd
                    + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                    + H * m.v_head_dim * D
                )
            return D * H * hd + 2 * D * KV * hd + H * hd * D

        def dense_ff_params(ff: int) -> int:
            mult = 3 if self.activation == "swiglu" else 2
            return mult * D * ff

        total = 0
        active = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn_params() + dense_ff_params(self.d_ff)
            n_cross = self.n_layers // self.cross_attn_every if self.cross_attn_every else 0
            total = self.n_layers * per_layer + n_cross * attn_params()
            total += self.n_encoder_layers * (attn_params() + dense_ff_params(self.d_ff))
            if self.is_enc_dec:  # decoder cross-attention
                total += self.n_layers * attn_params()
            active = total
        elif self.family == "moe":
            m = self.moe
            router = D * m.num_experts
            routed = m.num_experts * dense_ff_params(m.expert_ff)
            shared = m.num_shared * dense_ff_params(m.expert_ff)
            n_moe = self.n_layers - m.first_k_dense
            total = self.n_layers * attn_params()
            total += m.first_k_dense * dense_ff_params(m.dense_ff)
            total += n_moe * (router + routed + shared)
            active = self.n_layers * attn_params()
            active += m.first_k_dense * dense_ff_params(m.dense_ff)
            active += n_moe * (router + (m.top_k + m.num_shared) * dense_ff_params(m.expert_ff))
        elif self.family == "ssm":
            x = self.xlstm
            inner = int(x.mlstm_proj_factor * D)
            n_s = self.n_layers // x.slstm_every if x.slstm_every else 0
            n_m = self.n_layers - n_s
            mlstm = 2 * D * inner + 3 * inner * inner // max(self.n_heads, 1) + inner * D
            # sLSTM: 4 gates × (input + recurrent per-head) + FFN
            hd_s = D // self.n_heads
            slstm = 4 * (D * D + self.n_heads * hd_s * hd_s) + 2 * D * int(x.slstm_ff_factor * D)
            total = n_m * mlstm + n_s * slstm
            active = total
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(D)
            nh = s.n_heads(D)
            mamba = D * (2 * di + 2 * s.d_state + nh) + di * s.d_conv + di * D
            n_shared = self.n_layers // self.shared_attn_every if self.shared_attn_every else 0
            shared_blk = attn_params() + dense_ff_params(self.d_ff)
            total = self.n_layers * mamba + shared_blk  # weights shared: counted once
            active = self.n_layers * mamba + n_shared * shared_blk
        else:
            raise ValueError(self.family)
        return total + embed, active + embed
