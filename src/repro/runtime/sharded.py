"""ShardedBackend — the jit data plane spread across ``jax.devices()``.

Each segment is pinned to one device by a pluggable
:class:`~repro.runtime.scheduler.PlacementPolicy` (round-robin by default —
the Storm scheme generalized from worker slots to devices). A segment's
task states live on its device; boundary batches fetched from the transport
are moved to the consuming segment's device before the jitted step, so
cross-device streams pay exactly one transfer per hop — the device-mesh
analogue of the paper's broker indirection.

Placement bookkeeping (slot map, EWMA device aggregates with idle decay,
policy-driven straggler migration, restore-time sticky hints) is shared
with the multiproc backend via
:class:`~repro.runtime.scheduler.PlacedBackendMixin`.

On a single-device host this degenerates to :class:`InProcessJitBackend`
with placement bookkeeping (useful in CI); with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` or real accelerator
meshes the same code shards the segment set N ways.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Union

import jax

from repro.core.graph import Dataflow

from .backend import SegmentSpec
from .executor import InProcessJitBackend
from .scheduler import PlacedBackendMixin, PlacementPolicy
from .segment import Segment


class ShardedBackend(PlacedBackendMixin, InProcessJitBackend):
    name = "sharded"

    def __init__(
        self,
        placement: Union[str, PlacementPolicy] = "round_robin",
        devices: Optional[Sequence[Any]] = None,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        ewma_decay: float = 0.6,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
        transport: Any = "inproc",
        transport_options: Optional[Dict[str, Any]] = None,
    ):
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            max_workers=max_workers,
            transport=transport,
            transport_options=transport_options,
        )
        self.devices: List[Any] = list(devices) if devices is not None else list(jax.devices())
        if not self.devices:
            raise ValueError("ShardedBackend needs at least one device")
        self._init_placement(placement, ewma_decay=ewma_decay)

    # -- placement hooks (PlacedBackendMixin) -----------------------------------
    def _n_slots(self) -> int:
        return len(self.devices)

    def _move_segment(self, seg: Segment, old: int, new: int) -> None:
        """Migrate a segment's buffers: the compiled executable is
        device-agnostic; only task states move."""
        dev = self.devices[new]
        seg.states = jax.device_put(seg.states, dev)
        seg.active = jax.device_put(seg.active, dev)

    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]],
    ) -> Segment:
        seg = super()._build(spec, dataflow, init_states)
        idx = self._assign_slot(spec)
        dev = self.devices[idx]
        seg.states = jax.device_put(seg.states, dev)
        seg.active = jax.device_put(seg.active, dev)
        return seg

    def _fetch_inputs(self, seg: Segment, copy: bool = False) -> Dict[str, Any]:
        """Move boundary batches onto the consuming segment's device (one
        transfer per cross-segment hop); per-topic synchronization comes
        from the base fetch (concurrent steps sync on producers only)."""
        dev = self.devices[self.device_of[seg.spec.name]]
        return {
            t: jax.device_put(batch, dev)
            for t, batch in super()._fetch_inputs(seg, copy=copy).items()
        }

    def _gather_inputs(self, seg: Segment):
        # No view path here: device_put on the host platform may alias
        # numpy memory, so shm ring views must be privatized *before* the
        # transfer — fetch with copy=True on lappable transports instead
        # of revalidating after the fact.
        copy = getattr(self.transport, "fetch_view", None) is not None
        return self._fetch_inputs(seg, copy=copy), {}

    # -- durability hooks ---------------------------------------------------------
    def _dump_extra(self) -> Dict[str, Any]:
        extra = super()._dump_extra()
        extra["device_of"] = {name: int(i) for name, i in self.device_of.items()}
        extra["n_devices"] = len(self.devices)
        return extra

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        super()._restore_extra(extra)
        self.device_of_at_checkpoint = {
            name: int(i) for name, i in extra.get("device_of", {}).items()
        }
        if extra.get("n_devices") is not None:
            self._n_slots_at_checkpoint = int(extra["n_devices"])

    def spawn_config(self) -> Dict[str, Any]:
        cfg = super().spawn_config()
        if getattr(self.policy, "name", ""):
            cfg["placement"] = self.policy.name
        return cfg
