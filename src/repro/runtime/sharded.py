"""ShardedBackend — the jit data plane spread across ``jax.devices()``.

Each segment is pinned to one device by a pluggable
:class:`~repro.runtime.scheduler.PlacementPolicy` (round-robin by default —
the Storm scheme generalized from worker slots to devices). A segment's
task states live on its device; boundary batches fetched from the broker
are moved to the consuming segment's device before the jitted step, so
cross-device streams pay exactly one transfer per hop — the device-mesh
analogue of the paper's broker indirection.

On a single-device host this degenerates to :class:`InProcessJitBackend`
with placement bookkeeping (useful in CI); with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` or real accelerator
meshes the same code shards the segment set N ways.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Union

import jax

from repro.core.graph import Dataflow

from .backend import SegmentSpec
from .executor import InProcessJitBackend
from .scheduler import PlacementPolicy, resolve_placement
from .segment import Segment


class ShardedBackend(InProcessJitBackend):
    name = "sharded"

    def __init__(
        self,
        placement: Union[str, PlacementPolicy] = "round_robin",
        devices: Optional[Sequence[Any]] = None,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
    ):
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            max_workers=max_workers,
        )
        self.devices: List[Any] = list(devices) if devices is not None else list(jax.devices())
        if not self.devices:
            raise ValueError("ShardedBackend needs at least one device")
        self.policy = resolve_placement(placement)
        self.device_of: Dict[str, int] = {}  # segment name -> device index
        # checkpoint-time placement of the backend we restored from (if any);
        # informational — restore re-places via the PlacementPolicy, since
        # the restoring host may have a different device pool.
        self.device_of_at_checkpoint: Dict[str, int] = {}

    # -- placement --------------------------------------------------------------
    def device_load(self) -> Dict[int, int]:
        """Device index → deployed task count (paused tasks occupy slots)."""
        load: Dict[int, int] = {}
        for name, seg in self.segments.items():
            idx = self.device_of[name]
            load[idx] = load.get(idx, 0) + len(seg.spec.task_ids)
        return load

    def device_ewma(self) -> Dict[int, float]:
        """Device index → summed EWMA step-time (ms) of its segments — the
        straggler tracker's measured view of device pressure, fed to the
        placement policy on assign *and* redispatch."""
        ewma: Dict[int, float] = {}
        for name, ms in self.ewma_ms.items():
            idx = self.device_of.get(name)
            if idx is not None:
                ewma[idx] = ewma.get(idx, 0.0) + ms
        return ewma

    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]],
    ) -> Segment:
        seg = super()._build(spec, dataflow, init_states)
        idx = self.policy.assign(
            spec, len(self.devices), self.device_load(), ewma=self.device_ewma()
        )
        self.device_of[spec.name] = idx
        dev = self.devices[idx]
        seg.states = jax.device_put(seg.states, dev)
        seg.active = jax.device_put(seg.active, dev)
        return seg

    def kill(self, segment_name: str) -> None:
        super().kill(segment_name)
        self.device_of.pop(segment_name, None)

    def redispatch(self, segment_name: str) -> None:
        """Straggler mitigation with teeth: consult the placement policy for
        a new device and *migrate* the segment's states there (the compiled
        executable is device-agnostic; only buffers move). Static policies
        keep the old stay-put behavior via the default ``redispatch`` hook.
        """
        super().redispatch(segment_name)  # record + reset the EWMA
        seg = self.segments.get(segment_name)
        current = self.device_of.get(segment_name)
        if seg is None or current is None:
            return
        new = self.policy.redispatch(
            seg.spec,
            current,
            len(self.devices),
            self.device_load(),
            ewma=self.device_ewma(),
        )
        if new != current and 0 <= new < len(self.devices):
            dev = self.devices[new]
            seg.states = jax.device_put(seg.states, dev)
            seg.active = jax.device_put(seg.active, dev)
            self.device_of[segment_name] = new

    def _fetch_inputs(self, seg: Segment) -> Dict[str, Any]:
        """Move boundary batches onto the consuming segment's device (one
        transfer per cross-segment hop); per-topic synchronization comes
        from the base fetch (concurrent steps sync on producers only)."""
        dev = self.devices[self.device_of[seg.spec.name]]
        return {
            t: jax.device_put(batch, dev)
            for t, batch in super()._fetch_inputs(seg).items()
        }

    # -- durability hooks ---------------------------------------------------------
    def _dump_extra(self) -> Dict[str, Any]:
        extra = super()._dump_extra()
        extra["device_of"] = {name: int(i) for name, i in self.device_of.items()}
        return extra

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        super()._restore_extra(extra)
        self.device_of_at_checkpoint = {
            name: int(i) for name, i in extra.get("device_of", {}).items()
        }
