"""Stream transports — the pluggable boundary-stream plane.

The paper's merged dataflows run on a distributed DSPS: boundary streams
between partial DAGs cross worker (and host) boundaries through an
Enterprise Service Bus. Our :class:`~repro.runtime.broker.Broker` is the
in-process analogue; this module makes the *transport* a protocol so the
same data plane can ride a single process, a pool of worker processes, or
a TCP link between hosts. Transports plug in by name through a registry
mirroring ``MergeStrategy`` / ``ExecutionBackend`` / ``PlacementPolicy``:

  * ``"inproc"`` — :class:`InProcTransport`, today's topic-granular broker
    (per-topic lock/sequence/condvar) refactored onto the protocol.
    Zero-copy, single-process only.
  * ``"shm"`` — :class:`ShmTransport`, shared-memory ring buffers (one
    mmap-backed file per topic on ``/dev/shm``) with a per-topic sequence
    word and a seqlock read protocol, so worker *processes* publish and
    fetch without pickling through a pipe. This is the default transport
    of the ``multiproc`` backend.
  * ``"tcp"`` — :class:`TcpTransport`, a length-prefixed socket protocol
    against a :class:`TcpBrokerServer` (which wraps an in-process broker),
    so brokers can span hosts.

The protocol surface is exactly what the jit backends already use —

  ``publish / fetch / fetch_synced / drop / seq / sequences / has /
  topics / counters / reset_counters / __len__``

— which is what lets ``_fetch_inputs`` / ``_drop_streams`` ride any
transport untouched. Every transport keeps the broker's concurrency
contract: per-topic sequencing (``fetch_synced(topic, min_seq)`` blocks on
*its* producer only), and ``drop`` wakes in-flight synced fetches with a
``KeyError`` instead of deadlocking (kill/unmerge stay safe mid-step).

Cross-process attachment: transports that can span processes implement
:meth:`Transport.connect_info` (a picklable spec) and workers rebuild a
connected transport from it via :func:`connect_transport`.

This module is deliberately JAX-free; batches are encoded as raw
dtype/shape/bytes (bit-exact for the float32 event tensors).
"""
from __future__ import annotations

import base64
import fcntl
import json
import mmap
import os
import random
import shutil
import socket
import struct
import tempfile
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Set, Tuple, Type, Union

import numpy as np

# The error taxonomy lives in broker.py (the transports wrap a Broker, so
# the in-process transport raises the same types for free) and is
# re-exported here: TransportError > TopicDropped (also a KeyError) >
# TransportTimeout (also a TimeoutError). Supervisor hang-detection can
# classify any transport stall with one `except TransportError`.
from .broker import Broker, TopicDropped, TransportError, TransportTimeout

__all__ = [
    "Transport",
    "TransportError",
    "TopicDropped",
    "TransportTimeout",
    "InProcTransport",
    "ShmTransport",
    "TcpTransport",
    "TcpBrokerServer",
    "register_transport",
    "available_transports",
    "resolve_transport",
    "connect_transport",
]


class Transport:
    """The boundary-stream protocol (see module docstring for the verbs).

    Concrete transports implement the full broker surface; the base class
    only pins down the contract and the cross-process attachment hooks.
    """

    name: str = ""
    # Process-local count of fetch/fetch_view/fetch_synced calls served.
    # Deliberately NOT part of counters() — that dict has an exact-equality
    # checkpoint contract — and not persisted; the obs layer mirrors it
    # into ``repro_transport_fetches`` at scrape time.
    fetch_count: int = 0

    # -- data path ------------------------------------------------------------
    def publish(self, topic: str, batch: Any) -> None:
        raise NotImplementedError

    def fetch(self, topic: str, copy: bool = False) -> Any:
        """Latest batch on ``topic``.

        Zero-copy by default: transports may return a **read-only view**
        into their own buffers (the shm ring, the wire receive buffer);
        such a view is bit-stable only until the producer laps the ring —
        callers that hold batches across steps, or mutate them, pass
        ``copy=True`` for a private writable array.
        """
        raise NotImplementedError

    def fetch_synced(
        self, topic: str, min_seq: int, timeout: float = 60.0, copy: bool = False
    ) -> Any:
        raise NotImplementedError

    def drop(self, topic: str) -> None:
        raise NotImplementedError

    # -- observability --------------------------------------------------------
    def seq(self, topic: str) -> int:
        raise NotImplementedError

    def sequences(self) -> Dict[str, int]:
        raise NotImplementedError

    def has(self, topic: str) -> bool:
        raise NotImplementedError

    def topics(self) -> Dict[str, Any]:
        raise NotImplementedError

    def counters(self) -> Dict[str, int]:
        """Cumulative ``{"bytes_published", "publishes"}`` across all topics."""
        raise NotImplementedError

    def reset_counters(self) -> None:
        raise NotImplementedError

    def restore_counters(self, bytes_published: int, publishes: int) -> None:
        """Set the cumulative counters (checkpoint restore)."""
        raise NotImplementedError

    def __len__(self) -> int:
        return sum(1 for _ in self.sequences())

    # -- lifecycle / attachment ----------------------------------------------
    def close(self) -> None:
        """Release transport resources (idempotent)."""

    def connect_info(self) -> Dict[str, Any]:
        """Picklable spec from which :func:`connect_transport` rebuilds a
        connected transport in another process. Transports that cannot
        span processes raise :class:`TransportError`."""
        raise TransportError(
            f"transport {self.name!r} cannot span processes "
            f"(pick 'shm' or 'tcp' for the multiproc backend)"
        )


# -- batch wire codec -----------------------------------------------------------


def _encode_batch(batch: Any) -> Tuple[Dict[str, Any], bytes]:
    """(header, payload bytes) for one event batch — bit-exact, JAX-free."""
    arr = np.asarray(batch, order="C")
    return {"dtype": arr.dtype.str, "shape": list(arr.shape)}, arr.tobytes()


def _decode_batch(
    header: Dict[str, Any], payload: Any, copy: bool = False
) -> np.ndarray:
    """Payload bytes → event batch.

    Zero-copy by default: the returned array is a **read-only**
    ``frombuffer`` view over ``payload`` (bytes, memoryview, or mmap
    slice); ``copy=True`` materializes a private writable array for the
    callers that mutate or outlive the buffer.
    """
    arr = np.frombuffer(payload, dtype=np.dtype(header["dtype"]))
    arr = arr.reshape(header["shape"])
    if copy:
        return arr.copy()
    if arr.flags.writeable:  # writable source buffer (e.g. an mmap slice)
        arr.flags.writeable = False
    return arr


# -- inproc ---------------------------------------------------------------------


class InProcTransport(Broker, Transport):
    """Today's topic-granular broker on the Transport protocol.

    Zero-copy (device buffers pass by reference) and thread-safe per
    topic, but confined to one process — the ``multiproc`` backend
    rejects it with a clear error.
    """

    name = "inproc"

    def counters(self) -> Dict[str, int]:
        return {
            "bytes_published": int(self.bytes_published),
            "publishes": int(self.publishes),
        }

    def restore_counters(self, bytes_published: int, publishes: int) -> None:
        self.bytes_published = int(bytes_published)
        self.publishes = int(publishes)


# -- shm ------------------------------------------------------------------------

# Topic file layout (little-endian):
#   header (64 B):  magic u32 | version u32 | seq u64 | dropped u32 |
#                   nslots u32 | slot_bytes u64 | topic_bytes_published u64 |
#                   pad to 64
#   then nslots slots, each: slot header (64 B: dtype str16 | ndim u32 |
#   shape u64 x4 | nbytes u64 | pad) + slot_bytes payload capacity.
#
# Single-writer per topic (a running task has exactly one producing
# segment), so the header fields need no cross-process lock; readers use a
# seqlock: read seq, copy the slot, re-read seq — a publish that lapped the
# ring during the copy (seq advanced by >= nslots) forces a retry.
_SHM_MAGIC = 0x5250524F  # "RPRO"
_SHM_VERSION = 1
_HDR = struct.Struct("<IIQIIQQ")  # 40 bytes used, header padded to 64
_HDR_SIZE = 64
_SLOT_HDR = struct.Struct("<16sIIQQQQQ")  # dtype, ndim, pad, shape[4], nbytes
_SLOT_HDR_SIZE = 64
_SHM_NSLOTS = 4
_SHM_READ_RETRIES = 64


def _shm_root() -> str:
    return "/dev/shm" if os.path.isdir("/dev/shm") else tempfile.gettempdir()


def _topic_filename(topic: str) -> str:
    return base64.urlsafe_b64encode(topic.encode("utf-8")).decode("ascii") + ".topic"


def _filename_topic(name: str) -> str:
    return base64.urlsafe_b64decode(name[: -len(".topic")].encode("ascii")).decode(
        "utf-8"
    )


class _ShmTopic:
    """One attached topic file: mmap + parsed geometry."""

    __slots__ = ("mm", "file", "nslots", "slot_bytes", "path", "ino")

    def __init__(self, path: str, file, mm: mmap.mmap, ino: int):
        self.path = path
        self.file = file
        self.mm = mm
        self.ino = ino
        magic, version, _seq, _dropped, nslots, slot_bytes, _tb = _HDR.unpack_from(
            mm, 0
        )
        if magic != _SHM_MAGIC or version != _SHM_VERSION:
            raise TransportError(f"shm topic file {path!r} has a bad header")
        self.nslots = nslots
        self.slot_bytes = slot_bytes

    def read_seq(self) -> int:
        return _HDR.unpack_from(self.mm, 0)[2]

    def read_dropped(self) -> bool:
        return bool(_HDR.unpack_from(self.mm, 0)[3])

    def slot_offset(self, publish_no: int) -> int:
        idx = (publish_no - 1) % self.nslots
        return _HDR_SIZE + idx * (_SLOT_HDR_SIZE + self.slot_bytes)

    def close(self) -> None:
        try:
            self.mm.close()
        except BufferError:
            # A zero-copy fetch view still references this mapping; the OS
            # mapping is released when the last view is garbage-collected.
            pass
        finally:
            self.file.close()


class ShmTransport(Transport):
    """Shared-memory ring-buffer transport.

    Each topic is one fixed-capacity mmap-backed file under a session
    directory (on ``/dev/shm`` when available): a small ring of slots, a
    per-topic publish sequence word, and a per-topic byte counter. The
    directory doubles as the topic registry (one file per live topic), so
    any attached process can enumerate topics; the rare mutating ops
    (drop, counter reset) serialize on an ``flock`` while the publish /
    fetch hot path stays lock-free (single writer + seqlock readers).

    ``fetch_synced`` spins on the sequence word (with a micro-sleep), so a
    consumer process blocks on *its* producer's publish exactly like the
    in-process broker's condition variable — and a concurrent ``drop``
    wakes it with a ``KeyError`` via the dropped flag.

    ``slot_bytes`` bounds one batch's payload; topics size themselves from
    their first batch (with headroom) and raise a clear error if a later
    batch outgrows the ring.
    """

    name = "shm"

    def __init__(
        self,
        dir: Optional[str] = None,
        slot_bytes: Optional[int] = None,
        nslots: int = _SHM_NSLOTS,
    ):
        self._owner = dir is None
        if dir is None:
            dir = tempfile.mkdtemp(prefix=f"repro-shm-{uuid.uuid4().hex[:8]}-", dir=_shm_root())
        self.dir = dir
        self.slot_bytes = slot_bytes
        self.nslots = nslots
        self._attached: Dict[str, _ShmTopic] = {}
        self._lock = threading.Lock()  # guards the attach cache (thread side)
        # Dropped/stale incarnations are parked here instead of being
        # closed in place: a concurrent reader may still hold the mapping
        # (closing it mid-read would turn the contract KeyError into a
        # ValueError on a dead mmap). They are closed on close().
        self._retired: List[_ShmTopic] = []
        self._closed = False
        if self._owner:
            self._write_meta({"graveyard_bytes": 0, "graveyard_publishes": 0,
                              "base_bytes": 0, "base_publishes": 0})

    # -- registry / meta -------------------------------------------------------
    def _path(self, topic: str) -> str:
        return os.path.join(self.dir, _topic_filename(topic))

    def _meta_path(self) -> str:
        return os.path.join(self.dir, "meta.json")

    def _flock(self):
        lock_path = os.path.join(self.dir, ".lock")
        f = open(lock_path, "a+")
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        return f

    def _read_meta(self) -> Dict[str, int]:
        try:
            with open(self._meta_path()) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return {"graveyard_bytes": 0, "graveyard_publishes": 0,
                    "base_bytes": 0, "base_publishes": 0}

    def _write_meta(self, meta: Dict[str, int]) -> None:
        tmp = self._meta_path() + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, self._meta_path())

    # -- attachment ------------------------------------------------------------
    def _attach(self, topic: str, create_bytes: Optional[int] = None) -> Optional[_ShmTopic]:
        """Attach (or create, when ``create_bytes`` is set) a topic file.

        The cache is invalidated when the on-disk incarnation changed
        (drop + re-publish creates a fresh file with a new inode)."""
        path = self._path(topic)
        with self._lock:
            cached = self._attached.get(topic)
            if cached is not None:
                try:
                    ino = os.stat(path).st_ino
                except FileNotFoundError:
                    ino = None
                if ino == cached.ino and not cached.read_dropped():
                    return cached
                self._retired.append(cached)  # maybe still mid-read elsewhere
                del self._attached[topic]
            if create_bytes is None:
                try:
                    f = open(path, "r+b")
                except FileNotFoundError:
                    return None
            else:
                slot_bytes = self.slot_bytes or max(4 * create_bytes, 1 << 16)
                size = _HDR_SIZE + self.nslots * (_SLOT_HDR_SIZE + slot_bytes)
                tmp = path + f".tmp{os.getpid()}"
                with open(tmp, "wb") as tf:
                    tf.truncate(size)
                    buf = bytearray(_HDR_SIZE)
                    _HDR.pack_into(buf, 0, _SHM_MAGIC, _SHM_VERSION, 0, 0,
                                   self.nslots, slot_bytes, 0)
                    tf.seek(0)
                    tf.write(bytes(buf))
                os.replace(tmp, path)  # single writer — no create race
                f = open(path, "r+b")
            mm = mmap.mmap(f.fileno(), os.fstat(f.fileno()).st_size)
            st = _ShmTopic(path, f, mm, os.fstat(f.fileno()).st_ino)
            self._attached[topic] = st
            return st

    # -- data path -------------------------------------------------------------
    def publish(self, topic: str, batch: Any) -> None:
        header, payload = _encode_batch(batch)
        st = self._attach(topic)
        if st is None or st.read_dropped():
            st = self._attach(topic, create_bytes=len(payload))
        if len(payload) > st.slot_bytes:
            raise TransportError(
                f"batch of {len(payload)} B exceeds topic {topic!r} ring slot "
                f"capacity {st.slot_bytes} B — construct ShmTransport with a "
                f"larger slot_bytes"
            )
        seq = st.read_seq()
        off = st.slot_offset(seq + 1)
        shape = list(header["shape"])[:4] + [0] * max(0, 4 - len(header["shape"]))
        if len(header["shape"]) > 4:
            raise TransportError("shm transport carries batches of rank <= 4")
        _SLOT_HDR.pack_into(
            st.mm, off,
            header["dtype"].encode("ascii"), len(header["shape"]), 0,
            shape[0], shape[1], shape[2], shape[3], len(payload),
        )
        st.mm[off + _SLOT_HDR_SIZE: off + _SLOT_HDR_SIZE + len(payload)] = payload
        # publish point: bump seq (and the single-writer byte counter) last
        _, _, _, dropped, nslots, slot_bytes, tb = _HDR.unpack_from(st.mm, 0)
        _HDR.pack_into(st.mm, 0, _SHM_MAGIC, _SHM_VERSION, seq + 1, 0,
                       nslots, slot_bytes, tb + len(payload))

    def _read_latest(
        self, st: _ShmTopic, topic: str, copy: bool = False
    ) -> Tuple[np.ndarray, int]:
        """Seqlock read of the latest slot → ``(batch, seq)``.

        Validity: the slot of publish #``seq`` is rewritten only while
        publish #``seq + nslots`` is in flight, during which the sequence
        word already reads ``seq + nslots - 1`` — so a slot image (copy
        *or* view) is consistent iff the post-read sequence is strictly
        below ``seq + nslots - 1``. An exactly-one-lap writer (post-read
        sequence ``== seq + nslots - 1``) may already be tearing the slot,
        hence the strict bound.

        Under a sustained fast writer every attempt can land inside the
        tear window; a tight retry loop then fails spuriously on a
        perfectly healthy topic. Retries therefore back off with a
        jittered micro-sleep (~1 µs doubling to ~1 ms) so the reader
        desynchronizes from the writer cadence and lands in a gap.
        """
        delay = 1e-6
        for attempt in range(_SHM_READ_RETRIES):
            if attempt:
                time.sleep(delay * (0.5 + random.random()))
                delay = min(delay * 2.0, 1e-3)
            seq = st.read_seq()
            if st.read_dropped() or seq == 0:
                raise TopicDropped(f"no data published on topic {topic!r}")
            off = st.slot_offset(seq)
            dtype_b, ndim, _pad, s0, s1, s2, s3, nbytes = _SLOT_HDR.unpack_from(
                st.mm, off
            )
            start = off + _SLOT_HDR_SIZE
            payload: Any = (
                bytes(st.mm[start: start + nbytes]) if copy
                else memoryview(st.mm)[start: start + nbytes]
            )
            if st.read_seq() < seq + st.nslots - 1:
                shape = [s0, s1, s2, s3][:ndim]
                batch = _decode_batch(
                    {"dtype": dtype_b.rstrip(b"\x00").decode("ascii"),
                     "shape": shape},
                    payload,
                    copy=copy,
                )
                return batch, seq
        raise TransportError(
            f"topic {topic!r} ring lapped {_SHM_READ_RETRIES} reads in a row"
        )

    def fetch(self, topic: str, copy: bool = False) -> np.ndarray:
        """Latest batch; a **read-only view into the ring** unless
        ``copy=True``. A view stays bit-identical until the producer laps
        the ring (``nslots - 2`` further publishes with an in-flight
        writer; see :meth:`view_valid`)."""
        self.fetch_count += 1
        st = self._attach(topic)
        if st is None:
            raise TopicDropped(f"no data published on topic {topic!r}")
        return self._read_latest(st, topic, copy=copy)[0]

    def fetch_view(
        self, topic: str, min_seq: Optional[int] = None, timeout: float = 60.0
    ) -> Tuple[np.ndarray, int]:
        """Zero-copy fetch returning ``(view, seq)``.

        The sequence token feeds :meth:`view_valid`: a scheduler that
        consumed the view (e.g. fed it to a jitted step that may alias
        host buffers) revalidates after the fact and re-fetches with
        ``copy=True`` if the ring lapped mid-use. ``min_seq`` adds the
        :meth:`fetch_synced` producer wait before the read.
        """
        self.fetch_count += 1
        if min_seq is not None:
            st = self._await_seq(topic, min_seq, timeout)
        else:
            st = self._attach(topic)
            if st is None:
                raise TopicDropped(f"no data published on topic {topic!r}")
        return self._read_latest(st, topic, copy=False)

    def view_valid(self, topic: str, seq: int) -> bool:
        """Whether a view obtained at publish #``seq`` is still bit-valid
        (same strict one-lap bound as the seqlock read)."""
        st = self._attach(topic)
        if st is None or st.read_dropped():
            return False
        return st.read_seq() < seq + st.nslots - 1

    def _await_seq(self, topic: str, min_seq: int, timeout: float) -> _ShmTopic:
        """Spin (with backoff) until ``topic`` reaches ``min_seq``; returns
        the attached topic, ready for a seqlock read."""
        deadline = time.monotonic() + timeout
        delay = 0.0001
        seen = False
        while True:
            st = self._attach(topic)
            if st is not None:
                seen = True
                if st.read_dropped():
                    raise TopicDropped(f"topic {topic!r} dropped while awaited")
                if st.read_seq() >= min_seq:
                    return st
            elif seen:
                # the incarnation we were waiting on was dropped (file gone)
                raise TopicDropped(f"topic {topic!r} dropped while awaited")
            if time.monotonic() > deadline:  # pragma: no cover - defensive
                raise TransportTimeout(
                    f"topic {topic!r} never reached sequence {min_seq} within {timeout}s"
                )
            time.sleep(delay)
            delay = min(delay * 2, 0.002)

    def fetch_synced(
        self, topic: str, min_seq: int, timeout: float = 60.0, copy: bool = False
    ) -> np.ndarray:
        self.fetch_count += 1
        st = self._await_seq(topic, min_seq, timeout)
        return self._read_latest(st, topic, copy=copy)[0]

    def drop(self, topic: str) -> None:
        with self._flock() as lk:
            st = self._attach(topic)
            if st is None:
                return
            # fold the topic's cumulative totals into the graveyard, mark
            # dropped (wakes synced fetches in every attached process),
            # then unlink the incarnation
            _, _, seq, _, nslots, slot_bytes, tb = _HDR.unpack_from(st.mm, 0)
            meta = self._read_meta()
            meta["graveyard_bytes"] += int(tb)
            meta["graveyard_publishes"] += int(seq)
            self._write_meta(meta)
            _HDR.pack_into(st.mm, 0, _SHM_MAGIC, _SHM_VERSION, seq, 1,
                           nslots, slot_bytes, tb)
            try:
                os.remove(st.path)
            except FileNotFoundError:  # pragma: no cover - concurrent drop
                pass
            with self._lock:
                if self._attached.get(topic) is st:
                    # park rather than close: blocked fetch_synced readers
                    # still hold this mapping and must observe the dropped
                    # flag (KeyError), not a closed-mmap ValueError
                    self._retired.append(st)
                    del self._attached[topic]

    # -- observability ---------------------------------------------------------
    def _live_topics(self) -> List[str]:
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return []
        return [
            _filename_topic(n) for n in names
            if n.endswith(".topic") and ".tmp" not in n
        ]

    def seq(self, topic: str) -> int:
        st = self._attach(topic)
        return 0 if st is None or st.read_dropped() else st.read_seq()

    def sequences(self) -> Dict[str, int]:
        out = {}
        for topic in self._live_topics():
            s = self.seq(topic)
            if s > 0:
                out[topic] = s
        return out

    def has(self, topic: str) -> bool:
        return self.seq(topic) > 0

    def topics(self) -> Dict[str, Any]:
        out = {}
        for topic in self._live_topics():
            try:
                # private copies: checkpoint encoders may hold these past
                # further publishes (deferred background encode)
                out[topic] = self.fetch(topic, copy=True)
            except KeyError:
                continue
        return out

    def counters(self) -> Dict[str, int]:
        meta = self._read_meta()
        total_b = meta["graveyard_bytes"]
        total_p = meta["graveyard_publishes"]
        for topic in self._live_topics():
            st = self._attach(topic)
            if st is None:
                continue
            _, _, seq, _, _, _, tb = _HDR.unpack_from(st.mm, 0)
            total_b += int(tb)
            total_p += int(seq)
        return {
            "bytes_published": total_b - meta["base_bytes"],
            "publishes": total_p - meta["base_publishes"],
        }

    @property
    def bytes_published(self) -> int:
        return self.counters()["bytes_published"]

    @property
    def publishes(self) -> int:
        return self.counters()["publishes"]

    def reset_counters(self) -> None:
        self.restore_counters(0, 0)

    def restore_counters(self, bytes_published: int, publishes: int) -> None:
        with self._flock() as lk:
            meta = self._read_meta()
            meta["base_bytes"] = 0
            meta["base_publishes"] = 0
            self._write_meta(meta)
            current = self.counters()
            meta["base_bytes"] = current["bytes_published"] - int(bytes_published)
            meta["base_publishes"] = current["publishes"] - int(publishes)
            self._write_meta(meta)

    def __len__(self) -> int:
        return len(self.sequences())

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._lock:
            for st in self._attached.values():
                st.close()
            self._attached.clear()
            for st in self._retired:
                st.close()
            self._retired.clear()
        if self._owner:
            shutil.rmtree(self.dir, ignore_errors=True)

    def connect_info(self) -> Dict[str, Any]:
        return {
            "kind": "shm",
            "dir": self.dir,
            "slot_bytes": self.slot_bytes,
            "nslots": self.nslots,
        }

    def __del__(self):  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass


# -- tcp ------------------------------------------------------------------------

# Wire format (both directions): u32 header length | JSON header |
# u32 payload length | raw payload bytes. Batches travel as payload with
# dtype/shape in the header; everything else is header-only.
_U32 = struct.Struct("<I")


def _send_msg(sock: socket.socket, header: Dict[str, Any], payload: bytes = b"") -> None:
    hdr = json.dumps(header).encode("utf-8")
    sock.sendall(_U32.pack(len(hdr)) + hdr + _U32.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("transport peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_msg(sock: socket.socket) -> Tuple[Dict[str, Any], bytes]:
    hdr_len = _U32.unpack(_recv_exact(sock, 4))[0]
    header = json.loads(_recv_exact(sock, hdr_len).decode("utf-8"))
    payload_len = _U32.unpack(_recv_exact(sock, 4))[0]
    payload = _recv_exact(sock, payload_len) if payload_len else b""
    return header, payload


def _recv_msg_idle(sock: socket.socket) -> Optional[Tuple[Dict[str, Any], bytes]]:
    """Server-side :func:`_recv_msg` for sockets with a timeout set.

    Returns ``None`` on an *idle* timeout — no byte of a new message has
    arrived yet — so the handler loop can poll its shutdown flag instead of
    blocking in ``recv`` forever (the killed-client leak). A timeout once a
    message has started is a stalled/dead peer: framing sync is lost, so it
    raises :class:`ConnectionError` and the handler drops the connection.
    """
    try:
        first = sock.recv(1)
    except socket.timeout:
        return None
    if not first:
        raise ConnectionError("transport peer closed the connection")
    try:
        hdr_len = _U32.unpack(first + _recv_exact(sock, 3))[0]
        header = json.loads(_recv_exact(sock, hdr_len).decode("utf-8"))
        payload_len = _U32.unpack(_recv_exact(sock, 4))[0]
        payload = _recv_exact(sock, payload_len) if payload_len else b""
    except socket.timeout as e:
        raise ConnectionError("transport peer stalled mid-message") from e
    return header, payload


class TcpBrokerServer:
    """A broker reachable over TCP — one handler thread per connection,
    state in an inner :class:`~repro.runtime.broker.Broker` (so per-topic
    sequencing and drop-wake semantics are inherited verbatim).

    Shutdown hygiene: the listen socket is ``SO_REUSEADDR`` and every
    connection carries a ``conn_timeout`` idle poll, so a killed client
    cannot strand a handler thread in ``recv`` forever and a restarted
    server rebinds the same port immediately — ``close()`` also closes the
    tracked connections, which unblocks their handlers right away.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, conn_timeout: float = 5.0):
        self.broker = Broker()
        self.conn_timeout = conn_timeout
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.address: Tuple[str, int] = self._sock.getsockname()
        self._closed = False
        self._conns: Set[socket.socket] = set()
        self._conns_lock = threading.Lock()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="repro-tcp-broker", daemon=True
        )
        self._accept_thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            with self._conns_lock:
                if self._closed:
                    conn.close()
                    return
                self._conns.add(conn)
            # daemon handler threads reap themselves on disconnect — not
            # retained (a long-lived server would leak dead Thread objects)
            threading.Thread(
                target=self._serve, args=(conn,), daemon=True,
                name="repro-tcp-conn",
            ).start()

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        conn.settimeout(self.conn_timeout)
        try:
            while not self._closed:
                msg = _recv_msg_idle(conn)
                if msg is None:  # idle poll — re-check the shutdown flag
                    continue
                header, payload = msg
                try:
                    reply, out = self._handle(header, payload)
                except KeyError as e:
                    reply, out = {"key_error": str(e)}, b""
                except TimeoutError as e:  # pragma: no cover - defensive
                    reply, out = {"timeout_error": str(e)}, b""
                _send_msg(conn, reply, out)
        except (ConnectionError, OSError):
            pass
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            conn.close()

    def _handle(self, h: Dict[str, Any], payload: bytes) -> Tuple[Dict[str, Any], bytes]:
        op = h["op"]
        b = self.broker
        if op == "publish":
            b.publish(h["topic"], _decode_batch(h, payload))
            return {"ok": True}, b""
        if op in ("fetch", "fetch_synced"):
            batch = (
                b.fetch(h["topic"]) if op == "fetch"
                else b.fetch_synced(h["topic"], h["min_seq"], h.get("timeout", 60.0))
            )
            hdr, out = _encode_batch(batch)
            hdr["ok"] = True
            return hdr, out
        if op == "drop":
            b.drop(h["topic"])
            return {"ok": True}, b""
        if op == "seq":
            return {"value": b.seq(h["topic"])}, b""
        if op == "sequences":
            return {"value": b.sequences()}, b""
        if op == "has":
            return {"value": b.has(h["topic"])}, b""
        if op == "len":
            return {"value": len(b)}, b""
        if op == "topics":
            enc = {}
            for topic, batch in b.topics().items():
                hdr, out = _encode_batch(batch)
                hdr["data"] = base64.b64encode(out).decode("ascii")
                enc[topic] = hdr
            return {"value": enc}, b""
        if op == "counters":
            return {"value": {"bytes_published": b.bytes_published,
                              "publishes": b.publishes}}, b""
        if op == "reset_counters":
            b.reset_counters()
            return {"ok": True}, b""
        if op == "restore_counters":
            b.bytes_published = int(h["bytes_published"])
            b.publishes = int(h["publishes"])
            return {"ok": True}, b""
        if op == "ping":
            return {"ok": True}, b""
        raise ValueError(f"unknown transport op {op!r}")  # pragma: no cover

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # shutdown() before close(): close() alone doesn't wake a thread
        # blocked in accept() — the open file description (and the LISTEN
        # port) would survive until the next connection attempt.
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:  # pragma: no cover
            pass
        # Actively close live connections so handler threads unblock now,
        # not one idle-timeout later.
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.close()
            except OSError:  # pragma: no cover
                pass
        # The port is only certainly rebindable once the accept thread has
        # let go of the listening file description.
        if self._accept_thread is not threading.current_thread():
            self._accept_thread.join(timeout=2.0)


class TcpTransport(Transport):
    """Length-prefixed socket transport against a :class:`TcpBrokerServer`.

    Connections are per-thread (``threading.local``): a blocked
    ``fetch_synced`` occupies only its own connection, so concurrent
    scheduler threads (and worker processes) never serialize on one
    socket. Constructing without an ``address`` starts an in-process
    server and connects to it — the single-host convenience mode; pass
    the address of a remote server to span hosts.
    """

    name = "tcp"

    def __init__(self, address: Optional[Tuple[str, int]] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self._server: Optional[TcpBrokerServer] = None
        if address is None:
            self._server = TcpBrokerServer(host=host, port=port)
            address = self._server.address
        self.address = (str(address[0]), int(address[1]))
        self._local = threading.local()
        self._closed = False

    def _conn(self) -> socket.socket:
        sock = getattr(self._local, "sock", None)
        if sock is None:
            sock = socket.create_connection(self.address, timeout=120.0)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.sock = sock
        return sock

    def _call(self, header: Dict[str, Any], payload: bytes = b"",
              retry: bool = True) -> Tuple[Dict[str, Any], bytes]:
        sock = self._conn()
        try:
            _send_msg(sock, header, payload)
            reply, out = _recv_msg(sock)
        except (ConnectionError, OSError):
            self._local.sock = None
            if not retry:
                # non-idempotent ops (publish/drop/counter writes) must not
                # re-execute: the server may have applied the first attempt
                # before the connection died, and a double publish would
                # advance the topic sequence twice for one logical publish
                raise
            # one reconnect attempt (server restarts, idle timeouts)
            sock = self._conn()
            _send_msg(sock, header, payload)
            reply, out = _recv_msg(sock)
        if "key_error" in reply:
            raise TopicDropped(reply["key_error"])
        if "timeout_error" in reply:  # pragma: no cover - defensive
            raise TransportTimeout(reply["timeout_error"])
        return reply, out

    # -- data path -------------------------------------------------------------
    def publish(self, topic: str, batch: Any) -> None:
        header, payload = _encode_batch(batch)
        header.update(op="publish", topic=topic)
        self._call(header, payload, retry=False)

    def fetch(self, topic: str, copy: bool = False) -> np.ndarray:
        """Latest batch; a read-only ``frombuffer`` view over the receive
        buffer by default (the buffer is private to this call, so unlike
        shm views it can never go stale — ``copy=True`` only buys
        writability)."""
        self.fetch_count += 1
        reply, payload = self._call({"op": "fetch", "topic": topic})
        return _decode_batch(reply, payload, copy=copy)

    def fetch_synced(
        self, topic: str, min_seq: int, timeout: float = 60.0, copy: bool = False
    ) -> np.ndarray:
        self.fetch_count += 1
        reply, payload = self._call(
            {"op": "fetch_synced", "topic": topic, "min_seq": min_seq,
             "timeout": timeout}
        )
        return _decode_batch(reply, payload, copy=copy)

    def drop(self, topic: str) -> None:
        self._call({"op": "drop", "topic": topic}, retry=False)

    # -- observability ---------------------------------------------------------
    def seq(self, topic: str) -> int:
        return int(self._call({"op": "seq", "topic": topic})[0]["value"])

    def sequences(self) -> Dict[str, int]:
        return dict(self._call({"op": "sequences"})[0]["value"])

    def has(self, topic: str) -> bool:
        return bool(self._call({"op": "has", "topic": topic})[0]["value"])

    def topics(self) -> Dict[str, Any]:
        enc = self._call({"op": "topics"})[0]["value"]
        return {
            topic: _decode_batch(hdr, base64.b64decode(hdr["data"]))
            for topic, hdr in enc.items()
        }

    def counters(self) -> Dict[str, int]:
        return dict(self._call({"op": "counters"})[0]["value"])

    @property
    def bytes_published(self) -> int:
        return self.counters()["bytes_published"]

    @property
    def publishes(self) -> int:
        return self.counters()["publishes"]

    def reset_counters(self) -> None:
        self._call({"op": "reset_counters"}, retry=False)

    def restore_counters(self, bytes_published: int, publishes: int) -> None:
        self._call({"op": "restore_counters",
                    "bytes_published": int(bytes_published),
                    "publishes": int(publishes)}, retry=False)

    def __len__(self) -> int:
        return int(self._call({"op": "len"})[0]["value"])

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        sock = getattr(self._local, "sock", None)
        if sock is not None:
            try:
                sock.close()
            except OSError:  # pragma: no cover
                pass
        if self._server is not None:
            self._server.close()

    def connect_info(self) -> Dict[str, Any]:
        return {"kind": "tcp", "address": list(self.address)}


# -- registry -------------------------------------------------------------------

_TRANSPORTS: Dict[str, Type[Transport]] = {}


def register_transport(cls: Type[Transport]) -> Type[Transport]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"transport class {cls.__name__} has no name")
    if cls.name in _TRANSPORTS:
        raise ValueError(f"transport {cls.name!r} already registered")
    _TRANSPORTS[cls.name] = cls
    return cls


for _cls in (InProcTransport, ShmTransport, TcpTransport):
    register_transport(_cls)


def available_transports() -> List[str]:
    return sorted(_TRANSPORTS)


def resolve_transport(
    transport: Union[str, Transport, Type[Transport]], **kwargs: Any
) -> Transport:
    """Name / instance / class → transport instance (names hit the registry)."""
    if isinstance(transport, Transport):
        return transport
    if isinstance(transport, type) and issubclass(transport, Transport):
        return transport(**kwargs)
    if isinstance(transport, str):
        cls = _TRANSPORTS.get(transport)
        if cls is None:
            raise ValueError(
                f"unknown transport {transport!r} "
                f"(registered: {', '.join(available_transports())})"
            )
        return cls(**kwargs)
    raise TypeError(
        f"transport must be a name or Transport, got {type(transport).__name__}"
    )


def connect_transport(spec: Dict[str, Any]) -> Transport:
    """Rebuild a connected transport in another process from
    :meth:`Transport.connect_info` output."""
    kind = spec.get("kind")
    if kind == "shm":
        return ShmTransport(
            dir=spec["dir"], slot_bytes=spec.get("slot_bytes"),
            nslots=spec.get("nslots", _SHM_NSLOTS),
        )
    if kind == "tcp":
        return TcpTransport(address=tuple(spec["address"]))
    raise TransportError(f"cannot connect a transport from spec {spec!r}")
