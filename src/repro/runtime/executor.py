"""Executor — steps deployed segments, accounts resources, detects stragglers.

Segments step in launch order: merges only ever add segments *downstream* of
existing ones (boundary streams flow old → new; see DESIGN.md invariant), so
launch order is a valid topological order of the segment graph.

Resource accounting reproduces the paper's measurements:
  * *running task count* — live (non-paused) tasks across segments (Fig. 2);
  * *cores used* — Σ cost_weight·events for live tasks plus a pause overhead
    ε per paused task (paused Storm bolts still occupy their worker slot —
    the paper's observed drain-phase overhead), scaled by a calibration
    constant (Fig. 3);
  * broker bytes published (the indirection overhead defrag removes).

Straggler mitigation: per-segment step-time EWMA; a segment exceeding
``k × median`` is flagged and re-dispatched (on hardware: moved to a spare
host; here the policy and bookkeeping are exercised and unit-tested).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import Dataflow

from .broker import Broker, topic_for
from .segment import Segment, SegmentSpec, build_segment

# Fraction of a task's cost still consumed while paused (deployed-but-idle
# Storm bolt). Calibrated so the paper's drain-phase crossover reproduces.
PAUSE_EPSILON = 0.03
# events·cost_weight per core: 1 core ≡ one weight-1.0 task at 10 ev/s ×
# 32-event batches — matches the paper's constant 10 ev/s input rate setup.
CORE_CALIBRATION = 320.0


@dataclass
class StepReport:
    step: int
    live_tasks: int
    paused_tasks: int
    cost: float  # core-equivalents this step
    wall_ms: float
    segment_ms: Dict[str, float] = field(default_factory=dict)
    stragglers: List[str] = field(default_factory=list)


class Executor:
    def __init__(self, straggler_factor: float = 3.0, ewma_alpha: float = 0.3):
        self.broker = Broker()
        self.segments: Dict[str, Segment] = {}
        self.forwarding: Dict[str, Set[str]] = {}  # segment -> task ids forwarded
        self.paused: Set[str] = set()  # running task ids paused (global view)
        self.step_count = 0
        self._launch_seq = 0
        # straggler tracking
        self.straggler_factor = straggler_factor
        self.ewma_alpha = ewma_alpha
        self.ewma_ms: Dict[str, float] = {}
        self.redispatches: List[Tuple[int, str]] = []
        self.reports: List[StepReport] = []

    # -- deployment -----------------------------------------------------------
    def deploy(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]] = None,
    ) -> Segment:
        spec.created_at = self._launch_seq
        self._launch_seq += 1
        seg = build_segment(spec, dataflow, init_states=init_states)
        self.segments[spec.name] = seg
        self.forwarding[spec.name] = set(spec.publish)
        return seg

    def kill(self, segment_name: str) -> None:
        seg = self.segments.pop(segment_name)
        self.forwarding.pop(segment_name, None)
        for tid in seg.spec.task_ids:
            self.broker.drop(topic_for(tid))
            self.paused.discard(tid)

    # -- control signals (paper §4.3 control topic) -----------------------------
    def forward(self, task_id: str) -> None:
        """Ask the segment owning ``task_id`` to forward its output stream."""
        for name, seg in self.segments.items():
            if task_id in seg.spec.task_ids:
                self.forwarding[name].add(task_id)
                return
        raise KeyError(f"task {task_id!r} not deployed")

    def pause(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.pause(task_ids)
        self.paused |= {t for t in task_ids if self._owner(t) is not None}

    def resume(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.resume(task_ids)
        self.paused -= set(task_ids)

    def _owner(self, task_id: str) -> Optional[str]:
        for name, seg in self.segments.items():
            if task_id in seg.spec.task_ids:
                return name
        return None

    # -- stepping ----------------------------------------------------------------
    def step(self) -> StepReport:
        t0 = time.perf_counter()
        seg_ms: Dict[str, float] = {}
        ordered = sorted(self.segments.values(), key=lambda s: s.spec.created_at)
        for seg in ordered:
            s0 = time.perf_counter()
            inputs = {t: self.broker.fetch(t) for t in seg.boundary_topics}
            new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
            seg.states = new_states
            for tid in self.forwarding[seg.name]:
                if tid in outputs:
                    self.broker.publish(topic_for(tid), outputs[tid])
            seg.steps_run += 1
            seg_ms[seg.name] = (time.perf_counter() - s0) * 1e3

        live, paused_n, cost = self._account()
        stragglers = self._update_stragglers(seg_ms)
        self.step_count += 1
        report = StepReport(
            step=self.step_count,
            live_tasks=live,
            paused_tasks=paused_n,
            cost=cost,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            segment_ms=seg_ms,
            stragglers=stragglers,
        )
        self.reports.append(report)
        return report

    def run(self, steps: int) -> List[StepReport]:
        return [self.step() for _ in range(steps)]

    # -- accounting ----------------------------------------------------------------
    def _account(self) -> Tuple[int, int, float]:
        live = 0
        paused_n = 0
        cost = 0.0
        for seg in self.segments.values():
            for tid in seg.spec.task_ids:
                w = seg.operators[tid].cost_weight * seg.spec.batch_of[tid]
                if bool(seg.active[tid]):
                    live += 1
                    cost += w
                else:
                    paused_n += 1
                    cost += PAUSE_EPSILON * w
        return live, paused_n, cost / CORE_CALIBRATION

    @property
    def live_task_count(self) -> int:
        return sum(len(s.live_task_ids()) for s in self.segments.values())

    def sink_state(self, task_id: str) -> Any:
        owner = self._owner(task_id)
        if owner is None:
            raise KeyError(f"sink task {task_id!r} not deployed")
        return self.segments[owner].states[task_id]

    # -- straggler mitigation -----------------------------------------------------
    def _update_stragglers(self, seg_ms: Dict[str, float]) -> List[str]:
        flagged: List[str] = []
        for name, ms in seg_ms.items():
            prev = self.ewma_ms.get(name)
            self.ewma_ms[name] = ms if prev is None else (
                self.ewma_alpha * ms + (1 - self.ewma_alpha) * prev
            )
        # prune EWMAs of killed segments
        for name in list(self.ewma_ms):
            if name not in self.segments:
                del self.ewma_ms[name]
        if len(self.ewma_ms) >= 2:
            vals = sorted(self.ewma_ms.values())
            median = vals[len(vals) // 2]
            for name, ew in list(self.ewma_ms.items()):
                if median > 0 and ew > self.straggler_factor * median:
                    flagged.append(name)
                    self.redispatch(name)
        return flagged

    def redispatch(self, segment_name: str) -> None:
        """Re-dispatch a straggling segment (hardware: move to spare host).

        The compiled executable and task states are retained; the EWMA is
        reset so the relocated segment is judged afresh.
        """
        self.redispatches.append((self.step_count, segment_name))
        self.ewma_ms.pop(segment_name, None)

    # -- defragmentation (enactment; planning in repro.core.defrag) -----------------
    def defragment(
        self,
        dag_name: str,
        fused_spec: SegmentSpec,
        dataflow: Dataflow,
    ) -> Segment:
        """Replace all segments of ``dag_name`` by one fused segment.

        Task states carry over (state-preserving defrag — beyond the paper,
        which would relaunch cold). Paused tasks are dropped entirely,
        reclaiming their ε overhead.
        """
        carried: Dict[str, Any] = {}
        for name, seg in list(self.segments.items()):
            if seg.spec.dag_name != dag_name:
                continue
            for tid in fused_spec.task_ids:
                if tid in seg.spec.task_ids:
                    carried[tid] = seg.states[tid]
            self.kill(name)
        return self.deploy(fused_spec, dataflow, init_states=carried)
