"""InProcessJitBackend — the jit data plane behind the ExecutionBackend API.

Steps deployed segments in launch order: merges only ever add segments
*downstream* of existing ones (boundary streams flow old → new; see
DESIGN.md invariant), so launch order is a valid topological order of the
segment graph.

Resource accounting, straggler EWMAs, pause flags and the task→segment
reverse index (O(1) ``forward``/``_owner`` instead of the old linear scan
over segments) live in the shared :class:`repro.runtime.backend.ExecutionBackend`
base — this module adds only what is jit-specific: segment compilation,
broker transport, and real device buffers for task states.

``Executor`` remains as a backwards-compatible alias.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

from repro.core.graph import Dataflow

from .backend import (
    CORE_CALIBRATION,
    PAUSE_EPSILON,
    ExecutionBackend,
    SegmentSpec,
    StepReport,
)
from .broker import Broker, topic_for
from .segment import Segment, build_segment

__all__ = [
    "CORE_CALIBRATION",
    "Executor",
    "InProcessJitBackend",
    "PAUSE_EPSILON",
    "StepReport",
]


class InProcessJitBackend(ExecutionBackend):
    """Today's Executor: one jit-compiled step function per segment, broker
    topics between segments, device-resident task states."""

    name = "inprocess"

    def __init__(self, straggler_factor: float = 3.0, ewma_alpha: float = 0.3):
        super().__init__(straggler_factor=straggler_factor, ewma_alpha=ewma_alpha)
        self.broker = Broker()

    # -- ExecutionBackend hooks -------------------------------------------------
    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]],
    ) -> Segment:
        return build_segment(spec, dataflow, init_states=init_states)

    def _drop_streams(self, seg: Segment) -> None:
        for tid in seg.spec.task_ids:
            self.broker.drop(topic_for(tid))

    def _fetch_inputs(self, seg: Segment) -> Dict[str, Any]:
        """Boundary inputs for one segment (hook — sharded moves them on-device)."""
        return {t: self.broker.fetch(t) for t in seg.boundary_topics}

    def _step_segments(self) -> Dict[str, float]:
        seg_ms: Dict[str, float] = {}
        ordered = sorted(self.segments.values(), key=lambda s: s.spec.created_at)
        for seg in ordered:
            s0 = time.perf_counter()
            inputs = self._fetch_inputs(seg)
            new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
            seg.states = new_states
            for tid in self.forwarding[seg.name]:
                if tid in outputs:
                    self.broker.publish(topic_for(tid), outputs[tid])
            seg.steps_run += 1
            seg_ms[seg.name] = (time.perf_counter() - s0) * 1e3
        return seg_ms


# Backwards-compatible name: the pre-API-redesign data plane class.
Executor = InProcessJitBackend
