"""InProcessJitBackend — the jit data plane behind the ExecutionBackend API.

Steps deployed segments in launch order: merges only ever add segments
*downstream* of existing ones (boundary streams flow old → new; see
DESIGN.md invariant), so launch order is a valid topological order of the
segment graph.

Resource accounting, straggler EWMAs, pause flags and the task→segment
reverse index (O(1) ``forward``/``_owner`` instead of the old linear scan
over segments) live in the shared :class:`repro.runtime.backend.ExecutionBackend`
base — this module adds only what is jit-specific: segment compilation,
broker transport, and real device buffers for task states.

``Executor`` remains as a backwards-compatible alias.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.graph import Dataflow

from .backend import (
    CORE_CALIBRATION,
    PAUSE_EPSILON,
    ExecutionBackend,
    PyTree,
    SegmentSpec,
    StepReport,
)
from .broker import topic_for
from .checkpoint import decode_pytree
from .segment import Segment, build_segment
from .transport import Transport, resolve_transport

__all__ = [
    "CORE_CALIBRATION",
    "Executor",
    "InProcessJitBackend",
    "PAUSE_EPSILON",
    "StepReport",
]


class InProcessJitBackend(ExecutionBackend):
    """Today's Executor: one jit-compiled step function per segment, broker
    topics between segments, device-resident task states.

    Boundary streams ride a pluggable :class:`~repro.runtime.transport.Transport`
    (``transport=``): the default ``"inproc"`` is the zero-copy in-process
    broker; ``"shm"`` / ``"tcp"`` move the same topics through shared
    memory or sockets — the data plane's publish/fetch path is
    transport-agnostic. ``self.broker`` stays as an alias for the
    transport (pre-transport-API name)."""

    name = "inprocess"

    def __init__(
        self,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
        transport: Any = "inproc",
        transport_options: Optional[Dict[str, Any]] = None,
    ):
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            max_workers=max_workers,
        )
        self.transport: Transport = resolve_transport(
            transport, **(transport_options or {})
        )
        self.broker = self.transport  # backwards-compatible alias
        # Compiled-segment reuse: structurally identical segments share one
        # canonical jitted executable instead of recompiling (coordinator-
        # side — this backend compiles in-process).
        from .compile_cache import CompileCache

        self.compile_cache = CompileCache()
        self.compile_cache.tracer = self.tracer
        # Per-topic sequence targets for the concurrent step in flight
        # (None outside one): each forwarding task publishes exactly once
        # per step, so a boundary read of this step must observe sequence
        # start+1 on its producer's topic — and only on that topic.
        self._topic_target: Optional[Dict[str, int]] = None

    # -- ExecutionBackend hooks -------------------------------------------------
    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]],
    ) -> Segment:
        return build_segment(
            spec, dataflow, init_states=init_states, cache=self.compile_cache
        )

    def _drop_streams(self, seg: Segment) -> None:
        for tid in seg.spec.task_ids:
            self.broker.drop(topic_for(tid))

    def _fetch_inputs(self, seg: Segment, copy: bool = False) -> Dict[str, Any]:
        """Boundary inputs for one segment (hook — sharded moves them on-device).

        During a concurrent step each topic read synchronizes on *its*
        producer's publish of this step (per-topic sequencing) — the
        ready-queue already dispatched producers first, so the wait is a
        cheap verification, but it hard-guarantees deterministic inputs
        even for custom backends with looser dispatch.
        """
        targets = self._topic_target
        if targets is None:
            return {t: self.broker.fetch(t, copy=copy) for t in seg.boundary_topics}
        return {
            t: self.broker.fetch_synced(t, targets[t], copy=copy) if t in targets
            else self.broker.fetch(t, copy=copy)
            for t in seg.boundary_topics
        }

    def _gather_inputs(self, seg: Segment) -> Tuple[Dict[str, Any], Dict[str, int]]:
        """Boundary inputs plus revalidation tokens for the zero-copy path.

        On transports exposing :meth:`fetch_view` (shm), inputs are
        read-only views into the ring and ``tokens`` maps each topic to the
        sequence observed — ``_step_one`` revalidates them after computing
        and recomputes from private copies if the ring lapped mid-step.
        Fused segments skip the view path entirely: donation invalidates
        the pre-step states, so a recompute is impossible — they pay one
        private copy per boundary topic instead.
        """
        fused = bool(getattr(seg.spec, "fused", False))
        views = None if fused else getattr(self.transport, "fetch_view", None)
        if views is None:
            return self._fetch_inputs(seg, copy=fused), {}
        targets = self._topic_target or {}
        inputs: Dict[str, Any] = {}
        tokens: Dict[str, int] = {}
        for t in seg.boundary_topics:
            inputs[t], tokens[t] = views(t, min_seq=targets.get(t))
        return inputs, tokens

    def _begin_concurrent_step(self) -> None:
        # one sequences() snapshot instead of a seq() per topic — on the
        # tcp transport each seq() would be its own socket round-trip
        seqs = self.transport.sequences()
        self._topic_target = {
            topic_for(tid): seqs.get(topic_for(tid), 0) + 1
            for name, tids in self.forwarding.items()
            if name in self.segments
            for tid in tids
        }

    def _end_concurrent_step(self) -> None:
        self._topic_target = None

    def _step_one(self, seg: Segment) -> Optional[float]:
        if self.tracer.enabled:
            with self.tracer.span("fetch", "transport", segment=seg.name,
                                  topics=len(seg.boundary_topics)):
                inputs, tokens = self._gather_inputs(seg)
        else:
            inputs, tokens = self._gather_inputs(seg)
        new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
        if tokens:
            # Zero-copy stale-view check: the CPU jit may alias the host
            # views, so the computation must finish before we can trust it;
            # if any source slot lapped mid-step, recompute from private
            # copies and the untouched pre-step states. Publishes and the
            # state commit happen only after validation (exactly-once).
            jax.block_until_ready((new_states, outputs))
            if not all(self.transport.view_valid(t, s) for t, s in tokens.items()):
                for t in tokens:
                    inputs[t] = self.transport.fetch(t, copy=True)
                new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
        seg.states = new_states
        if self.tracer.enabled:
            with self.tracer.span("publish", "transport", segment=seg.name):
                for tid in self.forwarding[seg.name]:
                    if tid in outputs:
                        self.broker.publish(topic_for(tid), outputs[tid])
        else:
            for tid in self.forwarding[seg.name]:
                if tid in outputs:
                    self.broker.publish(topic_for(tid), outputs[tid])
        # Block on the segment's computation (the Storm worker finishes its
        # batch before acking). JAX dispatch is async — without this,
        # segment_ms measures dispatch (~µs), the straggler EWMAs are
        # noise, and the sync/concurrent distinction evaporates. Blocking
        # here is what lets concurrent dispatch genuinely overlap devices:
        # each worker thread waits on *its* device while the others run.
        jax.block_until_ready(new_states)
        seg.steps_run += 1
        return None  # report measured wall-time

    # -- durability hooks ---------------------------------------------------------
    def _decode_init_states(
        self, spec: SegmentSpec, dataflow: Dataflow, states_enc: Dict[str, Any]
    ) -> Dict[str, PyTree]:
        """Conform checkpointed states to this backend's operator templates.

        Same-backend restores round-trip bit-exactly (arrays decode to the
        original bytes). Cross-backend restores from the dry-run backend
        carry only sink counters and ``()`` placeholders; leaves that don't
        structurally match the operator's ``init_state`` template fall back
        to the template — so e.g. a dry-run sink state ``{count, checksum}``
        seeds the jit sink's ``count`` while ``last`` re-initializes to
        zeros, keeping sink *counts* exactly continuous (checksums are
        jit-only state and restart from the template in that direction).
        """
        from repro.ops import operator_for_task

        out: Dict[str, PyTree] = {}
        for tid, enc in states_enc.items():
            value = decode_pytree(enc)
            op = operator_for_task(dataflow.tasks[tid], batch=spec.batch_of[tid])
            out[tid] = _conform_state(value, op.init_state(spec.batch_of[tid]))
        return out

    def _dump_extra(self) -> Dict[str, Any]:
        """Transport topic buffers + publish counters.

        Strictly, buffers are reconstructible (launch order is topological,
        so every boundary topic is re-published upstream within the first
        post-restore step before its consumer fetches it) — but persisting
        them keeps a restored transport observable-identical, including for
        tooling that reads topics between steps.
        """
        counters = self.transport.counters()
        return {
            "broker": {
                topic: self._state_encoder(batch)
                for topic, batch in sorted(self.transport.topics().items())
            },
            "broker_bytes_published": int(counters["bytes_published"]),
            "broker_publishes": int(counters["publishes"]),
        }

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        for topic, enc in extra.get("broker", {}).items():
            self.transport.publish(topic, decode_pytree(enc))
        # publish() above bumped the counters; restore the checkpointed view
        self.transport.restore_counters(
            int(extra.get("broker_bytes_published", 0)),
            int(extra.get("broker_publishes", 0)),
        )

    def spawn_config(self) -> Dict[str, Any]:
        return {"transport": self.transport.name}


def _conform_state(value: Any, template: Any) -> Any:
    """Merge a decoded state pytree onto an operator's init-state template.

    Matching leaves adopt the checkpointed value (cast to the template's
    dtype); structural mismatches — missing dict keys, wrong tuple arity,
    wrong array shape, ``()`` placeholders from a dry-run checkpoint —
    resolve to the template, leaf by leaf."""
    if isinstance(template, dict):
        if not isinstance(value, dict):
            return template
        return {k: _conform_state(value.get(k, _MISSING), t) for k, t in template.items()}
    if isinstance(template, (tuple, list)):
        if not isinstance(value, (tuple, list)) or len(value) != len(template):
            return template
        return type(template)(_conform_state(v, t) for v, t in zip(value, template))
    if value is _MISSING or value is None:
        return template
    tmpl = np.asarray(template)
    try:
        arr = np.asarray(value)
    except Exception:
        return template
    if arr.shape != tmpl.shape:
        return template
    return arr.astype(tmpl.dtype)


_MISSING = object()


# Backwards-compatible name: the pre-API-redesign data plane class.
Executor = InProcessJitBackend
