"""Pub-sub broker — the Enterprise-Service-Bus analogue of paper §4.3.

Storm topologies are immutable once launched; the paper therefore deploys a
merged dataflow as *partial DAGs* (segments) glued by broker topics. Here a
topic is a named buffer holding the latest event batch published by an
upstream task's segment; downstream segments fetch it at the start of their
step. Duplicate semantics (fan-out) are free: multiple subscribers read the
same buffer (zero-copy on device).

The broker counts published bytes per topic — the indirection overhead the
paper observes (and that defragmentation removes) is thus measurable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp


def topic_for(task_id: str) -> str:
    """The derived-stream topic of a running task (paper: unique data topic)."""
    return f"stream/{task_id}"


class Broker:
    def __init__(self) -> None:
        self._topics: Dict[str, jnp.ndarray] = {}
        self.bytes_published: int = 0
        self.publishes: int = 0

    def publish(self, topic: str, batch: jnp.ndarray) -> None:
        self._topics[topic] = batch
        self.bytes_published += batch.size * batch.dtype.itemsize
        self.publishes += 1

    def fetch(self, topic: str) -> jnp.ndarray:
        if topic not in self._topics:
            raise KeyError(f"no data published on topic {topic!r}")
        return self._topics[topic]

    def has(self, topic: str) -> bool:
        return topic in self._topics

    def topics(self) -> Dict[str, jnp.ndarray]:
        """Snapshot view of the live topic buffers (checkpointing)."""
        return dict(self._topics)

    def drop(self, topic: str) -> None:
        self._topics.pop(topic, None)

    def reset_counters(self) -> None:
        self.bytes_published = 0
        self.publishes = 0

    def __len__(self) -> int:
        return len(self._topics)
