"""Pub-sub broker — the Enterprise-Service-Bus analogue of paper §4.3.

Storm topologies are immutable once launched; the paper therefore deploys a
merged dataflow as *partial DAGs* (segments) glued by broker topics. Here a
topic is a named buffer holding the latest event batch published by an
upstream task's segment; downstream segments fetch it at the start of their
step. Duplicate semantics (fan-out) are free: multiple subscribers read the
same buffer (zero-copy on device).

Topic granularity: every topic carries its own lock, **sequence number**
(count of publishes since creation) and condition variable, so boundary
reads synchronize only on their producers — never on a broker-wide
barrier. This is what lets the concurrent stepping pipeline dispatch
independent segments from different threads:

  * ``publish``/``fetch`` are thread-safe per topic;
  * ``fetch_synced(topic, min_seq)`` blocks until that topic's sequence
    reaches ``min_seq`` — the per-topic ordering guarantee the wave
    scheduler relies on for deterministic sink counts (each forwarding
    task publishes exactly once per step, so "producer stepped" ≡
    "sequence advanced by one");
  * ``drop`` is safe under in-flight dispatch: a dropped topic wakes any
    blocked ``fetch_synced`` with a ``KeyError`` instead of deadlocking.

The broker counts published bytes per topic — the indirection overhead the
paper observes (and that defragmentation removes) is thus measurable.
"""
from __future__ import annotations

import threading
from typing import Any, Dict

import numpy as np


class TransportError(RuntimeError):
    """A transport cannot carry the requested payload or span the caller."""


class TopicDropped(TransportError, KeyError):
    """The topic carries no data: never published, or dropped mid-wait.

    Subclasses ``KeyError`` so pre-taxonomy handlers (``except KeyError``)
    keep working across every transport, while supervisor hang-detection
    can classify any transport stall with one ``except TransportError``.
    """

    def __str__(self) -> str:
        # KeyError.__str__ repr-quotes the message; keep it readable.
        return RuntimeError.__str__(self)


class TransportTimeout(TransportError, TimeoutError):
    """A bounded wait (``fetch_synced``) expired before its condition."""


def topic_for(task_id: str) -> str:
    """The derived-stream topic of a running task (paper: unique data topic)."""
    return f"stream/{task_id}"


class _Topic:
    """Per-topic state: latest buffer, publish sequence, waiter wake-up."""

    __slots__ = ("cond", "buffer", "seq", "dropped")

    def __init__(self) -> None:
        self.cond = threading.Condition()
        self.buffer: Any = None
        self.seq = 0  # publishes on this topic since creation
        self.dropped = False


class Broker:
    def __init__(self) -> None:
        self._topics: Dict[str, _Topic] = {}
        # Guards the topic registry and the byte/publish counters; never
        # held while waiting — waits happen on the per-topic condition.
        self._lock = threading.Lock()
        self.bytes_published: int = 0
        self.publishes: int = 0
        # fetch-side twin of the publish counters; observability-only
        # (never persisted — see Transport.fetch_count)
        self.fetch_count: int = 0

    def _state(self, topic: str, create: bool = False) -> _Topic | None:
        with self._lock:
            st = self._topics.get(topic)
            if st is None and create:
                st = self._topics[topic] = _Topic()
            return st

    def publish(self, topic: str, batch: Any) -> None:
        st = self._state(topic, create=True)
        with st.cond:
            st.buffer = batch
            st.dropped = False
            st.seq += 1
            st.cond.notify_all()
        with self._lock:
            self.bytes_published += batch.size * batch.dtype.itemsize
            self.publishes += 1

    def fetch(self, topic: str, copy: bool = False) -> Any:
        self.fetch_count += 1
        st = self._state(topic)
        if st is None:
            raise TopicDropped(f"no data published on topic {topic!r}")
        with st.cond:
            if st.buffer is None:
                raise TopicDropped(f"no data published on topic {topic!r}")
            return self._maybe_copy(st.buffer, copy)

    @staticmethod
    def _maybe_copy(buffer: Any, copy: bool) -> Any:
        """In-process topics pass buffers by reference (zero-copy fan-out);
        ``copy=True`` is the uniform escape hatch for callers that mutate."""
        if not copy:
            return buffer
        return np.array(buffer, copy=True)

    def fetch_synced(
        self, topic: str, min_seq: int, timeout: float = 60.0, copy: bool = False
    ) -> Any:
        """Fetch once the topic's sequence reaches ``min_seq``.

        The per-producer synchronization point of concurrent stepping: the
        consumer waits for *its* producer's publish of this step, not for a
        global barrier. Dropping the topic while a fetch is in flight wakes
        the waiter with a ``KeyError`` (kill/unmerge stay safe mid-step);
        the timeout guards against scheduler bugs turning into hangs.
        """
        self.fetch_count += 1
        st = self._state(topic, create=True)
        with st.cond:
            ok = st.cond.wait_for(lambda: st.dropped or st.seq >= min_seq, timeout)
            if st.dropped or st.buffer is None:
                raise TopicDropped(f"topic {topic!r} dropped while awaited")
            if not ok:  # pragma: no cover - defensive
                raise TransportTimeout(
                    f"topic {topic!r} never reached sequence {min_seq} "
                    f"(at {st.seq}) within {timeout}s"
                )
            return self._maybe_copy(st.buffer, copy)

    def seq(self, topic: str) -> int:
        """Publish count of ``topic`` (0 if it never existed)."""
        st = self._state(topic)
        return 0 if st is None else st.seq

    def sequences(self) -> Dict[str, int]:
        """Snapshot of every live topic's sequence number (observability)."""
        with self._lock:
            items = list(self._topics.items())
        return {t: st.seq for t, st in items if st.buffer is not None}

    def has(self, topic: str) -> bool:
        st = self._state(topic)
        return st is not None and st.buffer is not None

    def topics(self) -> Dict[str, Any]:
        """Snapshot view of the live topic buffers (checkpointing)."""
        with self._lock:
            items = list(self._topics.items())
        return {t: st.buffer for t, st in items if st.buffer is not None}

    def drop(self, topic: str) -> None:
        with self._lock:
            st = self._topics.pop(topic, None)
        if st is not None:
            with st.cond:
                st.dropped = True
                st.buffer = None
                st.cond.notify_all()

    def reset_counters(self) -> None:
        with self._lock:
            self.bytes_published = 0
            self.publishes = 0

    def __len__(self) -> int:
        with self._lock:
            return sum(1 for st in self._topics.values() if st.buffer is not None)
