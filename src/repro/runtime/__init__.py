"""Storm-analogue data plane behind the pluggable ExecutionBackend API:
broker, jit-compiled segments, the in-process / sharded / dry-run backends,
worker placement model, and the StreamSystem that binds the ReuseManager
control plane to any backend.

Imports resolve lazily (PEP 562) so that control-plane and dry-run users —
``StreamSystem(backend="dryrun")`` — never pay the JAX import; the jit
modules load on first attribute access.
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

# JAX-free eagerly-imported surface.
from .checkpoint import (
    CHECKPOINT_FORMAT_VERSION,
    CheckpointError,
    CheckpointStore,
    decode_pytree,
    encode_pytree,
    is_checkpoint_path,
)
from .backend import (
    CORE_CALIBRATION,
    PAUSE_EPSILON,
    BackendSnapshot,
    ExecutionBackend,
    SegmentSpec,
    StepReport,
    available_backends,
    compute_batches,
    register_backend,
    resolve_backend,
)
from .scheduler import (
    TASKS_PER_WORKER,
    WORKERS_PER_NODE,
    PlacedBackendMixin,
    Placement,
    PlacementPolicy,
    StragglerPolicy,
    WaveEvent,
    available_placements,
    compute_waves,
    place_round_robin,
    register_placement,
    resolve_placement,
    run_ready_queue,
)
from .transport import (
    InProcTransport,
    ShmTransport,
    TcpBrokerServer,
    TcpTransport,
    Transport,
    TransportError,
    available_transports,
    connect_transport,
    register_transport,
    resolve_transport,
)

# name -> (module, attribute); resolved on first access to keep JAX lazy.
_LAZY = {
    "Broker": ("repro.runtime.broker", "Broker"),
    "topic_for": ("repro.runtime.broker", "topic_for"),
    "DryRunBackend": ("repro.runtime.dryrun", "DryRunBackend"),
    "Executor": ("repro.runtime.executor", "Executor"),
    "InProcessJitBackend": ("repro.runtime.executor", "InProcessJitBackend"),
    "MultiprocBackend": ("repro.runtime.worker", "MultiprocBackend"),
    "RemoteSegment": ("repro.runtime.worker", "RemoteSegment"),
    "Segment": ("repro.runtime.segment", "Segment"),
    "WorkerError": ("repro.runtime.worker", "WorkerError"),
    "build_segment": ("repro.runtime.segment", "build_segment"),
    "ShardedBackend": ("repro.runtime.sharded", "ShardedBackend"),
    "StreamSystem": ("repro.runtime.system", "StreamSystem"),
}

if TYPE_CHECKING:  # pragma: no cover - static imports for type checkers
    from .broker import Broker, topic_for
    from .dryrun import DryRunBackend
    from .executor import Executor, InProcessJitBackend
    from .segment import Segment, build_segment
    from .sharded import ShardedBackend
    from .system import StreamSystem
    from .worker import MultiprocBackend, RemoteSegment, WorkerError

__all__ = [
    "BackendSnapshot",
    "Broker",
    "CHECKPOINT_FORMAT_VERSION",
    "CORE_CALIBRATION",
    "CheckpointError",
    "CheckpointStore",
    "DryRunBackend",
    "ExecutionBackend",
    "Executor",
    "InProcTransport",
    "InProcessJitBackend",
    "MultiprocBackend",
    "PAUSE_EPSILON",
    "PlacedBackendMixin",
    "Placement",
    "PlacementPolicy",
    "RemoteSegment",
    "Segment",
    "SegmentSpec",
    "ShardedBackend",
    "ShmTransport",
    "StepReport",
    "StragglerPolicy",
    "StreamSystem",
    "TASKS_PER_WORKER",
    "TcpBrokerServer",
    "TcpTransport",
    "Transport",
    "TransportError",
    "WORKERS_PER_NODE",
    "WaveEvent",
    "WorkerError",
    "available_backends",
    "available_placements",
    "available_transports",
    "build_segment",
    "compute_batches",
    "compute_waves",
    "connect_transport",
    "decode_pytree",
    "encode_pytree",
    "is_checkpoint_path",
    "place_round_robin",
    "register_backend",
    "register_placement",
    "register_transport",
    "resolve_backend",
    "resolve_placement",
    "resolve_transport",
    "run_ready_queue",
    "topic_for",
]


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value
