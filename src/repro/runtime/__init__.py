"""Storm-analogue data plane: broker, jit-compiled segments, executor with
resource accounting + straggler mitigation, worker placement model, and the
StreamSystem that binds the ReuseManager control plane to the data plane."""
from .broker import Broker, topic_for
from .executor import CORE_CALIBRATION, PAUSE_EPSILON, Executor, StepReport
from .scheduler import (
    TASKS_PER_WORKER,
    WORKERS_PER_NODE,
    Placement,
    StragglerPolicy,
    place_round_robin,
)
from .segment import Segment, SegmentSpec, build_segment, compute_batches
from .system import StreamSystem

__all__ = [
    "Broker",
    "CORE_CALIBRATION",
    "Executor",
    "PAUSE_EPSILON",
    "Placement",
    "Segment",
    "SegmentSpec",
    "StepReport",
    "StragglerPolicy",
    "StreamSystem",
    "TASKS_PER_WORKER",
    "WORKERS_PER_NODE",
    "build_segment",
    "compute_batches",
    "place_round_robin",
    "topic_for",
]
