"""Compiled-segment reuse cache — collaborative reuse extended from streams
and tasks down to XLA executables.

The paper shares *streams* between overlapping dataflows; PR 2's backends
share *tasks* within one running DAG. This module closes the last gap:
two segments that are **structurally identical** — same task types, same
canonical configs, same batch sizes, same internal wiring, same fused
flag — lower to byte-identical XLA programs, so compiling both is pure
waste. That situation is the common case under churn: a removed dataflow
resubmitted later, dozens of users submitting the same template, or a
Default-strategy run where every submission deploys its own copy.

Mechanism:

  * :func:`structural_signature` — canonicalize a :class:`SegmentSpec`
    (task ids → ``t0, t1, …`` in spec order, external boundary parents →
    ``x0, x1, …`` in first-appearance order) and hash types/configs/
    batches/wiring with the same length-prefixed SHA-256 the merge
    algorithm uses (:mod:`repro.core.signatures`). Task *names* and topic
    *strings* are erased; everything the compiled program depends on is
    kept. Boundary array shapes are **not** part of the key — JAX keys
    its own trace cache by argument shapes under one callable, so a
    shared callable handles differing boundary shapes correctly (each
    new shape pays its own trace, subsequent segments with that shape hit).
  * :class:`CompileCache` — an LRU of **canonical** jitted step functions.
    On miss, the segment builder compiles a canonicalized twin of the
    spec and caches *that*; hit or miss, the real segment steps through a
    :class:`_RenamedStepFn` adapter that maps its task ids / topics onto
    the canonical names per call. The first trace therefore always lands
    on the shared canonical callable — a later structurally identical
    segment reuses the traced executable and skips XLA entirely.

Placement of the cache mirrors where compilation happens: the in-process
jit and sharded backends hold one cache in the coordinator
(``backend.compile_cache``); the multiproc backend's workers each hold a
process-local cache (:func:`process_compile_cache`) surfaced through the
``cache_stats`` worker RPC. Hit/miss/evict counters flow up to
``session.stats()``.

This module is import-safe without JAX (the coordinator of the multiproc
backend is JAX-free); :func:`~repro.runtime.segment.build_segment` is
imported lazily at first miss.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from repro.core.graph import Dataflow, Task
from repro.core.signatures import _digest

from .backend import SegmentSpec
from .broker import topic_for

__all__ = [
    "CompileCache",
    "process_compile_cache",
    "structural_signature",
]


def _canonical_maps(spec: SegmentSpec) -> Tuple[Dict[str, str], Dict[str, str]]:
    """Task-id and external-parent renamings erasing all naming history.

    Task ids map in ``spec.task_ids`` order; external (boundary) parents
    map in first-appearance order over the per-task parent lists — the
    same order :func:`build_segment` derives its boundary topics in, so
    the canonical segment's boundary wiring is isomorphic to the real one.
    """
    tid_map = {t: f"t{i}" for i, t in enumerate(spec.task_ids)}
    ext: List[str] = []
    for t in spec.task_ids:
        for p in spec.parents[t]:
            if p not in tid_map and p not in ext:
                ext.append(p)
    ext_map = {p: f"x{i}" for i, p in enumerate(ext)}
    return tid_map, ext_map


def structural_signature(spec: SegmentSpec, dataflow: Dataflow) -> str:
    """Structural identity of a segment's compiled program.

    Two specs with equal signatures compile to the same XLA program:
    the key covers the fused flag and, per task in order, ⟨type,
    canonical config, batch, canonically renamed parent refs⟩. Parent
    refs keep their per-task *list order* (concatenation order is
    semantics); ``publish`` is excluded (the step returns every task's
    output regardless — forwarding is a runtime choice).
    """
    tid_map, ext_map = _canonical_maps(spec)
    parts: List[bytes] = [b"fused" if spec.fused else b"unfused"]
    for t in spec.task_ids:
        task = dataflow.tasks[t]
        refs = ",".join(
            tid_map[p] if p in tid_map else ext_map[p] for p in spec.parents[t]
        )
        parts.extend(
            (
                task.type.encode(),
                task.config.encode(),
                str(int(spec.batch_of[t])).encode(),
                refs.encode(),
            )
        )
    return _digest(parts)


def _canonicalize(
    spec: SegmentSpec, dataflow: Dataflow
) -> Tuple[SegmentSpec, Dataflow, Dict[str, str], Dict[str, str]]:
    """The canonical twin of ⟨spec, dataflow⟩ plus the renaming maps."""
    tid_map, ext_map = _canonical_maps(spec)
    ref = {**tid_map, **ext_map}
    canon_spec = SegmentSpec(
        name="canonical",
        dag_name="canonical",
        task_ids=[tid_map[t] for t in spec.task_ids],
        parents={
            tid_map[t]: [ref[p] for p in spec.parents[t]] for t in spec.task_ids
        },
        publish={tid_map[t] for t in spec.publish if t in tid_map},
        batch_of={tid_map[t]: int(spec.batch_of[t]) for t in spec.task_ids},
        created_at=0,
        fused=spec.fused,
    )
    canon_df = Dataflow("canonical")
    for t in spec.task_ids:
        task = dataflow.tasks[t]
        # direct construction: config is already a canonical string and must
        # round-trip byte-exactly into the canonical task definition
        canon_df.add_task(Task(id=tid_map[t], type=task.type, config=task.config))
    return canon_spec, canon_df, tid_map, ext_map


class _RenamedStepFn:
    """Per-segment adapter over a shared canonical jitted step function.

    Renames the segment's dict keys (task ids, boundary topic strings)
    onto the canonical names on the way in and back on the way out. Key
    order is irrelevant — JAX flattens dict pytrees in sorted-key order —
    so renaming preserves the traced argument structure exactly, and a
    donated canonical call (fused specs) donates the caller's own arrays.
    Exposes ``lower`` so :func:`~repro.runtime.segment.donation_report`
    keeps working on cached segments.
    """

    def __init__(self, fn: Any, tid_map: Dict[str, str], topic_map: Dict[str, str]):
        self._fn = fn
        self._tid = dict(tid_map)
        self._topic = dict(topic_map)  # real boundary topic -> canonical topic
        self._tid_rev = {v: k for k, v in tid_map.items()}

    def _rename_in(self, states, active, inputs):
        return (
            {self._tid[k]: v for k, v in states.items()},
            {self._tid[k]: v for k, v in active.items()},
            {self._topic[k]: v for k, v in inputs.items()},
        )

    def __call__(self, states, active, inputs):
        new_states, outputs = self._fn(*self._rename_in(states, active, inputs))
        return (
            {self._tid_rev[k]: v for k, v in new_states.items()},
            {self._tid_rev[k]: v for k, v in outputs.items()},
        )

    def lower(self, states, active, inputs):
        return self._fn.lower(*self._rename_in(states, active, inputs))


class CompileCache:
    """LRU cache of canonical jitted segment step functions.

    ``capacity`` bounds the number of distinct structures held; eviction
    is least-recently-used (the evicted executable stays alive only while
    segments still reference it). Counters are cumulative for the cache's
    lifetime — ``stats()`` is the surface ``session.stats()`` aggregates.
    """

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: "OrderedDict[str, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional repro.obs.Tracer set by the owning backend/worker; a miss
        # (canonical build → trace + jit) is the expensive event worth a span
        self.tracer: Optional[Any] = None

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {
            "hits": int(self.hits),
            "misses": int(self.misses),
            "evictions": int(self.evictions),
            "entries": len(self._entries),
        }

    def step_fn_for(self, spec: SegmentSpec, dataflow: Dataflow) -> _RenamedStepFn:
        """The (shared, canonical) step function for a spec, adapter-wrapped.

        On miss the canonical twin is built uncached — its jitted callable
        is the cached artifact. Even the missing segment steps through the
        adapter, so the first trace happens on the shared callable and
        every later structurally identical segment reuses it.
        """
        key = structural_signature(spec, dataflow)
        fn = self._entries.get(key)
        if fn is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            canon_spec, _, tid_map, ext_map = _canonicalize(spec, dataflow)
        else:
            self.misses += 1
            from .segment import build_segment  # lazy: imports JAX

            canon_spec, canon_df, tid_map, ext_map = _canonicalize(spec, dataflow)
            tracer = self.tracer
            if tracer is not None and tracer.enabled:
                with tracer.span("compile_miss", "compile",
                                 signature=key[:12], tasks=len(spec.task_ids),
                                 fused=bool(spec.fused)):
                    fn = build_segment(canon_spec, canon_df).step_fn
            else:
                fn = build_segment(canon_spec, canon_df).step_fn
            self._entries[key] = fn
            if len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        topic_map = {topic_for(p): topic_for(c) for p, c in ext_map.items()}
        return _RenamedStepFn(fn, tid_map, topic_map)


# One cache per worker process (the multiproc data plane compiles inside
# its workers; the coordinator stays JAX-free and aggregates over RPC).
_PROCESS_CACHE: Optional[CompileCache] = None


def process_compile_cache() -> CompileCache:
    global _PROCESS_CACHE
    if _PROCESS_CACHE is None:
        _PROCESS_CACHE = CompileCache()
    return _PROCESS_CACHE
