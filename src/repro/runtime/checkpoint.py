"""Durable data-plane checkpoints — versioned, atomic, torn-write tolerant.

The ReuseManager journal already makes the *control plane* durable: replay
reconstructs 𝔻/𝔻̄/Δ/Φ byte-identically. This module adds the missing data
plane half. A checkpoint is one JSON file holding

  * the control-plane operation journal (so restore can replay it), and
  * the backend's :meth:`~repro.runtime.backend.ExecutionBackend.dump_state`
    payload — deployed segment specs, task ⟨type, config⟩ definitions,
    per-task state pytrees, forwarding/pause flags, broker buffers and
    straggler EWMAs —

wrapped in an integrity envelope (format version, monotonic checkpoint id,
sha256 of the canonical payload). Crash consistency comes from three
mechanics:

  * **atomic write** — serialize to ``<file>.tmp`` in the same directory,
    fsync, then :func:`os.replace` onto the final name, so a checkpoint is
    either fully present or absent;
  * **monotonic ids** — files are named ``ckpt-<id>.json`` with ids that
    only grow (corrupt files still advance the counter, so a re-written
    checkpoint never reuses a torn file's id);
  * **torn-last tolerance** — :meth:`CheckpointStore.latest` walks ids
    newest-first and returns the first envelope that parses, carries a
    supported format version and matches its sha256, so a crash mid-write
    falls back to the previous durable checkpoint instead of failing.

The module is deliberately JAX-free (numpy only) so that a
``backend="dryrun"`` session can checkpoint and restore without ever
importing JAX. Array leaves in task-state pytrees are encoded as
base64-packed bytes with dtype/shape, which round-trips jit states
bit-exactly and costs nothing for the dry-run backend's scalar states.
"""
from __future__ import annotations

import base64
import hashlib
import json
import os
import queue
import re
import threading
import time
from contextlib import nullcontext
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# Format history (see README "Crash recovery" for the compatibility table):
#   1 — initial format: envelope {checkpoint_format, checkpoint_id,
#       created_at, sha256, payload}; payload {backend, strategy, journal,
#       base_batch, seg_counter, task_batch, segments_of, checkpoint_every,
#       data:{step_count, launch_seq, paused, ewma_ms, redispatches,
#       segments:[...], extra:{...}}}.
CHECKPOINT_FORMAT_VERSION = 1
SUPPORTED_FORMATS = {1}

_CKPT_RE = re.compile(r"^ckpt-(\d{8})\.json$")


class CheckpointError(ValueError):
    """A checkpoint file is missing, torn, or of an unsupported format."""


class UnsupportedFormatError(CheckpointError):
    """A structurally intact checkpoint written in a format this binary
    does not speak (version skew). Restore skips it like any other
    CheckpointError, but retention must never reap it — a newer/older
    binary sharing the directory can still restore from it."""


# -- pytree codec ---------------------------------------------------------------


def encode_pytree(x: Any) -> Any:
    """JSON-safe encoding of a task-state pytree.

    Scalars pass through; dict/tuple/list nodes are tagged so decode can
    rebuild the exact container types; array-likes (numpy or jax — anything
    with dtype/shape/tobytes) become base64 bytes + dtype + shape, which is
    bit-exact and needs no JAX import on either side.
    """
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        return {"__kind__": "dict", "items": {k: encode_pytree(v) for k, v in x.items()}}
    if isinstance(x, tuple):
        return {"__kind__": "tuple", "items": [encode_pytree(v) for v in x]}
    if isinstance(x, list):
        return {"__kind__": "list", "items": [encode_pytree(v) for v in x]}
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        # device → host for jax arrays; order="C" (not ascontiguousarray,
        # which promotes 0-d scalars to shape (1,)) for stable tobytes()
        arr = np.asarray(x, order="C")
        return {
            "__kind__": "ndarray",
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": base64.b64encode(arr.tobytes()).decode("ascii"),
        }
    raise TypeError(f"cannot checkpoint state leaf of type {type(x).__name__}")


def decode_pytree(x: Any) -> Any:
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        kind = x.get("__kind__")
        if kind == "dict":
            return {k: decode_pytree(v) for k, v in x["items"].items()}
        if kind == "tuple":
            return tuple(decode_pytree(v) for v in x["items"])
        if kind == "list":
            return [decode_pytree(v) for v in x["items"]]
        if kind == "ndarray":
            arr = np.frombuffer(
                base64.b64decode(x["data"]), dtype=np.dtype(x["dtype"])
            ).reshape(x["shape"])
            return arr.copy()  # frombuffer views are read-only
        raise CheckpointError(f"unknown pytree node kind {kind!r}")
    raise CheckpointError(f"cannot decode state node of type {type(x).__name__}")


class DeferredState:
    """A state pytree captured but not yet encoded.

    The background checkpointer snapshots on the stepping thread by
    wrapping each segment's state values in this marker — a reference
    capture, safe because backends replace state pytrees wholesale every
    step and never mutate arrays in place — and the writer thread later
    materializes them with :func:`encode_deferred`.
    """

    __slots__ = ("value",)

    def __init__(self, value: Any):
        self.value = value


def deferred_encoder(value: Any) -> DeferredState:
    """State encoder for snapshot-only dumps (see ``dump_state``)."""
    return DeferredState(value)


def encode_deferred(obj: Any) -> Any:
    """Materialize every :class:`DeferredState` marker in a payload —
    the writer-thread half of background checkpointing."""
    if isinstance(obj, DeferredState):
        return encode_pytree(obj.value)
    if isinstance(obj, dict):
        return {k: encode_deferred(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [encode_deferred(v) for v in obj]
    if isinstance(obj, tuple):
        return tuple(encode_deferred(v) for v in obj)
    return obj


def _canonical_json(payload: Dict[str, Any]) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def payload_digest(payload: Dict[str, Any]) -> str:
    return hashlib.sha256(_canonical_json(payload).encode("utf-8")).hexdigest()


# -- the on-disk store ----------------------------------------------------------


class CheckpointStore:
    """A directory of versioned checkpoints with atomic, monotonic writes.

    ``keep_last=N`` turns on retention: after every :meth:`save` the store
    prunes down to the newest N *valid* checkpoints (the newest valid one
    is never pruned — N must be ≥ 1) and reaps torn/corrupt files, which
    can never be restored anyway. Intact checkpoints in an *unsupported
    format* (version skew) are never reaped — see :meth:`prune`. Without
    ``keep_last`` the store only ever appends (long-lived sessions should
    set it).
    """

    def __init__(self, root: str, keep_last: Optional[int] = None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(
                f"keep_last must be >= 1 (the newest valid checkpoint "
                f"is never pruned), got {keep_last}"
            )
        self.root = str(root)
        self.keep_last = keep_last
        # ids whose files this instance already validated end-to-end —
        # checkpoint files are immutable once renamed into place, so prune
        # never has to re-read them (retention stays O(1) per save).
        self._validated_ids: set = set()
        # Telemetry plane (repro.obs), wired by the owning StreamSystem.
        # Instrumentation lives in the store — not the system — so the
        # background writer thread's saves are traced/counted identically
        # to synchronous ones.
        self.tracer: Optional[Any] = None
        self.metrics: Optional[Any] = None

    def _span(self, name: str, **args: Any):
        tracer = self.tracer
        if tracer is not None and tracer.enabled:
            return tracer.span(name, "checkpoint", **args)
        return nullcontext()

    # -- naming ---------------------------------------------------------------
    @staticmethod
    def filename(checkpoint_id: int) -> str:
        return f"ckpt-{checkpoint_id:08d}.json"

    def path_of(self, checkpoint_id: int) -> str:
        return os.path.join(self.root, self.filename(checkpoint_id))

    def list_ids(self) -> List[int]:
        """All checkpoint ids present on disk (valid or torn), ascending."""
        if not os.path.isdir(self.root):
            return []
        ids = []
        for name in os.listdir(self.root):
            m = _CKPT_RE.match(name)
            if m:
                ids.append(int(m.group(1)))
        return sorted(ids)

    # -- write ----------------------------------------------------------------
    def save(self, payload: Dict[str, Any]) -> str:
        """Write the next checkpoint atomically; returns its path.

        The id is one past the highest id on disk — torn files included, so
        a checkpoint that failed mid-write is never overwritten in place.
        """
        t0 = time.perf_counter()
        os.makedirs(self.root, exist_ok=True)
        ids = self.list_ids()
        checkpoint_id = (ids[-1] + 1) if ids else 1
        # Serialize the payload exactly once: the canonical string is both
        # the digest input and the bytes written (load() re-canonicalizes
        # the parsed payload, which reproduces this string — sorted keys).
        with self._span("ckpt_encode", checkpoint_id=checkpoint_id):
            payload_json = _canonical_json(payload)
        header = json.dumps(
            {
                "checkpoint_format": CHECKPOINT_FORMAT_VERSION,
                "checkpoint_id": checkpoint_id,
                "created_at": time.time(),
                "sha256": hashlib.sha256(payload_json.encode("utf-8")).hexdigest(),
            }
        )
        final = self.path_of(checkpoint_id)
        tmp = final + ".tmp"
        with self._span(
            "ckpt_fsync", checkpoint_id=checkpoint_id, bytes=len(payload_json)
        ):
            with open(tmp, "w") as f:
                f.write(header[:-1] + ', "payload": ' + payload_json + "}")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
        try:  # best-effort directory fsync so the rename itself is durable
            dirfd = os.open(self.root, os.O_RDONLY)
            try:
                os.fsync(dirfd)
            finally:
                os.close(dirfd)
        except OSError:  # pragma: no cover - platform-dependent
            pass
        self._validated_ids.add(checkpoint_id)  # valid by construction
        metrics = self.metrics
        if metrics is not None:
            metrics.counter(
                "repro_checkpoints_total", "durable checkpoints written"
            ).inc()
            metrics.histogram(
                "repro_checkpoint_save_ms",
                "end-to-end checkpoint save time: encode + fsync + rename (ms)",
            ).observe((time.perf_counter() - t0) * 1e3)
        if self.keep_last is not None:
            self.prune()
        return final

    # -- retention ------------------------------------------------------------
    def prune(self, keep_last: Optional[int] = None) -> List[str]:
        """Apply the retention policy; returns the paths removed.

        Torn/corrupt files are always reaped (they can never be restored,
        and their ids were already consumed — a later save never reuses
        them while they exist). Unsupported-*format* files are left alone:
        they are intact checkpoints from a different software version, and
        a binary that speaks that format can still restore them. Valid
        checkpoints keep the newest ``keep_last`` (defaults to the store's
        policy; ``None`` with no store policy reaps torn files only). The
        newest valid checkpoint is never pruned.

        Checkpoint files are immutable once renamed into place, so each
        file is fully validated at most once per store instance — steady
        state is one validation per prune (the newly saved checkpoint),
        not a re-read of the whole directory.
        """
        keep = keep_last if keep_last is not None else self.keep_last
        if keep is not None and keep < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep}")
        valid: List[int] = []
        removed: List[str] = []
        for checkpoint_id in self.list_ids():
            if checkpoint_id in self._validated_ids:
                valid.append(checkpoint_id)
                continue
            try:
                self.load(checkpoint_id)
            except UnsupportedFormatError:
                continue  # version skew: not ours to restore, not ours to reap
            except CheckpointError:
                path = self.path_of(checkpoint_id)
                try:
                    os.remove(path)
                    removed.append(path)
                except OSError:  # pragma: no cover - concurrent reaper
                    pass
            else:
                self._validated_ids.add(checkpoint_id)
                valid.append(checkpoint_id)
        if keep is not None:
            for checkpoint_id in valid[:-keep]:
                path = self.path_of(checkpoint_id)
                try:
                    os.remove(path)
                except OSError:  # pragma: no cover - concurrent reaper
                    pass
                else:
                    removed.append(path)
                    self._validated_ids.discard(checkpoint_id)
        return removed

    # -- read -----------------------------------------------------------------
    def load(self, path_or_id: Any) -> Dict[str, Any]:
        """Load + validate one checkpoint envelope (raises CheckpointError)."""
        path = self.path_of(path_or_id) if isinstance(path_or_id, int) else str(path_or_id)
        try:
            with open(path) as f:
                envelope = json.load(f)
        except FileNotFoundError:
            raise CheckpointError(f"checkpoint {path!r} does not exist")
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointError(f"checkpoint {path!r} is torn or not JSON: {e}")
        if not isinstance(envelope, dict) or "payload" not in envelope:
            raise CheckpointError(f"checkpoint {path!r} has no payload envelope")
        fmt = envelope.get("checkpoint_format")
        if fmt not in SUPPORTED_FORMATS:
            raise UnsupportedFormatError(
                f"checkpoint {path!r} has unsupported format {fmt!r} "
                f"(supported: {sorted(SUPPORTED_FORMATS)})"
            )
        digest = payload_digest(envelope["payload"])
        if digest != envelope.get("sha256"):
            raise CheckpointError(f"checkpoint {path!r} failed its sha256 integrity check")
        return envelope

    def latest(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        """Newest *valid* checkpoint as ``(id, envelope)``.

        Walks ids newest-first, skipping torn/corrupt/unsupported files —
        the crash-consistency contract: a crash mid-``save`` loses at most
        the checkpoint being written.
        """
        for checkpoint_id in reversed(self.list_ids()):
            try:
                return checkpoint_id, self.load(checkpoint_id)
            except CheckpointError:
                continue
        return None

    def latest_payload(self) -> Dict[str, Any]:
        found = self.latest()
        if found is None:
            raise CheckpointError(f"no valid checkpoint under {self.root!r}")
        return found[1]["payload"]


class BackgroundCheckpointWriter:
    """Single writer thread turning snapshot payloads into durable files.

    With ``checkpoint_every=1`` on the synchronous path every step pays
    the full encode + fsync + rename; this writer moves that off the
    stepping thread — the stepping side only captures references
    (:func:`deferred_encoder`), the writer encodes and saves in
    submission order through the same :meth:`CheckpointStore.save`, so
    atomicity / monotonic-id / torn-write semantics are unchanged. A
    crash loses at most the checkpoints still queued — exactly the
    window a slower synchronous cadence would never have written at all.

    Writer-thread failures surface on the next :meth:`submit` /
    :meth:`flush` (the stepping thread never blocks on them mid-step).
    """

    def __init__(self, store: CheckpointStore):
        self.store = store
        self._queue: "queue.Queue[Optional[Dict[str, Any]]]" = queue.Queue()
        self._errors: List[BaseException] = []
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="repro-ckpt-writer", daemon=True
            )
            self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                self.store.save(encode_deferred(item))
            except BaseException as e:  # noqa: BLE001 - reported on flush
                with self._lock:
                    self._errors.append(e)
            finally:
                self._queue.task_done()

    def _raise_pending(self) -> None:
        with self._lock:
            if self._errors:
                err = self._errors[:]
                self._errors.clear()
                raise CheckpointError(
                    f"background checkpoint write failed: {err[0]!r}"
                ) from err[0]

    def submit(self, payload: Dict[str, Any]) -> None:
        """Queue one snapshot payload for durable write (non-blocking)."""
        if self._closed:
            raise CheckpointError("checkpoint writer is closed")
        self._raise_pending()
        self._ensure_thread()
        self._queue.put(payload)

    def flush(self) -> None:
        """Block until every queued checkpoint is durably on disk."""
        self._queue.join()
        self._raise_pending()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._thread is not None and self._thread.is_alive():
            self._queue.join()
            self._queue.put(None)
            self._thread.join(timeout=30)
        self._raise_pending()


def is_checkpoint_path(path: str) -> bool:
    """True if ``path`` names a checkpoint directory or a single checkpoint
    file — used by ``ReuseSession.restore`` to dispatch between full-system
    restore and the legacy control-plane journal restore."""
    if os.path.isdir(path):
        return True
    if _CKPT_RE.match(os.path.basename(path)):
        return True
    if os.path.isfile(path):
        try:
            with open(path) as f:
                head = f.read(512).lstrip()
            return head.startswith("{") and '"checkpoint_format"' in head
        except OSError:
            return False
    return False
