"""StreamSystem — the Reusable Dataflow Manager bound to a pluggable data plane.

Glues the control plane (:class:`repro.core.ReuseManager`) to any
:class:`repro.runtime.backend.ExecutionBackend` exactly as the paper's §4.3
Manager binds to Storm:

  * ``submit`` — run the merge algorithm; launch one new segment holding the
    created tasks ``T_x``; signal reused boundary tasks (``S_x⁺`` upstream
    ends) to *forward* their derived streams to broker topics.
  * ``remove`` — run the unmerge algorithm; *pause* terminated tasks via the
    control flags (Reuse) or kill the submission's segments outright (the
    Default baseline, which owns its topologies).
  * ``defragment`` — enact :func:`repro.core.defrag.plan_defrag`: relaunch
    one fused segment per running DAG, carrying task states over, dropping
    paused tasks and broker hops.

``strategy="none"`` is the paper's Default: no reuse, one segment per
submission, kill on removal. ``backend`` picks the data plane from the
registry (``"inprocess"`` jit, ``"sharded"`` multi-device, ``"dryrun"``
pure cost model) or accepts an :class:`ExecutionBackend` instance; the
policy layer here is backend-agnostic and JAX-free.

Durability: with ``checkpoint_dir=`` (and optionally ``checkpoint_every=N``
steps) the system writes versioned on-disk checkpoints — control-plane
journal + the backend's full ``dump_state`` — and
:meth:`StreamSystem.restore` rebuilds the whole system from the newest
valid one: replay the journal, redeploy every segment (on the checkpointed
backend or a different one), re-pause, and resume stepping with
trajectories identical to an uninterrupted run.
"""
from __future__ import annotations

import os
from contextlib import nullcontext
from typing import Any, Dict, List, Optional, Sequence, Set, Union

from repro.core import MergeStrategy, ReuseManager
from repro.core.defrag import (
    FusionPlan,
    FusionReport,
    canonical_parents,
    plan_defrag,
    plan_fusion,
    score_fusion_plan,
)
from repro.core.graph import Dataflow
from repro.core.manager import RemovalReceipt, SubmissionReceipt

from .backend import (
    ExecutionBackend,
    SegmentSpec,
    StepReport,
    compute_batches,
    resolve_backend,
)
from repro.obs import render_prometheus, write_chrome_trace

from .checkpoint import BackgroundCheckpointWriter, CheckpointStore, deferred_encoder
from .scheduler import Placement, place_round_robin


class StreamSystem:
    def __init__(
        self,
        strategy: Union[str, MergeStrategy] = "signature",
        base_batch: int = 32,
        check_invariants: bool = False,
        journal_path: Optional[str] = None,
        backend: Union[str, ExecutionBackend] = "inprocess",
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        checkpoint_keep_last: Optional[int] = None,
        checkpoint_background: bool = False,
        step_mode: Optional[str] = None,
        max_workers: Optional[int] = None,
        on_wave: Optional[Any] = None,
        report_history: Optional[int] = None,
        transport: Optional[Any] = None,
        workers: Optional[int] = None,
        backend_options: Optional[Dict[str, Any]] = None,
        supervise: Union[bool, Dict[str, Any]] = False,
        autoscale: Optional[Union[bool, Dict[str, Any]]] = None,
        on_worker_event: Optional[Any] = None,
    ):
        self.manager = ReuseManager(
            strategy=strategy, check_invariants=check_invariants, journal_path=journal_path
        )
        # Backend construction knobs: `transport=` picks the stream
        # transport ("inproc"/"shm"/"tcp"), `workers=` sizes the multiproc
        # worker pool; anything else rides in backend_options. They apply
        # when the backend is named (or a class) — a pre-built instance
        # already made those choices.
        options: Dict[str, Any] = dict(backend_options or {})
        if transport is not None:
            options["transport"] = transport
        if workers is not None:
            options["workers"] = workers
        if options and isinstance(backend, ExecutionBackend):
            raise ValueError(
                "transport=/workers=/backend_options= need a backend name or "
                "class — a backend instance is already constructed"
            )
        self.backend = resolve_backend(backend, **options)
        self.backend.configure_stepping(
            step_mode=step_mode,
            max_workers=max_workers,
            on_wave=on_wave,
            report_history=report_history,
        )
        self.base_batch = base_batch
        self.task_batch: Dict[str, int] = {}  # running task id -> output batch size
        self._seg_counter = 0
        self._segments_of: Dict[str, List[str]] = {}  # submission -> segment names
        # Last fusion planner verdicts (every accept/reject with reasons) —
        # refreshed by each fuse() call.
        self.fusion_report: Optional[FusionReport] = None
        self.checkpoint_keep_last = checkpoint_keep_last
        self.checkpoint_store = (
            CheckpointStore(checkpoint_dir, keep_last=checkpoint_keep_last)
            if checkpoint_dir
            else None
        )
        self.checkpoint_every = checkpoint_every
        # Background checkpointing: the auto-cadence snapshots on the
        # stepping thread (reference capture) and encodes/fsyncs/renames on
        # a writer thread, so checkpoint_every=1 no longer pauses stepping.
        self.checkpoint_background = bool(checkpoint_background)
        self._ckpt_writer: Optional[BackgroundCheckpointWriter] = None
        if checkpoint_every and not checkpoint_dir:
            raise ValueError("checkpoint_every needs a checkpoint_dir")
        if checkpoint_keep_last and not checkpoint_dir:
            raise ValueError("checkpoint_keep_last needs a checkpoint_dir")
        if checkpoint_background and not checkpoint_dir:
            raise ValueError("checkpoint_background needs a checkpoint_dir")
        # Cluster plane (multiproc only): `supervise=` arms self-healing —
        # a heartbeat thread plus in-step recovery respawn dead/hung
        # workers and redeploy their segments from shadow snapshots;
        # `autoscale=` resizes the worker pool on the EWMA pressure signal
        # after every step. Both accept True or a dict of knobs.
        self._supervisor = None
        self._autoscaler = None
        if on_worker_event is not None:
            self.backend.on_worker_event = on_worker_event
        if supervise:
            from repro.cluster import WorkerSupervisor

            sup_kwargs = supervise if isinstance(supervise, dict) else {}
            self._supervisor = WorkerSupervisor(self.backend, **sup_kwargs).start()
        if autoscale:
            from repro.cluster import Autoscaler

            scale_kwargs = autoscale if isinstance(autoscale, dict) else {}
            self._autoscaler = Autoscaler(self.backend, **scale_kwargs)
        # Telemetry plane (repro.obs): the backend owns the registry and
        # tracer; the system wires the control plane and durability layer
        # into them and contributes a snapshot-time collector mirroring
        # transport / compile-cache / reuse-savings state — scrape-time
        # work only, never on the stepping hot path.
        self.manager.tracer = self.backend.tracer
        if self.checkpoint_store is not None:
            self._wire_checkpoint_store(self.checkpoint_store)
        self._obs_registry: Optional[Any] = None
        self._wire_collectors()

    @property
    def executor(self) -> ExecutionBackend:
        """Backwards-compatible alias for the data plane (pre-API-redesign name)."""
        return self.backend

    @property
    def strategy(self) -> str:
        return self.manager.strategy

    @property
    def reuses(self) -> bool:
        return self.manager._strategy.reuses

    def _mint_segment(self) -> str:
        self._seg_counter += 1
        return f"seg{self._seg_counter}"

    def _span(self, name: str, **args: Any):
        """A "control"-category span on the backend's tracer (no-op when
        tracing is off — span admission is checked here so disabled runs
        don't even build the context manager)."""
        tracer = self.backend.tracer
        if tracer.enabled:
            return tracer.span(name, "control", **args)
        return nullcontext()

    # -- operations ---------------------------------------------------------------
    def submit(self, df: Dataflow) -> SubmissionReceipt:
        receipt = self.manager.submit(df)
        self._deploy(receipt)
        return receipt

    def submit_many(self, dfs: Sequence[Dataflow]) -> List[SubmissionReceipt]:
        """Batch submit: one batch-aware control-plane pass, then one segment
        per member's created tasks, deployed in batch order (so boundary
        streams between batch members flow older segment → newer, keeping the
        backend's launch-order invariant)."""
        receipts = self.manager.submit_many(dfs)
        for receipt in receipts:
            self._deploy(receipt)
        return receipts

    def _deploy(self, receipt: SubmissionReceipt) -> None:
        run_df = self.manager.running[receipt.running_dag]
        created: Set[str] = set(receipt.plan.created.values())
        if not created:  # fully contained in running DAGs — nothing to launch
            self._segments_of[receipt.name] = []
            # sinks must still be forwarded? no — reused sinks already consume.
            return

        canon = canonical_parents(run_df)
        order = [tid for tid in run_df.topological_order() if tid in created]
        parents = {tid: canon[tid] for tid in order}
        self.task_batch = compute_batches(order, parents, self.task_batch, self.base_batch)

        # Control signal: reused upstream ends of boundary streams forward
        # their derived stream to the broker (paper's control topic).
        for up_id, _down in receipt.plan.new_streams_boundary:
            self.backend.forward(up_id)

        spec = SegmentSpec(
            name=self._mint_segment(),
            dag_name=receipt.running_dag,
            task_ids=order,
            parents=parents,
            publish=set(),
            batch_of={t: self.task_batch[t] for t in order},
        )
        self.backend.deploy(spec, run_df)
        self._segments_of[receipt.name] = [spec.name]

    def remove(self, name: str) -> RemovalReceipt:
        own_segments = self._segments_of.pop(name, [])
        receipt = self.manager.remove(name)
        if not self.reuses:
            # Default: the submission owns its topologies — kill them.
            for seg_name in own_segments:
                if seg_name in self.backend.segments:
                    self.backend.kill(seg_name)
        else:
            # Reuse: Storm can't kill a subset of a topology — pause instead.
            self.backend.pause(set(receipt.terminated_tasks))
        # Terminated running-task ids are never re-minted, so their batch
        # entries are dead either way (paused tasks keep the batch copied
        # into their SegmentSpec). Without this, churn grows the dict
        # without bound.
        for tid in receipt.terminated_tasks:
            self.task_batch.pop(tid, None)
        return receipt

    def defragment(self) -> int:
        """Relaunch one fused segment per running DAG; returns segments killed."""
        with self._span("defrag", segments=len(self.backend.segments)):
            return self._defragment_impl()

    def _defragment_impl(self) -> int:
        plan = plan_defrag(self.manager.running)
        killed = len(self.backend.segments)
        # Carry live task states across the relaunch (beyond-paper:
        # state-preserving defrag — Storm would restart cold).
        carried: Dict[str, Any] = {}
        live: Set[str] = set()
        for fused in plan.fused:
            live |= set(fused.order)
        for seg in list(self.backend.segments.values()):
            for tid in seg.spec.task_ids:
                if tid in live:
                    carried[tid] = seg.states[tid]
        for seg_name in list(self.backend.segments):
            self.backend.kill(seg_name)
        for fused in plan.fused:
            run_df = self.manager.running[fused.dag_name]
            spec = SegmentSpec(
                name=self._mint_segment(),
                dag_name=fused.dag_name,
                task_ids=fused.order,
                parents=fused.parents,
                publish=set(),
                batch_of={t: self.task_batch[t] for t in fused.order},
            )
            self.backend.deploy(
                spec, run_df, init_states={t: carried[t] for t in fused.order if t in carried}
            )
        # Dropped paused tasks are no longer deployed anywhere — their batch
        # entries go with them (the churn-leak fix, see tests).
        self.task_batch = {t: b for t, b in self.task_batch.items() if t in live}
        # Segment ownership bookkeeping: after defrag, segments are shared —
        # submissions no longer own segments (only meaningful for Default,
        # which never defragments).
        for sub in self._segments_of:
            self._segments_of[sub] = []
        return killed

    def _score_fusion(self, plan: FusionPlan, overhead_ms: float) -> FusionReport:
        """Score a fusion plan with the dry-run latency model.

        Per-segment step costs come from :class:`repro.ops.costs
        .LatencyModel` fit on the backend's live latency samples (EWMA-fed
        segment wall-times), so the planner's "cheapest slot" is the
        EWMA-cheapest worker. Before any sample exists every segment
        models as 0 ms — consolidation is then free and all private-pipe
        chains are accepted, matching the pre-planner behaviour.
        """
        from repro.ops.costs import cost_weight_for_task, fit_latency_model

        backend = self.backend
        samples = backend.latency_samples()
        model = fit_latency_model(samples) if samples else None
        seg_ms: Dict[str, float] = {}
        for name, seg in backend.segments.items():
            if model is None:
                seg_ms[name] = 0.0
                continue
            units: Dict[str, float] = {}
            for tid in seg.spec.task_ids:
                task = backend.task_defs[tid]
                units[task.type] = units.get(task.type, 0.0) + (
                    cost_weight_for_task(task) * seg.spec.batch_of[tid]
                )
            seg_ms[name] = model.segment_ms(units)
        return score_fusion_plan(
            plan,
            backend.seg_deps,
            seg_ms,
            slot_of=getattr(backend, "device_of", None),
            n_slots=backend._n_slots() if hasattr(backend, "_n_slots") else 1,
            overhead_ms=overhead_ms,
        )

    def _migrate_chain(self, members: List[str], target: int) -> None:
        """Consolidate a chain's members onto one slot before fusing.

        Cross-worker chains must be worker-local before recompilation (the
        fused segment lives on exactly one slot); reuse the straggler-
        migration machinery — states RPC, kill, redeploy with carried
        states and re-applied pauses. Backends without placement (the
        in-process jit backend) have nothing to do.
        """
        device_of = getattr(self.backend, "device_of", None)
        if device_of is None:
            return
        for m in members:
            cur = device_of.get(m)
            if cur is None or cur == target:
                continue
            self.backend._move_segment(self.backend.segments[m], cur, target)
            device_of[m] = target

    def fuse(self, min_length: int = 2, overhead_ms: float = 0.25) -> Dict[str, List[str]]:
        """Fuse linear same-DAG segment chains into single compiled segments.

        Enacts :func:`repro.core.defrag.plan_fusion`: each maximal chain of
        segments joined by private (no fan-in/fan-out) boundary streams is
        replaced by ONE segment whose whole task chain compiles to a single
        jitted step with XLA buffer donation — the chain's intermediate
        streams become executable temporaries instead of broker topics.
        Unlike :meth:`defragment` this is member-scoped (parallel waves stay
        untouched) and keeps paused residue deployed (and paused).

        Candidate chains are scored wave-aware first
        (:func:`repro.core.defrag.score_fusion_plan`): a chain whose
        consolidation onto its cheapest slot would stretch the step
        makespan by more than the ``(len−1) × overhead_ms`` dispatch
        saving is rejected — wide waves stay wide. Every verdict lands in
        :attr:`fusion_report`. Accepted cross-worker chains are migrated
        member-by-member to the target slot before recompiling, and the
        fused segment is pinned there.

        Returns ``{fused segment name: [member names replaced]}``.
        """
        with self._span("fuse", segments=len(self.backend.segments)):
            return self._fuse_impl(min_length, overhead_ms)

    def _fuse_impl(self, min_length: int, overhead_ms: float) -> Dict[str, List[str]]:
        dag_of = {n: s.spec.dag_name for n, s in self.backend.segments.items()}
        plan = plan_fusion(self.backend.seg_deps, dag_of, min_length=min_length)
        self.fusion_report = self._score_fusion(plan, overhead_ms=overhead_ms)
        fused: Dict[str, List[str]] = {}
        for decision in self.fusion_report.decisions:
            if not decision.accepted:
                continue
            chain = decision.chain
            members = chain.members
            if any(m not in self.backend.segments for m in members):
                # stale plan entry (member killed since planning) — a chain
                # must never fuse over a dead segment
                continue
            self._migrate_chain(members, decision.target_slot)
            specs = [self.backend.segments[m].spec for m in members]
            # Chain order is upstream→downstream and member task_ids are
            # topological, so concatenation is topological for the union.
            combined: List[str] = []
            parents: Dict[str, List[str]] = {}
            batch_of: Dict[str, int] = {}
            for s in specs:
                combined.extend(s.task_ids)
                parents.update({t: list(s.parents[t]) for t in s.task_ids})
                batch_of.update(s.batch_of)
            # Keep every member's *current* forwarding set: intra-chain
            # consumers go in-segment, but a forwarded topic may also feed
            # external segments (fan-out at the task level) or observers.
            publish: Set[str] = set()
            for m in members:
                publish |= self.backend.forwarding.get(m, set())
            # Synthetic task-definition container (as in checkpoint
            # restore): fused chains may hold paused tasks that the
            # manager's running DAG no longer lists.
            df = Dataflow(chain.dag_name)
            for tid in combined:
                df.add_task(self.backend.task_defs[tid])
            spec = SegmentSpec(
                name=self._mint_segment(),
                dag_name=chain.dag_name,
                task_ids=combined,
                parents=parents,
                publish=publish,
                batch_of=batch_of,
                # Donation hazard: the background checkpointer's deferred
                # encode holds references to step-k states that a donated
                # step k+1 would invalidate — fall back to plain fusion.
                fused=not self.checkpoint_background,
            )
            # Deploy the fused segment where its members were consolidated —
            # placed backends consult the pin before their placement policy.
            pins = getattr(self.backend, "_pin_slot", None)
            if pins is not None:
                pins[spec.name] = decision.target_slot
            self.backend.fuse_segments(spec, df, members)
            # Reuse-savings attribution, recorded where the decision lands:
            # every accepted chain dispatches one segment where it used to
            # dispatch len(members).
            self.backend.metrics.counter(
                "repro_fusion_segments_saved_total",
                "segment dispatches eliminated per step by accepted chain "
                "fusion (chain length − 1 per fused chain)",
            ).inc(len(members) - 1)
            members_set = set(members)
            for sub, segs in self._segments_of.items():
                if any(s in members_set for s in segs):
                    merged: List[str] = []
                    for s in segs:
                        repl = spec.name if s in members_set else s
                        if repl not in merged:
                            merged.append(repl)
                    self._segments_of[sub] = merged
            fused[spec.name] = list(members)
        return fused

    # -- execution -----------------------------------------------------------------
    def step(self) -> StepReport:
        report = self.backend.step()
        mgr = self.manager
        saved = mgr.submitted_task_count - mgr.running_task_count
        if saved > 0 and report.live_tasks:
            # Reuse-savings attribution in the paper's Fig. 3 cost units:
            # each step, reuse avoided running `saved` tasks that Default
            # would have stepped — modelled at this step's per-live-task
            # cost. Accumulated here (where the step happens), mirrored out
            # by the /metrics scrape.
            self.backend.metrics.counter(
                "repro_reuse_core_steps_avoided_total",
                "modelled core-equivalent step cost avoided by reuse, "
                "accumulated per step (per-live-task cost × tasks saved)",
            ).inc(report.cost / report.live_tasks * saved)
        if self._autoscaler is not None:
            self._autoscaler.observe(report)
        if (
            self.checkpoint_every
            and self.checkpoint_store is not None
            and self.backend.step_count % self.checkpoint_every == 0
        ):
            if self.checkpoint_background:
                self._checkpoint_async()
            else:
                self.checkpoint()
        return report

    def run(self, steps: int) -> List[StepReport]:
        # Route through step() so the auto-checkpoint cadence applies.
        return [self.step() for _ in range(steps)]

    # -- durability (full-system checkpoint/restore) --------------------------------
    def checkpoint_payload(self, state_encoder: Optional[Any] = None) -> Dict[str, Any]:
        """The full durable state: control-plane journal + data-plane dump.

        Deterministic for a given system state (no wall-clock stamps — the
        envelope written by :class:`CheckpointStore` carries those), which
        is what makes ``payload → restore → payload`` a fixed point.
        ``state_encoder`` is forwarded to the backend dump — the background
        checkpointer passes the deferring marker encoder."""
        return {
            "backend": self.backend.name or type(self.backend).__name__,
            # constructor kwargs reproducing the data-plane topology
            # (transport kind, worker count, placement) for re-spawn on
            # restore; applied when restoring onto the same backend name
            "backend_config": self.backend.spawn_config(),
            "strategy": self.manager.strategy,
            "journal": list(self.manager.journal),
            "base_batch": int(self.base_batch),
            "seg_counter": int(self._seg_counter),
            "task_batch": {t: int(b) for t, b in self.task_batch.items()},
            "segments_of": {n: list(segs) for n, segs in self._segments_of.items()},
            "checkpoint_every": self.checkpoint_every,
            "checkpoint_keep_last": self.checkpoint_keep_last,
            "checkpoint_background": self.checkpoint_background,
            # Stepping-pipeline config rides along so a restore lands in the
            # same mode by default; the segment dependency DAG itself is
            # derived state and is rebuilt by redeploy, never persisted.
            "step_mode": self.backend.step_mode,
            "max_workers": self.backend.max_workers,
            "data": self.backend.dump_state(state_encoder),
        }

    def _checkpoint_async(self) -> None:
        """Queue a snapshot for the writer thread (auto-cadence path)."""
        if self._ckpt_writer is None:
            self._ckpt_writer = BackgroundCheckpointWriter(self.checkpoint_store)
        self._ckpt_writer.submit(self.checkpoint_payload(deferred_encoder))

    def flush_checkpoints(self) -> None:
        """Block until queued background checkpoints are durably on disk."""
        if self._ckpt_writer is not None:
            self._ckpt_writer.flush()

    def checkpoint(self, checkpoint_dir: Optional[str] = None) -> str:
        """Write one durable checkpoint synchronously; returns its path.

        Queued background checkpoints are flushed first so ids on disk
        stay chronological."""
        store = (
            CheckpointStore(checkpoint_dir, keep_last=self.checkpoint_keep_last)
            if checkpoint_dir
            else self.checkpoint_store
        )
        if store is None:
            raise ValueError(
                "no checkpoint_dir configured — pass one to checkpoint() or the constructor"
            )
        self._wire_checkpoint_store(store)
        self.flush_checkpoints()
        return store.save(self.checkpoint_payload())

    @classmethod
    def from_payload(
        cls,
        payload: Dict[str, Any],
        backend: Optional[Union[str, ExecutionBackend]] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        checkpoint_keep_last: Optional[int] = None,
        checkpoint_background: Optional[bool] = None,
        step_mode: Optional[str] = None,
        max_workers: Optional[int] = None,
        on_wave: Optional[Any] = None,
        journal_path: Optional[str] = None,
        check_invariants: bool = False,
        transport: Optional[Any] = None,
        workers: Optional[int] = None,
        backend_options: Optional[Dict[str, Any]] = None,
        supervise: Union[bool, Dict[str, Any]] = False,
        autoscale: Optional[Union[bool, Dict[str, Any]]] = None,
        on_worker_event: Optional[Any] = None,
    ) -> "StreamSystem":
        """Reconstruct a full system from a checkpoint payload.

        Replays the control-plane journal (minting the exact same running
        task ids and DAG names), then redeploys every checkpointed segment
        on the target backend — by default the checkpointed one, or any
        other registered backend for a cross-backend restore
        (``inprocess`` ⇄ ``dryrun``; see the backend decode hooks for what
        carries across). ``step_mode``/``max_workers`` override the
        checkpointed stepping config — a checkpoint taken in either mode
        restores into either mode (the segment dependency DAG is derived
        state, rebuilt by the redeploy)."""
        mgr = ReuseManager.replay(
            payload["journal"],
            strategy=payload["strategy"],
            journal_path=journal_path,
        )
        mgr.check_invariants = check_invariants
        target = backend if backend is not None else payload["backend"]
        # Re-spawn the checkpointed data-plane topology (transport kind,
        # worker pool, placement) when restoring onto the same backend
        # name; explicit transport=/workers=/backend_options= override it,
        # and a cross-backend restore starts from that backend's defaults.
        options: Dict[str, Any] = {}
        if isinstance(target, str) and target == payload.get("backend"):
            options.update(payload.get("backend_config") or {})
        if backend_options:
            options.update(backend_options)
        if transport is not None:
            options["transport"] = transport
        if workers is not None:
            options["workers"] = workers
        system = cls(
            strategy=payload["strategy"],
            base_batch=int(payload["base_batch"]),
            backend=target,
            backend_options=options or None,
            supervise=supervise,
            autoscale=autoscale,
            on_worker_event=on_worker_event,
            checkpoint_dir=checkpoint_dir,
            checkpoint_background=(
                checkpoint_background
                if checkpoint_background is not None
                else (bool(payload.get("checkpoint_background", False)) and bool(checkpoint_dir))
            ),
        )
        # The cadence/retention survive the restore even when no
        # checkpoint_dir is configured yet (step() only auto-checkpoints
        # once a store exists), so payload → restore → payload stays a
        # fixed point.
        system.checkpoint_every = (
            checkpoint_every if checkpoint_every is not None
            else payload.get("checkpoint_every")
        )
        system.checkpoint_keep_last = (
            checkpoint_keep_last if checkpoint_keep_last is not None
            else payload.get("checkpoint_keep_last")
        )
        if system.checkpoint_store is not None:
            system.checkpoint_store.keep_last = system.checkpoint_keep_last
        system.backend.configure_stepping(
            step_mode=step_mode if step_mode is not None else payload.get("step_mode"),
            max_workers=(
                max_workers if max_workers is not None else payload.get("max_workers")
            ),
            on_wave=on_wave,
        )
        system.manager = mgr
        system.manager.tracer = system.backend.tracer  # replaced the wired one
        system.task_batch = {t: int(b) for t, b in payload["task_batch"].items()}
        system._seg_counter = int(payload["seg_counter"])
        system._segments_of = {n: list(s) for n, s in payload["segments_of"].items()}
        system.backend.restore_state(payload["data"])
        if check_invariants:
            system.manager.verify()
        return system

    @classmethod
    def restore(
        cls,
        path: str,
        backend: Optional[Union[str, ExecutionBackend]] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        checkpoint_keep_last: Optional[int] = None,
        checkpoint_background: Optional[bool] = None,
        step_mode: Optional[str] = None,
        max_workers: Optional[int] = None,
        on_wave: Optional[Any] = None,
        journal_path: Optional[str] = None,
        check_invariants: bool = False,
        transport: Optional[Any] = None,
        workers: Optional[int] = None,
        backend_options: Optional[Dict[str, Any]] = None,
        supervise: Union[bool, Dict[str, Any]] = False,
        autoscale: Optional[Union[bool, Dict[str, Any]]] = None,
        on_worker_event: Optional[Any] = None,
    ) -> "StreamSystem":
        """Restore from ``path`` — a checkpoint directory (newest valid
        checkpoint wins; torn last checkpoints are skipped) or one concrete
        ``ckpt-*.json`` file. The restored system keeps checkpointing into
        the same directory unless ``checkpoint_dir`` says otherwise."""
        if os.path.isdir(path):
            store = CheckpointStore(path)
            payload = store.latest_payload()
            default_dir = path
        else:
            store = CheckpointStore(os.path.dirname(path) or ".")
            payload = store.load(path)["payload"]
            default_dir = os.path.dirname(path) or "."
        return cls.from_payload(
            payload,
            backend=backend,
            checkpoint_dir=checkpoint_dir or default_dir,
            checkpoint_every=checkpoint_every,
            checkpoint_keep_last=checkpoint_keep_last,
            checkpoint_background=checkpoint_background,
            step_mode=step_mode,
            max_workers=max_workers,
            on_wave=on_wave,
            journal_path=journal_path,
            check_invariants=check_invariants,
            transport=transport,
            workers=workers,
            backend_options=backend_options,
            supervise=supervise,
            autoscale=autoscale,
            on_worker_event=on_worker_event,
        )

    def quiesce(self) -> None:
        """Drain in-flight work without releasing anything.

        Blocks until any concurrent dispatch in progress has finished (the
        stepping pool is drained and dropped; it is re-created lazily on
        the next concurrent step) and queued background checkpoints are
        durably on disk. The serving front end calls this before taking a
        shutdown checkpoint, so the written state can never race a step.
        """
        self.flush_checkpoints()
        self.backend._reset_pool()

    def close(self) -> None:
        """Release data-plane resources: flush queued background
        checkpoints, then close the backend (dispatch pool; for the
        multiproc backend also the worker pool and transport).

        Idempotent; single-process systems remain usable — stepping
        recreates what they need lazily."""
        if self._supervisor is not None:
            self._supervisor.stop()
        if self._ckpt_writer is not None:
            self._ckpt_writer.close()
            self._ckpt_writer = None
        self.backend.close()

    # -- observability ----------------------------------------------------------------
    def sink_digests(self, sub_name: str) -> Dict[str, Dict[str, Any]]:
        """Per submitted sink: count/checksum state — the output stream
        identity used to verify Default ≡ Reuse (paper's §3.3 guarantee).
        Checksums are jit-only; the dry-run backend reports 0.0."""
        sub_df = self.manager.submitted[sub_name]
        task_map = self.manager.task_maps[sub_name]
        out: Dict[str, Dict[str, Any]] = {}
        for sink_id in sub_df.sink_ids:
            st = self.backend.sink_state(task_map[sink_id])
            out[sink_id] = {
                "count": int(st["count"]),
                "checksum": float(st["checksum"]),
            }
        return out

    def worker_health(self) -> Optional[Dict[str, Any]]:
        """Cluster-plane health: worker liveness, respawn history, recent
        events, autoscaler state. ``None`` for in-process backends (there
        is no worker pool to be unhealthy)."""
        health = self.backend.worker_health()
        if health is None:
            return None
        if self._supervisor is not None:
            health["heartbeat_interval"] = self._supervisor.heartbeat_interval
            health["heartbeat_running"] = self._supervisor.running
        if self._autoscaler is not None:
            health["autoscale"] = self._autoscaler.state()
        return health

    def placement(self) -> Placement:
        return place_round_robin(
            {name: len(seg.spec.task_ids) for name, seg in self.backend.segments.items()}
        )

    def segment_latency_ms(self) -> Dict[str, Dict[str, float]]:
        """Canonical per-segment step-latency digest — THE documented
        latency accessor.

        Reads the same measured ``StepReport.segment_ms`` history the
        dry-run fusion calibrator consumes (``backend.latency_samples()``
        feeding :func:`repro.ops.costs.fit_latency_model`), so capacity
        planning, fusion scoring and dashboards all see one source of
        truth. The straggler EWMAs remain internal scheduling state, not a
        latency surface — see :meth:`ExecutionBackend.segment_latency_stats`.
        """
        return self.backend.segment_latency_stats()

    # -- telemetry plane ---------------------------------------------------------
    def _wire_checkpoint_store(self, store: CheckpointStore) -> None:
        """Point a store at the backend's tracer/registry (encode/fsync
        spans and the checkpoint counters live inside the store, so the
        background writer thread is instrumented identically)."""
        store.tracer = self.backend.tracer
        store.metrics = self.backend.metrics

    def _wire_collectors(self) -> None:
        """Register the scrape-time collector on the backend's registry.

        Idempotent per registry instance — :meth:`configure_obs` swaps the
        registry, after which the next call re-registers on the new one.
        """
        registry = self.backend.metrics
        if registry is self._obs_registry:
            return
        registry.add_collector(self._collect_obs)
        self._obs_registry = registry

    def _collect_obs(self) -> None:
        """Mirror transport / compile-cache / reuse state into the registry.

        Runs inside every registry snapshot (Prometheus scrape, savings
        cross-checks), never on the stepping hot path. Counters use
        ``set_total`` — the underlying sources are already cumulative.
        """
        m = self.backend.metrics
        transport = getattr(self.backend, "transport", None)
        if transport is not None:
            counters = transport.counters()
            m.counter(
                "repro_transport_publishes_total",
                "event batches published onto boundary-stream topics",
            ).set_total(counters["publishes"])
            m.counter(
                "repro_transport_bytes_published_total",
                "payload bytes published onto boundary-stream topics",
            ).set_total(counters["bytes_published"])
            m.counter(
                "repro_transport_fetches_total",
                "boundary-stream fetches (plain, synced and zero-copy views)",
            ).set_total(getattr(transport, "fetch_count", 0))
        cache = self.backend.compile_cache_stats()
        m.counter(
            "repro_compile_cache_hits_total",
            "structurally identical segments served from the compiled-segment cache",
        ).set_total(cache.get("hits", 0))
        m.counter(
            "repro_compile_cache_misses_total",
            "segment structures compiled because no cached executable matched",
        ).set_total(cache.get("misses", 0))
        m.counter(
            "repro_compile_cache_evictions_total",
            "compiled-segment cache LRU evictions",
        ).set_total(cache.get("evictions", 0))
        m.gauge(
            "repro_compile_cache_entries",
            "distinct segment structures currently cached",
        ).set(cache.get("entries", 0))
        mgr = self.manager
        m.gauge(
            "repro_reuse_tasks_saved",
            "running tasks avoided right now by collaborative reuse "
            "(submitted task count minus running task count)",
        ).set(max(mgr.submitted_task_count - mgr.running_task_count, 0))
        oc = mgr.op_counts
        m.counter(
            "repro_reuse_tasks_submitted_total",
            "running tasks requested across all submissions (reused + created)",
        ).set_total(oc["tasks_submitted"])
        m.counter(
            "repro_reuse_tasks_reused_total",
            "requested tasks satisfied by an already-running task",
        ).set_total(oc["tasks_reused"])
        m.counter(
            "repro_merge_events_total",
            "submissions that merged into the running set reusing >=1 task",
        ).set_total(oc["merge_events"])
        m.counter(
            "repro_unmerge_events_total",
            "removals (each plans and applies one unmerge)",
        ).set_total(oc["unmerge_events"])

    def configure_obs(
        self,
        metrics: Optional[bool] = None,
        trace: Optional[bool] = None,
        sample_stride: Optional[int] = None,
        trace_capacity: Optional[int] = None,
    ) -> "StreamSystem":
        """Reconfigure the telemetry plane and re-wire every consumer
        (control plane, checkpoint store, collectors) onto the resulting
        registry/tracer — the system-level twin of
        :meth:`ExecutionBackend.configure_obs`."""
        self.backend.configure_obs(
            metrics=metrics,
            trace=trace,
            sample_stride=sample_stride,
            trace_capacity=trace_capacity,
        )
        self.manager.tracer = self.backend.tracer
        if self.checkpoint_store is not None:
            self._wire_checkpoint_store(self.checkpoint_store)
        self._wire_collectors()
        return self

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Merged registry snapshot — coordinator plus (multiproc) workers."""
        return self.backend.metrics_snapshot()

    def prometheus_text(self) -> str:
        """The merged snapshot rendered as Prometheus text exposition 0.0.4."""
        return render_prometheus(self.metrics_snapshot())

    def drain_spans(self) -> List[Dict[str, Any]]:
        """Drain buffered trace spans (destructive), coordinator + workers,
        sorted by start timestamp."""
        return self.backend.drain_spans()

    def export_chrome_trace(self, path: str) -> int:
        """Drain spans into a Chrome/Perfetto trace file; returns the
        number of spans written."""
        spans = self.drain_spans()
        write_chrome_trace(path, spans)
        return len(spans)

    @property
    def running_task_count(self) -> int:
        return self.manager.running_task_count

    @property
    def deployed_task_count(self) -> int:
        return self.backend.deployed_task_count
