"""Segments — jit-compiled partial DAGs (the Storm-topology analogue).

A segment owns a subset of a running DAG's tasks and compiles their
composition into **one** jitted step function. Immutability of the compiled
XLA executable mirrors Storm topology immutability; structural changes are
made by launching new segments wired through the broker (incremental merge)
or by defragmentation (relaunch as one fused segment).

Batched event semantics:
  * every stream carries one ``(B_t, EVENT_WIDTH)`` batch per step;
  * a task's input batch is the concatenation of its parents' outputs in
    **canonical order** (sorted by Merkle ancestor signature — equivalent
    tasks sort identically, so Default and Reuse runs process events in the
    same order and sink outputs are bit-identical);
  * interleave semantics ⇒ B_task = Σ B_parent; sources emit B₀.

Pause (paper §4.3): each task has an ``active`` flag in the carried state.
A paused task's body is skipped via ``lax.cond`` and it emits zeros; this is
the control-topic pause signal — no recompilation, no disruption to the
segment. Termination closure (terminated sets are descendant-closed — see
DESIGN.md) guarantees no live task ever consumes a paused task's output.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import Dataflow
from repro.ops import EVENT_WIDTH, Operator, operator_for_task

from .backend import SegmentSpec, compute_batches  # noqa: F401 — canonical home
from .broker import topic_for

PyTree = Any


@dataclass
class Segment:
    spec: SegmentSpec
    operators: Dict[str, Operator]
    step_fn: Callable  # jitted: (states, active, inputs) -> (states, outputs, taps)
    states: Dict[str, PyTree]
    active: Dict[str, jnp.ndarray]
    boundary_topics: List[str]  # topics fetched from the broker each step
    cost_of: Dict[str, float] = field(default_factory=dict)  # per-task cost_weight
    steps_run: int = 0

    @property
    def name(self) -> str:
        return self.spec.name

    def live_task_ids(self) -> List[str]:
        return [t for t in self.spec.task_ids if bool(self.active[t])]

    def pause(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = jnp.zeros((), jnp.bool_)

    def resume(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = jnp.ones((), jnp.bool_)


def _peephole_fused_kernels(
    spec: SegmentSpec,
    dataflow: Dataflow,
    operators: Dict[str, Operator],
    parents: Dict[str, List[str]],
) -> None:
    """Collapse straight-line elementwise runs onto multi-op pallas kernels.

    Within a *fused* segment, a run ``elementwise → … → (rmsnorm|elementwise)``
    where every link is a private single-parent/single-consumer edge computes
    a pure composition — the tail's operator is swapped for one fused kernel
    applied to the run head's input (``repro.ops.riot.make_fused_operator``),
    so the whole run is one pallas launch on accelerator backends. Interior
    operators keep computing: every task's output stays published-switchable
    (a later merge may subscribe to any topic), and on the ref/CPU path XLA
    CSEs the duplicated affine work away inside the single jitted step.

    Mutates ``operators`` and ``parents`` (the step closure's locals) only —
    ``spec`` is untouched, so boundary wiring, checkpoint state structure,
    and per-task cost accounting are unchanged. Deterministic in spec order,
    and driven purely by ⟨type, config, batch, wiring, fused⟩ — exactly the
    compile-cache key — so cached canonical twins fuse identically.
    """
    if not spec.fused:
        return
    from repro.ops.riot import (  # deferred: keep op registry init lazy
        FUSABLE_ELEMENTWISE,
        FUSED_TAILS,
        make_fused_operator,
    )

    in_segment = set(spec.task_ids)
    children: Dict[str, List[str]] = {}
    for t in spec.task_ids:
        for p in parents[t]:
            if p in in_segment:
                children.setdefault(p, []).append(t)
    used: Set[str] = set()
    for tid in reversed(spec.task_ids):  # tails first (task_ids is topo-sorted)
        if tid in used or dataflow.tasks[tid].type not in FUSED_TAILS:
            continue
        run = [tid]
        cur = tid
        while True:
            ps = parents[cur]
            if len(ps) != 1:
                break
            p = ps[0]
            if (
                p not in in_segment
                or children.get(p) != [cur]
                or dataflow.tasks[p].type not in FUSABLE_ELEMENTWISE
            ):
                break
            run.append(p)
            cur = p
        if len(run) < 2:
            continue
        run.reverse()  # head .. tail
        fused_op = make_fused_operator(
            [dataflow.tasks[t] for t in run], batch=spec.batch_of[tid]
        )
        if fused_op is None:
            continue
        operators[tid] = fused_op
        parents[tid] = list(parents[run[0]])
        used.update(run[:-1])


def build_segment(
    spec: SegmentSpec,
    dataflow: Dataflow,
    init_states: Optional[Dict[str, PyTree]] = None,
    cache: Any = None,
) -> Segment:
    """Compile a segment: one jitted step over all its tasks.

    With a ``cache`` (a :class:`repro.runtime.compile_cache.CompileCache`),
    the jitted step function is looked up by the spec's structural
    signature — a structurally identical segment built earlier shares its
    traced executable and this call skips XLA compilation entirely.
    """
    operators: Dict[str, Operator] = {}
    for tid in spec.task_ids:
        operators[tid] = operator_for_task(dataflow.tasks[tid], batch=spec.batch_of[tid])

    in_segment = set(spec.task_ids)
    boundary_parents: List[str] = []
    for tid in spec.task_ids:
        for p in spec.parents[tid]:
            if p not in in_segment and p not in boundary_parents:
                boundary_parents.append(p)
    boundary_topics = [topic_for(p) for p in boundary_parents]

    states: Dict[str, PyTree] = {}
    for tid in spec.task_ids:
        if init_states and tid in init_states:
            states[tid] = init_states[tid]
        else:
            states[tid] = operators[tid].init_state(spec.batch_of[tid])
    if spec.fused:
        # committed device arrays from step 0: donation only holds for
        # device-resident inputs (restored checkpoint states arrive as
        # host numpy, which XLA cannot alias)
        states = jax.device_put(states)
    active = {tid: jnp.ones((), jnp.bool_) for tid in spec.task_ids}

    task_ids = list(spec.task_ids)
    parents = {t: list(spec.parents[t]) for t in task_ids}
    batch_of = dict(spec.batch_of)
    _peephole_fused_kernels(spec, dataflow, operators, parents)

    def step_fn(
        states: Dict[str, PyTree],
        active: Dict[str, jnp.ndarray],
        inputs: Dict[str, jnp.ndarray],
    ):
        outputs: Dict[str, jnp.ndarray] = {}  # task id -> output batch
        new_states: Dict[str, PyTree] = {}
        for tid in task_ids:
            op, st, flag = operators[tid], states[tid], active[tid]
            if op.is_source:
                st2, y = jax.lax.cond(
                    flag,
                    lambda op=op, st=st: op.apply(st),
                    lambda st=st, b=batch_of[tid]: (
                        st,
                        jnp.zeros((b, EVENT_WIDTH), jnp.float32),
                    ),
                )
            else:
                xs = [
                    outputs[p] if p in outputs else inputs[topic_for(p)]
                    for p in parents[tid]
                ]
                x = xs[0] if len(xs) == 1 else jnp.concatenate(xs, axis=0)
                if op.is_sink:
                    st2 = jax.lax.cond(
                        flag,
                        lambda op=op, st=st, x=x: op.apply(st, x)[0],
                        lambda st=st: st,
                    )
                    y = None
                else:
                    # ops may change the event width (e.g. lm_embed lifts
                    # (B, 8) → (B, d)); the paused branch must emit zeros of
                    # the op's *output* shape, not the input's.
                    _, y_abs = jax.eval_shape(op.apply, st, x)
                    st2, y = jax.lax.cond(
                        flag,
                        lambda op=op, st=st, x=x: op.apply(st, x),
                        lambda st=st, y_abs=y_abs: (
                            st,
                            jnp.zeros(y_abs.shape, y_abs.dtype),
                        ),
                    )
            new_states[tid] = st2
            if y is not None:
                outputs[tid] = y
        # Return *all* task outputs; the executor publishes the forwarding
        # subset to the broker (runtime-switchable, no recompilation).
        return new_states, outputs

    if cache is not None:
        # Compiled-segment reuse: step through the cache's canonical jitted
        # callable (adapter-renamed per call). Structurally identical
        # segments — resubmitted dataflows, template copies — share one
        # traced executable instead of recompiling. The canonical twin is
        # built with the same fused flag, so donation semantics carry over.
        jitted = cache.step_fn_for(spec, dataflow)
    elif spec.fused:
        # Fusion-compiled hot path: donate the pre-step states to XLA so
        # the post-step states reuse their buffers in place and the fused
        # chain's intermediate streams live only as executable temporaries.
        # Donation invalidates the donated arrays — safe here because the
        # executors replace ``seg.states`` wholesale right after each call
        # and never step the same states twice (checkpoint/defrag reads
        # happen between steps, on the *new* states).
        jitted = jax.jit(step_fn, donate_argnums=(0,))
    else:
        jitted = jax.jit(step_fn)
    return Segment(
        spec=spec,
        operators=operators,
        step_fn=jitted,
        states=states,
        active=active,
        boundary_topics=boundary_topics,
        cost_of={tid: operators[tid].cost_weight for tid in spec.task_ids},
    )


def donation_report(seg: Segment, inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Verify that buffer donation actually holds for a segment's step.

    Lowers and compiles the segment's step for the given boundary
    ``inputs`` and reads the executable's memory analysis — the modern
    JAX surface of the classic ``setup_alias`` / ``total_allocation_size``
    check: ``alias_size_in_bytes`` counts the input bytes XLA aliased to
    outputs (> 0 iff donation held), and the argument/output/temp sizes
    give the roofline of what the step materializes.
    """
    lowered = seg.step_fn.lower(seg.states, seg.active, inputs)
    compiled = lowered.compile()
    try:
        mem = compiled.memory_analysis()
    except Exception:  # pragma: no cover - backend without memory stats
        mem = None
    report: Dict[str, Any] = {
        "fused": bool(seg.spec.fused),
        "donation_holds": False,
        "alias_size_in_bytes": 0,
    }
    if mem is not None:
        report.update(
            alias_size_in_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
            argument_size_in_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_size_in_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_size_in_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        )
        # total live bytes a step allocates beyond its aliased inputs —
        # the number the fused-vs-unfused roofline compares
        report["total_allocation_size"] = (
            report["argument_size_in_bytes"]
            + report["output_size_in_bytes"]
            + report["temp_size_in_bytes"]
            - report["alias_size_in_bytes"]
        )
        report["donation_holds"] = report["alias_size_in_bytes"] > 0
    return report
