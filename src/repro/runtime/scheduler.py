"""Worker-pool placement model + straggler policy — the Storm scheduler analogue.

The paper's setup: each node runs one Worker JVM per core (8/node), up to 8
tasks per Worker without interference, and a Worker hosts tasks from only
one topology (segment). Storm places tasks round-robin. This model converts
a set of deployed segments into the node count a real cluster would need —
benchmarks report it alongside task counts and core usage.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

WORKERS_PER_NODE = 8
TASKS_PER_WORKER = 8


@dataclass
class Placement:
    # segment -> list of (node, worker) slots, one per task
    assignments: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    nodes_used: int = 0
    workers_used: int = 0


def place_round_robin(segment_tasks: Dict[str, int]) -> Placement:
    """Round-robin placement honoring one-segment-per-worker.

    ``segment_tasks``: segment name -> number of deployed tasks (paused
    tasks still occupy slots — the paper's pause overhead in worker slots).
    """
    placement = Placement()
    next_worker = 0
    for name in sorted(segment_tasks):
        n = segment_tasks[name]
        slots: List[Tuple[int, int]] = []
        remaining = n
        while remaining > 0:
            batch = min(remaining, TASKS_PER_WORKER)
            node, worker = divmod(next_worker, WORKERS_PER_NODE)
            slots.extend((node, worker) for _ in range(batch))
            next_worker += 1
            remaining -= batch
        placement.assignments[name] = slots
    placement.workers_used = next_worker
    placement.nodes_used = (next_worker + WORKERS_PER_NODE - 1) // WORKERS_PER_NODE
    return placement


@dataclass
class StragglerEvent:
    step: int
    segment: str
    ewma_ms: float
    median_ms: float


class StragglerPolicy:
    """k·median EWMA policy (pure, unit-testable).

    The Executor embeds the same logic; this standalone class is used by the
    scheduler tests and by the simulated 1000-node run in the benchmarks.
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.3):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, timings_ms: Dict[str, float]) -> List[str]:
        for name, ms in timings_ms.items():
            prev = self.ewma.get(name)
            self.ewma[name] = ms if prev is None else self.alpha * ms + (1 - self.alpha) * prev
        for name in list(self.ewma):
            if name not in timings_ms:
                del self.ewma[name]
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        flagged = [
            name
            for name, ew in self.ewma.items()
            if median > 0 and ew > self.factor * median
        ]
        for name in flagged:
            self.events.append(StragglerEvent(step, name, self.ewma[name], median))
            # re-dispatch: relocated segment is judged afresh
            del self.ewma[name]
        return flagged
