"""Wave scheduling + placement policies + straggler policy — the Storm
scheduler analogue.

Three layers of scheduling live here:

  * the **wave / ready-queue scheduler** used by concurrent stepping —
    :func:`compute_waves` partitions the segment dependency DAG into
    topological levels (independent segments share a wave) and
    :func:`run_ready_queue` dispatches segments to a thread pool the
    moment their upstream segments finish, so devices genuinely overlap
    and a straggler only delays its own consumers;
  * :class:`PlacementPolicy` — the pluggable segment→device assignment API
    used by :class:`repro.runtime.sharded.ShardedBackend`. It generalizes
    :func:`place_round_robin` from the fixed worker-slot model to any pool
    of execution slots (``jax.devices()``, worker JVMs, hosts). Policies
    register by name, mirroring the strategy/backend registries, and may
    consult the straggler tracker's per-segment EWMA step-times (the
    ``ewma_aware`` policy closes the measurement→placement feedback loop);
  * :func:`place_round_robin` — the paper's setup: each node runs one
    Worker JVM per core (8/node), up to 8 tasks per Worker without
    interference, and a Worker hosts tasks from only one topology
    (segment). Storm places tasks round-robin. This model converts a set
    of deployed segments into the node count a real cluster would need —
    benchmarks report it alongside task counts and core usage.

This module is deliberately JAX-free.
"""
from __future__ import annotations

from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    AbstractSet,
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Tuple,
    Type,
    Union,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .backend import SegmentSpec

WORKERS_PER_NODE = 8
TASKS_PER_WORKER = 8


# -- wave / ready-queue scheduling (concurrent stepping) ------------------------


@dataclass(frozen=True)
class WaveEvent:
    """One wave of a step, delivered to ``on_wave`` observers.

    ``wave_ms`` is the wave's contribution to the step makespan: the *max*
    segment time in concurrent mode (segments overlap), the *sum* in sync
    mode (segments serialize).
    """

    step: int
    index: int
    segments: Tuple[str, ...]
    wave_ms: float


def _ordered(names, order: Optional[Mapping[str, int]]) -> List[str]:
    key = (order or {}).get
    return sorted(names, key=lambda n: (key(n, 0), n))


def compute_waves(
    deps: Mapping[str, AbstractSet[str]],
    order: Optional[Mapping[str, int]] = None,
) -> List[List[str]]:
    """Partition the segment dependency DAG into topological levels.

    ``deps`` maps segment → upstream segments (boundary-input producers).
    Segments in the same wave are mutually independent and may step
    concurrently; wave *k+1* reads only topics published by waves ≤ *k*.
    Within a wave, segments sort by ``order`` (launch sequence) so sync
    and concurrent stepping enumerate segments identically.
    """
    remaining = {n: len(ds) for n, ds in deps.items()}
    dependents: Dict[str, List[str]] = {n: [] for n in deps}
    for n, ds in deps.items():
        for d in ds:
            dependents[d].append(n)
    wave = _ordered([n for n, r in remaining.items() if r == 0], order)
    waves: List[List[str]] = []
    seen = 0
    while wave:
        waves.append(wave)
        seen += len(wave)
        nxt = []
        for n in wave:
            for m in dependents[n]:
                remaining[m] -= 1
                if remaining[m] == 0:
                    nxt.append(m)
        wave = _ordered(nxt, order)
    if seen < len(deps):
        stuck = sorted(n for n, r in remaining.items() if r > 0)
        raise ValueError(f"cycle in segment dependency graph: {stuck}")
    return waves


def compute_chains(
    deps: Mapping[str, AbstractSet[str]],
    assignment: Mapping[str, Any],
    order: Optional[Mapping[str, int]] = None,
) -> Tuple[Dict[Any, List[str]], Dict[str, int]]:
    """Flatten the dependency waves into one chain per execution slot.

    ``assignment`` maps segment → slot (worker id, device). Returns
    ``(chains, wave_of)``: each chain lists its slot's segments in global
    wave order (wave index, then launch order) — the order a worker must
    execute them so every intra-chain dependency is already satisfied when
    reached, and every cross-slot dependency points at an *earlier* wave.

    That ordering is what makes one-command-per-worker-per-step dispatch
    deadlock-free: consider the earliest (by wave, then order) entry
    blocked on a cross-slot producer. The producer sits in a strictly
    earlier wave, so every entry its slot must execute first is earlier
    still — by minimality none of them is blocked, so the producer's slot
    makes progress and eventually publishes. Inductively, all chains
    drain.
    """
    waves = compute_waves(deps, order=order)
    chains: Dict[Any, List[str]] = {}
    wave_of: Dict[str, int] = {}
    for i, wave in enumerate(waves):
        for name in wave:
            wave_of[name] = i
            chains.setdefault(assignment.get(name), []).append(name)
    return chains, wave_of


def run_ready_queue(
    deps: Mapping[str, AbstractSet[str]],
    runner: Callable[[str], float],
    max_workers: Optional[int] = None,
    order: Optional[Mapping[str, int]] = None,
    pool: Optional[ThreadPoolExecutor] = None,
    recover: Optional[Callable[[str, BaseException], bool]] = None,
    max_retries: int = 2,
) -> Dict[str, float]:
    """Dependency-aware concurrent dispatch over a thread pool.

    Every segment whose upstream segments have completed is dispatched
    immediately (no wave barrier — item-level readiness), so a straggler
    in one branch never delays independent branches. Returns the
    per-segment ``runner`` results (step wall-times in ms). The first
    runner exception is re-raised after in-flight work drains; no new
    segments are dispatched past an error.

    ``recover`` is the cluster plane's self-healing seam: when an item
    fails, ``recover(name, exc)`` may repair the fault (respawn the dead
    worker, redeploy its segments) and return ``True`` — the item is then
    **re-queued** instead of recorded as an error, at most ``max_retries``
    times per item. A declined or failed recovery falls through to the
    normal drain-and-raise path.

    Callers on a hot path pass a persistent ``pool`` (backends keep one
    across steps — pool spin-up costs more than a small step); without
    one a throwaway pool of ``max_workers`` is created and torn down.
    """
    names = list(deps)
    if not names:
        return {}
    remaining = {n: len(deps[n]) for n in names}
    dependents: Dict[str, List[str]] = {n: [] for n in names}
    for n, ds in deps.items():
        for d in ds:
            dependents[d].append(n)
    results: Dict[str, float] = {}
    errors: List[BaseException] = []
    retries: Dict[str, int] = {}
    owned = pool is None
    if pool is None:
        pool = ThreadPoolExecutor(max_workers=max_workers)
    try:
        futures = {
            pool.submit(runner, n): n
            for n in _ordered([n for n in names if remaining[n] == 0], order)
        }
        while futures:
            done, _ = wait(futures, return_when=FIRST_COMPLETED)
            newly: List[str] = []
            requeue: List[str] = []
            for fut in done:
                n = futures.pop(fut)
                try:
                    results[n] = fut.result()
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    recovered = False
                    if recover is not None and retries.get(n, 0) < max_retries:
                        try:
                            recovered = bool(recover(n, e))
                        except BaseException as re:  # noqa: BLE001
                            errors.append(re)
                            continue
                    if recovered:
                        retries[n] = retries.get(n, 0) + 1
                        requeue.append(n)
                    else:
                        errors.append(e)
                    continue
                for m in dependents[n]:
                    remaining[m] -= 1
                    if remaining[m] == 0:
                        newly.append(m)
            if errors:
                continue  # drain in-flight work, dispatch nothing new
            for m in _ordered(requeue + newly, order):
                futures[pool.submit(runner, m)] = m
    finally:
        if owned:
            pool.shutdown(wait=True)
    if errors:
        raise errors[0]
    if len(results) < len(names):
        stuck = sorted(n for n in names if n not in results)
        raise RuntimeError(f"cycle in segment dependency graph: {stuck}")
    return results


# -- segment → device placement (ShardedBackend) -------------------------------


class PlacementPolicy:
    """Assign each newly deployed segment to one of ``n_devices`` slots.

    ``load`` maps device index → number of tasks currently placed there;
    policies may ignore it (round-robin) or balance on it (least-loaded).
    ``ewma`` maps device index → aggregate EWMA step-time (ms) attributed
    to each device — the straggler tracker's view of how slow each device
    actually is (live segment EWMAs plus a time-decaying residual left by
    migrated-away segments, so a device that just shed its straggler cools
    gradually instead of instantly reading cold). Static policies ignore
    it; the ``ewma_aware`` policy balances on it and migrates segments off
    slow devices via :meth:`redispatch`. ``hints`` carries restore-time
    context (see :class:`StickyPlacement`): backends pass it only to
    policies whose ``assign`` declares the keyword, so older custom
    policies keep working unchanged.
    """

    name: str = ""

    def assign(
        self,
        spec: "SegmentSpec",
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
        hints: Optional[Dict[str, Any]] = None,
    ) -> int:
        raise NotImplementedError

    def redispatch(
        self,
        spec: "SegmentSpec",
        current: int,
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
    ) -> int:
        """Pick a new device for a straggling segment (default: stay put)."""
        return current

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} {self.name!r}>"


_PLACEMENTS: Dict[str, Type[PlacementPolicy]] = {}


def register_placement(cls: Type[PlacementPolicy]) -> Type[PlacementPolicy]:
    if not cls.name:
        raise ValueError(f"placement class {cls.__name__} has no name")
    if cls.name in _PLACEMENTS:
        raise ValueError(f"placement policy {cls.name!r} already registered")
    _PLACEMENTS[cls.name] = cls
    return cls


def available_placements() -> List[str]:
    return sorted(_PLACEMENTS)


def resolve_placement(policy: Union[str, PlacementPolicy, Type[PlacementPolicy]]) -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, PlacementPolicy):
        return policy()
    if isinstance(policy, str):
        cls = _PLACEMENTS.get(policy)
        if cls is None:
            raise ValueError(
                f"unknown placement {policy!r} (registered: {', '.join(available_placements())})"
            )
        return cls()
    raise TypeError(f"placement must be a name or PlacementPolicy, got {type(policy).__name__}")


@register_placement
class RoundRobinPlacement(PlacementPolicy):
    """Storm's scheme, lifted to device slots: segments cycle through the pool."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def assign(
        self,
        spec: "SegmentSpec",
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
        hints: Optional[Dict[str, Any]] = None,
    ) -> int:
        idx = self._next % n_devices
        self._next += 1
        return idx


@register_placement
class LeastLoadedPlacement(PlacementPolicy):
    """Greedy balance on deployed task count (paused tasks still occupy slots)."""

    name = "least_loaded"

    def assign(
        self,
        spec: "SegmentSpec",
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
        hints: Optional[Dict[str, Any]] = None,
    ) -> int:
        return min(range(n_devices), key=lambda i: (load.get(i, 0), i))


@register_placement
class EwmaAwarePlacement(PlacementPolicy):
    """Feedback placement: balance on *measured* per-device step-time EWMAs.

    Static policies see specs and task counts; this one consumes the
    straggler tracker's per-segment EWMA step-times aggregated per device
    (ROADMAP: backend-aware placement). New segments land on the device
    with the least observed work, and :meth:`redispatch` migrates a
    flagged straggler to the lightest *other* device — hot segments move
    off slow devices instead of being re-queued in place — but only when
    that device is *substantially* cooler (``improvement`` fraction of the
    source's pressure). Paired with the time-decaying device aggregates
    (a device that just shed a straggler stays warm for a few steps), the
    threshold is what damps ping-pong migrations: right after a
    migration the old device still reads hot, so an immediately re-flagged
    segment stays put instead of bouncing straight back.
    """

    name = "ewma_aware"

    def __init__(self, improvement: float = 0.5):
        if not 0.0 < improvement <= 1.0:
            raise ValueError(f"improvement must be in (0, 1], got {improvement}")
        self.improvement = improvement

    @staticmethod
    def _pressure(i: int, load: Dict[int, int], ewma: Optional[Dict[int, float]]):
        e = ewma or {}
        return (e.get(i, 0.0), load.get(i, 0), i)

    def assign(
        self,
        spec: "SegmentSpec",
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
        hints: Optional[Dict[str, Any]] = None,
    ) -> int:
        return min(range(n_devices), key=lambda i: self._pressure(i, load, ewma))

    def redispatch(
        self,
        spec: "SegmentSpec",
        current: int,
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
    ) -> int:
        if n_devices < 2:
            return current
        best = min(
            (i for i in range(n_devices) if i != current),
            key=lambda i: self._pressure(i, load, ewma),
        )
        e = ewma or {}
        cur_p = e.get(current, 0.0)
        if cur_p > 0.0 and e.get(best, 0.0) >= self.improvement * cur_p:
            return current  # destination barely cooler — migration won't pay
        return best


@register_placement
class StickyPlacement(PlacementPolicy):
    """Restore-time placement hints (ROADMAP): re-place each restored
    segment on the device it occupied *at checkpoint time* whenever the
    device pool still matches, preserving cache locality across restarts.

    The checkpointed map arrives through ``hints`` —
    ``checkpoint_device_of`` (segment → device index) and
    ``checkpoint_n_devices`` — which sharded/multiproc backends populate
    from the restored payload. Segments without a hint (new deployments,
    or a pool-size mismatch meaning the indices no longer name the same
    hardware) fall back to :class:`EwmaAwarePlacement`, as does straggler
    redispatch — stickiness pins the *starting* placement, it never traps
    a straggler.
    """

    name = "sticky"

    def __init__(self) -> None:
        self._fallback = EwmaAwarePlacement()

    def assign(
        self,
        spec: "SegmentSpec",
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
        hints: Optional[Dict[str, Any]] = None,
    ) -> int:
        h = hints or {}
        pinned = (h.get("checkpoint_device_of") or {}).get(spec.name)
        if (
            pinned is not None
            and h.get("checkpoint_n_devices") == n_devices
            and 0 <= int(pinned) < n_devices
        ):
            return int(pinned)
        return self._fallback.assign(spec, n_devices, load, ewma=ewma)

    def redispatch(
        self,
        spec: "SegmentSpec",
        current: int,
        n_devices: int,
        load: Dict[int, int],
        ewma: Optional[Dict[int, float]] = None,
    ) -> int:
        return self._fallback.redispatch(spec, current, n_devices, load, ewma=ewma)


# -- shared placement bookkeeping (sharded devices / multiproc workers) ----------


class PlacedBackendMixin:
    """Placement bookkeeping for backends that pin each segment to one slot
    of a pool — ``jax.devices()`` on the sharded backend, worker processes
    on the multiproc backend. Mixed into an ``ExecutionBackend`` subclass;
    the concrete backend implements :meth:`_n_slots` (pool size) and
    :meth:`_move_segment` (the actual state migration) and calls
    :meth:`_init_placement` from its constructor.

    Provides the EWMA feedback loop shared by both pools:

      * ``device_ewma()`` — per-slot aggregate of live segment EWMAs *plus*
        a residual left behind by migrated-away segments that decays by
        ``ewma_decay`` per step toward 0 (ROADMAP "EWMA decay on idle
        devices"): a slot that just shed its straggler stays warm for a few
        steps instead of instantly reading cold, which — combined with
        :class:`EwmaAwarePlacement`'s improvement threshold — prevents
        ping-pong migrations under bursty load;
      * ``redispatch()`` — consults the policy with the flagged segment's
        own EWMA re-attributed to its current slot (the base tracker resets
        it first), migrates via :meth:`_move_segment` when the policy picks
        a different slot, and credits the residual;
      * restore-time hints — ``device_of_at_checkpoint`` and the
        checkpointed pool size flow to policies that accept ``hints``
        (:class:`StickyPlacement`).
    """

    def _init_placement(
        self,
        policy: Union[str, "PlacementPolicy"],
        ewma_decay: float = 0.6,
    ) -> None:
        import inspect

        self.policy = resolve_placement(policy)
        self.device_of: Dict[str, int] = {}  # segment name -> slot index
        # checkpoint-time placement of the backend we restored from (if
        # any); informational unless the policy is hint-aware (sticky).
        self.device_of_at_checkpoint: Dict[str, int] = {}
        self._n_slots_at_checkpoint: Optional[int] = None
        if not 0.0 <= ewma_decay < 1.0:
            raise ValueError(f"ewma_decay must be in [0, 1), got {ewma_decay}")
        self.ewma_decay = ewma_decay
        self._ewma_residual: Dict[int, float] = {}
        # one-shot placement pins: {segment name -> slot}. The fusion
        # optimizer migrates a chain's members to one slot and pins the
        # fused replacement there, overriding the policy for that deploy.
        self._pin_slot: Dict[str, int] = {}
        # pass hints only to policies that declare the keyword, so custom
        # pre-hints PlacementPolicy subclasses keep working unchanged
        self._policy_takes_hints = (
            "hints" in inspect.signature(self.policy.assign).parameters
        )

    def _n_slots(self) -> int:
        raise NotImplementedError

    def _move_segment(self, seg: Any, old: int, new: int) -> None:
        raise NotImplementedError

    # -- aggregates ------------------------------------------------------------
    def device_load(self) -> Dict[int, int]:
        """Slot index → deployed task count (paused tasks occupy slots)."""
        load: Dict[int, int] = {}
        for name, seg in self.segments.items():
            idx = self.device_of[name]
            load[idx] = load.get(idx, 0) + len(seg.spec.task_ids)
        return load

    def device_ewma(self) -> Dict[int, float]:
        """Slot index → live segment EWMA sum + decaying migration residual."""
        ewma: Dict[int, float] = {
            idx: r for idx, r in self._ewma_residual.items() if r > 0.0
        }
        for name, ms in self.ewma_ms.items():
            idx = self.device_of.get(name)
            if idx is not None:
                ewma[idx] = ewma.get(idx, 0.0) + ms
        return ewma

    def _update_stragglers(self, seg_ms: Dict[str, float]) -> List[str]:
        # decay first: residuals cool one notch per step, then migrations
        # triggered by *this* step's flags credit fresh (undecayed) heat
        self._ewma_residual = {
            idx: r * self.ewma_decay
            for idx, r in self._ewma_residual.items()
            if r * self.ewma_decay > 1e-9
        }
        return super()._update_stragglers(seg_ms)

    # -- policy calls ----------------------------------------------------------
    def _assign_slot(self, spec: "SegmentSpec") -> int:
        pinned = self._pin_slot.pop(spec.name, None)
        if pinned is not None and 0 <= pinned < self._n_slots():
            self.device_of[spec.name] = pinned
            return pinned
        kwargs: Dict[str, Any] = {"ewma": self.device_ewma()}
        if self._policy_takes_hints:
            kwargs["hints"] = {
                "checkpoint_device_of": self.device_of_at_checkpoint,
                "checkpoint_n_devices": self._n_slots_at_checkpoint,
            }
        idx = self.policy.assign(spec, self._n_slots(), self.device_load(), **kwargs)
        self.device_of[spec.name] = idx
        return idx

    def kill(self, segment_name: str) -> None:
        super().kill(segment_name)
        self.device_of.pop(segment_name, None)

    def redispatch(self, segment_name: str) -> None:
        """Straggler mitigation with teeth: consult the placement policy for
        a new slot and migrate the segment's states there. Static policies
        keep the stay-put behavior via the default ``redispatch`` hook."""
        seg_ew = self.ewma_ms.get(segment_name, 0.0)
        super().redispatch(segment_name)  # record + reset the EWMA
        seg = self.segments.get(segment_name)
        current = self.device_of.get(segment_name)
        if seg is None or current is None:
            return
        # the flagged segment's own EWMA was just reset — re-attribute it to
        # its current slot so the policy sees the pressure behind the flag
        ewma = self.device_ewma()
        ewma[current] = ewma.get(current, 0.0) + seg_ew
        new = self.policy.redispatch(
            seg.spec, current, self._n_slots(), self.device_load(), ewma=ewma
        )
        if new != current and 0 <= new < self._n_slots():
            # migrations are rare control-plane events — worth a span and a
            # counter (getattr-guarded: the mixin contract doesn't require
            # the host backend to carry the telemetry plane)
            tracer = getattr(self, "tracer", None)
            if tracer is not None and tracer.enabled:
                with tracer.span("migrate", "control", segment=segment_name,
                                 src=current, dst=new, ewma_ms=round(seg_ew, 3)):
                    self._move_segment(seg, current, new)
            else:
                self._move_segment(seg, current, new)
            self.device_of[segment_name] = new
            metrics = getattr(self, "metrics", None)
            if metrics is not None:
                metrics.counter(
                    "repro_straggler_migrations_total",
                    "straggling segments migrated to another slot",
                ).inc()
            self._ewma_residual[current] = (
                self._ewma_residual.get(current, 0.0) + seg_ew
            )


@dataclass
class Placement:
    # segment -> list of (node, worker) slots, one per task
    assignments: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    nodes_used: int = 0
    workers_used: int = 0


def place_round_robin(segment_tasks: Dict[str, int]) -> Placement:
    """Round-robin placement honoring one-segment-per-worker.

    ``segment_tasks``: segment name -> number of deployed tasks (paused
    tasks still occupy slots — the paper's pause overhead in worker slots).
    """
    placement = Placement()
    next_worker = 0
    for name in sorted(segment_tasks):
        n = segment_tasks[name]
        slots: List[Tuple[int, int]] = []
        remaining = n
        while remaining > 0:
            batch = min(remaining, TASKS_PER_WORKER)
            node, worker = divmod(next_worker, WORKERS_PER_NODE)
            slots.extend((node, worker) for _ in range(batch))
            next_worker += 1
            remaining -= batch
        placement.assignments[name] = slots
    placement.workers_used = next_worker
    placement.nodes_used = (next_worker + WORKERS_PER_NODE - 1) // WORKERS_PER_NODE
    return placement


@dataclass
class StragglerEvent:
    step: int
    segment: str
    ewma_ms: float
    median_ms: float


class StragglerPolicy:
    """k·median EWMA policy (pure, unit-testable).

    The Executor embeds the same logic; this standalone class is used by the
    scheduler tests and by the simulated 1000-node run in the benchmarks.
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.3):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, timings_ms: Dict[str, float]) -> List[str]:
        for name, ms in timings_ms.items():
            prev = self.ewma.get(name)
            self.ewma[name] = ms if prev is None else self.alpha * ms + (1 - self.alpha) * prev
        for name in list(self.ewma):
            if name not in timings_ms:
                del self.ewma[name]
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        flagged = [
            name
            for name, ew in self.ewma.items()
            if median > 0 and ew > self.factor * median
        ]
        for name in flagged:
            self.events.append(StragglerEvent(step, name, self.ewma[name], median))
            # re-dispatch: relocated segment is judged afresh
            del self.ewma[name]
        return flagged
