"""Placement policies + worker-pool model + straggler policy — the Storm
scheduler analogue.

Two layers of placement live here:

  * :class:`PlacementPolicy` — the pluggable segment→device assignment API
    used by :class:`repro.runtime.sharded.ShardedBackend`. It generalizes
    :func:`place_round_robin` from the fixed worker-slot model to any pool
    of execution slots (``jax.devices()``, worker JVMs, hosts). Policies
    register by name, mirroring the strategy/backend registries.
  * :func:`place_round_robin` — the paper's setup: each node runs one
    Worker JVM per core (8/node), up to 8 tasks per Worker without
    interference, and a Worker hosts tasks from only one topology
    (segment). Storm places tasks round-robin. This model converts a set
    of deployed segments into the node count a real cluster would need —
    benchmarks report it alongside task counts and core usage.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Tuple, Type, Union

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .backend import SegmentSpec

WORKERS_PER_NODE = 8
TASKS_PER_WORKER = 8


# -- segment → device placement (ShardedBackend) -------------------------------


class PlacementPolicy:
    """Assign each newly deployed segment to one of ``n_devices`` slots.

    ``load`` maps device index → number of tasks currently placed there;
    policies may ignore it (round-robin) or balance on it (least-loaded).
    """

    name: str = ""

    def assign(self, spec: "SegmentSpec", n_devices: int, load: Dict[int, int]) -> int:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} {self.name!r}>"


_PLACEMENTS: Dict[str, Type[PlacementPolicy]] = {}


def register_placement(cls: Type[PlacementPolicy]) -> Type[PlacementPolicy]:
    if not cls.name:
        raise ValueError(f"placement class {cls.__name__} has no name")
    if cls.name in _PLACEMENTS:
        raise ValueError(f"placement policy {cls.name!r} already registered")
    _PLACEMENTS[cls.name] = cls
    return cls


def available_placements() -> List[str]:
    return sorted(_PLACEMENTS)


def resolve_placement(policy: Union[str, PlacementPolicy, Type[PlacementPolicy]]) -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, PlacementPolicy):
        return policy()
    if isinstance(policy, str):
        cls = _PLACEMENTS.get(policy)
        if cls is None:
            raise ValueError(
                f"unknown placement {policy!r} (registered: {', '.join(available_placements())})"
            )
        return cls()
    raise TypeError(f"placement must be a name or PlacementPolicy, got {type(policy).__name__}")


@register_placement
class RoundRobinPlacement(PlacementPolicy):
    """Storm's scheme, lifted to device slots: segments cycle through the pool."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def assign(self, spec: "SegmentSpec", n_devices: int, load: Dict[int, int]) -> int:
        idx = self._next % n_devices
        self._next += 1
        return idx


@register_placement
class LeastLoadedPlacement(PlacementPolicy):
    """Greedy balance on deployed task count (paused tasks still occupy slots)."""

    name = "least_loaded"

    def assign(self, spec: "SegmentSpec", n_devices: int, load: Dict[int, int]) -> int:
        return min(range(n_devices), key=lambda i: (load.get(i, 0), i))


@dataclass
class Placement:
    # segment -> list of (node, worker) slots, one per task
    assignments: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    nodes_used: int = 0
    workers_used: int = 0


def place_round_robin(segment_tasks: Dict[str, int]) -> Placement:
    """Round-robin placement honoring one-segment-per-worker.

    ``segment_tasks``: segment name -> number of deployed tasks (paused
    tasks still occupy slots — the paper's pause overhead in worker slots).
    """
    placement = Placement()
    next_worker = 0
    for name in sorted(segment_tasks):
        n = segment_tasks[name]
        slots: List[Tuple[int, int]] = []
        remaining = n
        while remaining > 0:
            batch = min(remaining, TASKS_PER_WORKER)
            node, worker = divmod(next_worker, WORKERS_PER_NODE)
            slots.extend((node, worker) for _ in range(batch))
            next_worker += 1
            remaining -= batch
        placement.assignments[name] = slots
    placement.workers_used = next_worker
    placement.nodes_used = (next_worker + WORKERS_PER_NODE - 1) // WORKERS_PER_NODE
    return placement


@dataclass
class StragglerEvent:
    step: int
    segment: str
    ewma_ms: float
    median_ms: float


class StragglerPolicy:
    """k·median EWMA policy (pure, unit-testable).

    The Executor embeds the same logic; this standalone class is used by the
    scheduler tests and by the simulated 1000-node run in the benchmarks.
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.3):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Dict[str, float] = {}
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, timings_ms: Dict[str, float]) -> List[str]:
        for name, ms in timings_ms.items():
            prev = self.ewma.get(name)
            self.ewma[name] = ms if prev is None else self.alpha * ms + (1 - self.alpha) * prev
        for name in list(self.ewma):
            if name not in timings_ms:
                del self.ewma[name]
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        flagged = [
            name
            for name, ew in self.ewma.items()
            if median > 0 and ew > self.factor * median
        ]
        for name in flagged:
            self.events.append(StragglerEvent(step, name, self.ewma[name], median))
            # re-dispatch: relocated segment is judged afresh
            del self.ewma[name]
        return flagged
