"""ExecutionBackend — the pluggable data-plane contract behind StreamSystem.

The paper's Manager (§4.3) binds the merge/unmerge control plane to one
concrete runtime (Storm). This module makes that binding an API instead:
:class:`StreamSystem` is a thin policy layer that drives any
:class:`ExecutionBackend` through a fixed verb set —

  ``deploy / kill / forward / pause / resume / step / snapshot /
  sink_state / account / dump_state / restore_state``

— and backends plug in by name through a registry that mirrors the
``MergeStrategy`` registry in :mod:`repro.core.strategies`. Three ship
built-in:

  * ``"inprocess"`` — :class:`repro.runtime.executor.InProcessJitBackend`,
    today's jit data plane (segments compiled to one XLA step each, broker
    topics between them);
  * ``"sharded"`` — :class:`repro.runtime.sharded.ShardedBackend`, the same
    jit plane with segments placed across ``jax.devices()`` via a pluggable
    :class:`~repro.runtime.scheduler.PlacementPolicy`;
  * ``"dryrun"`` — :class:`repro.runtime.dryrun.DryRunBackend`, no JAX at
    all: pure cost-model stepping over ``cost_weight × batch`` accounting,
    fast enough to sweep full OPMW/RIoT arrival-departure traces in
    milliseconds. Its ``live_tasks``/``paused_tasks``/``cost`` trajectories
    are contract-identical to the jit backends (checksums are jit-only).

This module is deliberately **JAX-free**: it holds the shared contract
(:class:`SegmentSpec`, :class:`StepReport`, the accounting constants, the
O(1) task→segment reverse index, straggler bookkeeping) so that a
``backend="dryrun"`` session never imports JAX.
"""
from __future__ import annotations

import importlib
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple, Type, Union

from repro.core.graph import Dataflow, Task
from repro.obs import NULL_REGISTRY, MetricsRegistry, Tracer

from .checkpoint import decode_pytree, encode_pytree
from .scheduler import WaveEvent, compute_waves, run_ready_queue

STEP_MODES = ("sync", "concurrent")

# Fraction of a task's cost still consumed while paused (deployed-but-idle
# Storm bolt). Calibrated so the paper's drain-phase crossover reproduces.
PAUSE_EPSILON = 0.03
# events·cost_weight per core: 1 core ≡ one weight-1.0 task at 10 ev/s ×
# 32-event batches — matches the paper's constant 10 ev/s input rate setup.
CORE_CALIBRATION = 320.0
# Straggler detection floor: below this median step-time the k·median test
# would flag pure perf_counter jitter (the dry-run backend steps in
# microseconds), so segments are only judged once steps cost real time.
STRAGGLER_MIN_MEDIAN_MS = 0.05

PyTree = Any


@dataclass
class SegmentSpec:
    """Static description of a segment before compilation/instantiation."""

    name: str
    dag_name: str  # running DAG this segment belongs to
    task_ids: List[str]  # topological order within the segment
    # task id -> parent ids in canonical (signature-sorted) order; parents may
    # live outside the segment (boundary inputs fetched from the broker).
    parents: Dict[str, List[str]]
    # tasks initially forwarding their output to the broker (boundary streams
    # known at deploy time). The backend can extend this set at runtime —
    # the paper's control-topic "forward" signal — without recompiling,
    # because the compiled step returns every task's output.
    publish: Set[str]
    batch_of: Dict[str, int]  # per-task output batch size
    created_at: int = 0  # launch sequence number (segments step in this order)
    # Fusion-compiled hot path: the jit planes compile this segment's step
    # with XLA buffer donation (pre-step states donated to post-step
    # states), so intermediate buffers never materialize. Donation
    # invalidates the donated arrays after each step — callers must not
    # retain references to a fused segment's states across steps (the
    # system layer therefore skips fusion under background checkpointing).
    fused: bool = False


@dataclass
class StepReport:
    step: int
    live_tasks: int
    paused_tasks: int
    cost: float  # core-equivalents this step
    wall_ms: float
    segment_ms: Dict[str, float] = field(default_factory=dict)
    stragglers: List[str] = field(default_factory=list)
    # Modelled step latency from the segment dependency DAG: Σ over waves of
    # the wave max in concurrent mode (independent segments overlap), Σ of
    # all segment_ms in sync mode (one serial sweep). For the dry-run
    # backend this *is* the predicted wall-clock of a concurrent deployment.
    makespan_ms: float = 0.0


def _encode_report(r: StepReport) -> Dict[str, Any]:
    """JSON-safe StepReport for the opt-in checkpoint ring buffer."""
    return {
        "step": int(r.step),
        "live_tasks": int(r.live_tasks),
        "paused_tasks": int(r.paused_tasks),
        "cost": float(r.cost),
        "wall_ms": float(r.wall_ms),
        "segment_ms": {k: float(v) for k, v in r.segment_ms.items()},
        "stragglers": list(r.stragglers),
        "makespan_ms": float(r.makespan_ms),
    }


def _decode_report(rec: Dict[str, Any]) -> StepReport:
    return StepReport(
        step=int(rec["step"]),
        live_tasks=int(rec["live_tasks"]),
        paused_tasks=int(rec["paused_tasks"]),
        cost=float(rec["cost"]),
        wall_ms=float(rec["wall_ms"]),
        segment_ms={k: float(v) for k, v in rec.get("segment_ms", {}).items()},
        stragglers=list(rec.get("stragglers", ())),
        makespan_ms=float(rec.get("makespan_ms", 0.0)),
    )


@dataclass
class BackendSnapshot:
    """Point-in-time backend state — the ``snapshot`` verb of the protocol."""

    backend: str
    step_count: int
    segments: Dict[str, List[str]]  # segment name -> deployed task ids
    paused: Set[str]
    live_tasks: int
    paused_tasks: int
    cost: float
    device_of: Dict[str, Any] = field(default_factory=dict)  # sharded only


def compute_batches(
    order: List[str],
    parents: Dict[str, List[str]],
    known: Dict[str, int],
    base_batch: int,
) -> Dict[str, int]:
    """Static per-task batch sizes: sources B₀, else Σ parent batches."""
    out = dict(known)
    for tid in order:
        if tid in out:
            continue
        ps = parents[tid]
        out[tid] = base_batch if not ps else sum(out[p] for p in ps)
    return out


class ExecutionBackend:
    """Data-plane protocol + the runtime-agnostic bookkeeping.

    Concrete backends implement two hooks:

      * :meth:`_build` — turn a :class:`SegmentSpec` into a segment object
        exposing ``spec``, ``states``, ``active``, ``cost_of``,
        ``pause``/``resume`` and ``live_task_ids``;
      * :meth:`_step_one` — advance one segment one step (returning a
        simulated duration in ms, or ``None`` to use the measured one).

    Everything else — the O(1) task→segment reverse index (replacing the
    old linear scans in ``forward``/``_owner``), the segment dependency
    DAG driving the sync/concurrent stepping pipeline, pause/resume
    flags, the cost accounting that reproduces the paper's Fig. 2/3
    counters, straggler EWMAs and state-preserving defragmentation — is
    shared here, so every backend reports identical control-plane
    trajectories by construction.

    Stepping runs in one of two modes (:meth:`configure_stepping`):
    ``"sync"`` — the original single-thread sweep in launch order — or
    ``"concurrent"`` — a dependency-aware ready-queue dispatch where every
    segment whose boundary producers have finished steps immediately on a
    thread pool (simulated clock on the dry-run backend). Both modes
    produce identical sink counts: concurrent dispatch respects the same
    producer-before-consumer order the launch-order sweep implies, and the
    broker's per-topic sequencing enforces it on the data path.
    """

    name: str = ""
    # Whether concurrent mode actually uses threads. The dry-run backend
    # flips this off: it keeps the dependency-DAG *makespan model* (wave
    # max, not wave sum) but steps on the caller's thread.
    concurrent_dispatch: bool = True

    def __init__(
        self,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
    ):
        self.segments: Dict[str, Any] = {}
        self.forwarding: Dict[str, Set[str]] = {}  # segment -> task ids forwarded
        self.paused: Set[str] = set()  # running task ids paused (global view)
        self.step_count = 0
        self._launch_seq = 0
        # O(1) reverse index: task id -> owning segment name, maintained
        # across deploy/kill/defragment (was an O(segments·tasks) scan).
        self._owner_of: Dict[str, str] = {}
        # task id -> ⟨type, config⟩ definition, kept so checkpoints can
        # redeploy paused tasks whose running DAGs are long gone.
        self.task_defs: Dict[str, Task] = {}
        # Segment dependency DAG: segment -> upstream segments producing its
        # boundary inputs. Maintained incrementally across deploy/kill (and
        # therefore merge/unmerge/defragment/restore, which compose them);
        # derived state — never checkpointed, always rebuilt by redeploy.
        self.seg_deps: Dict[str, Set[str]] = {}
        self._waves_cache: Optional[List[List[str]]] = None
        # stepping pipeline knobs (see configure_stepping)
        if step_mode not in STEP_MODES:
            raise ValueError(f"step_mode must be one of {STEP_MODES}, got {step_mode!r}")
        self.step_mode = step_mode
        self.max_workers = max_workers
        # Persistent dispatch pool for concurrent stepping, created lazily
        # on the first concurrent step and reused across steps (pool
        # spin-up costs more than a small step); dropped when max_workers
        # changes and on close().
        self._pool: Optional[ThreadPoolExecutor] = None
        self.on_wave: Optional[Callable[[WaveEvent], None]] = None
        # cluster-plane health surface: every backend accepts the hook, the
        # single-process backends just never emit (worker_health() -> None)
        self.worker_events: List[Any] = []
        self.on_worker_event: Optional[Callable[[Any], None]] = None
        # opt-in StepReport ring buffer: bounds self.reports in memory AND
        # persists the tail in checkpoints (None = unbounded, not persisted)
        self.history_limit: Optional[int] = None
        # straggler tracking
        self.straggler_factor = straggler_factor
        self.ewma_alpha = ewma_alpha
        self.ewma_ms: Dict[str, float] = {}
        self.redispatches: List[Tuple[int, str]] = []
        self.reports: List[StepReport] = []
        # state-leaf encoder used by dump_state/_dump_extra — swapped for a
        # deferring marker during background-checkpoint snapshots
        self._state_encoder: Callable[[Any], Any] = encode_pytree
        # telemetry plane (repro.obs): a per-backend metrics registry (so
        # tests running many systems in one process don't cross-pollute)
        # and a span tracer, disabled until configure_obs(trace=True)
        self.metrics: MetricsRegistry = MetricsRegistry()
        self.tracer = Tracer(enabled=False)
        self._mint_instruments()

    def _mint_instruments(self) -> None:
        """Pre-mint the hot-path instruments so step() does no name lookups."""
        m = self.metrics
        self._m_steps = m.counter("repro_steps_total", "data-plane steps completed")
        self._m_step_wall = m.histogram(
            "repro_step_wall_ms", "whole-step wall time (ms)"
        )
        self._m_seg_ms = m.histogram(
            "repro_segment_step_ms", "per-segment step time (ms)"
        )
        self._m_live = m.gauge("repro_tasks_live", "live (active) deployed tasks")
        self._m_paused = m.gauge("repro_tasks_paused", "paused deployed tasks")
        self._m_cost = m.gauge(
            "repro_cost_cores", "core-equivalents consumed by the last step"
        )

    def configure_obs(
        self,
        metrics: Optional[bool] = None,
        trace: Optional[bool] = None,
        sample_stride: Optional[int] = None,
        trace_capacity: Optional[int] = None,
    ) -> "ExecutionBackend":
        """Telemetry knobs (None leaves a knob unchanged).

        ``metrics=False`` swaps the registry for a no-op twin (the honest
        baseline of the overhead benchmark); ``trace=True`` arms span
        recording at ``sample_stride`` (record every Nth span per name).
        The multiproc backend additionally forwards trace configuration to
        its worker processes.
        """
        if metrics is not None:
            self.metrics = MetricsRegistry() if metrics else NULL_REGISTRY
            self._mint_instruments()
        if trace is not None or sample_stride is not None or trace_capacity is not None:
            self.tracer.configure(
                enabled=trace, sample_stride=sample_stride, capacity=trace_capacity
            )
        return self

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Aggregated metrics snapshot (overridden by worker-pool backends
        to merge worker registries shipped over the ``metrics`` RPC)."""
        return self.metrics.snapshot()

    def drain_spans(self) -> List[Dict[str, Any]]:
        """Pop all buffered trace spans (coordinator + any worker pools)."""
        return self.tracer.drain()

    def configure_stepping(
        self,
        step_mode: Optional[str] = None,
        max_workers: Optional[int] = None,
        on_wave: Optional[Callable[[WaveEvent], None]] = None,
        report_history: Optional[int] = None,
    ) -> "ExecutionBackend":
        """Set the stepping-pipeline knobs (None leaves a knob unchanged).

        Safe between steps at any point in the lifecycle — switching
        ``step_mode`` mid-run changes only the dispatch schedule, never
        the results.
        """
        if step_mode is not None:
            if step_mode not in STEP_MODES:
                raise ValueError(
                    f"step_mode must be one of {STEP_MODES}, got {step_mode!r}"
                )
            self.step_mode = step_mode
        if max_workers is not None and max_workers != self.max_workers:
            self.max_workers = max_workers
            self._reset_pool()  # resize on next concurrent step
        if on_wave is not None:
            self.on_wave = on_wave
        if report_history is not None:
            if report_history < 1:
                raise ValueError("report_history must be >= 1")
            self.history_limit = report_history
        return self

    # -- hooks for concrete backends ------------------------------------------
    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]],
    ) -> Any:
        raise NotImplementedError

    def _step_one(self, seg: Any) -> Optional[float]:
        """Advance one segment one step.

        Returns a simulated duration in ms (dry-run latency model) or
        ``None`` to report the measured wall-time. In concurrent mode this
        runs on a worker thread; it may touch only its own segment plus
        thread-safe transports (the broker).
        """
        raise NotImplementedError

    def _drop_streams(self, seg: Any) -> None:
        """Release any transport resources of a killed segment (broker topics)."""

    def _begin_concurrent_step(self) -> None:
        """Hook before a concurrent dispatch (jit backends snapshot per-topic
        sequence targets here so boundary reads sync on their producers)."""

    def _end_concurrent_step(self) -> None:
        """Hook after a concurrent dispatch completes or fails."""

    # -- deployment -----------------------------------------------------------
    def deploy(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]] = None,
    ) -> Any:
        spec.created_at = self._launch_seq
        self._launch_seq += 1
        seg = self._build(spec, dataflow, init_states)
        self.segments[spec.name] = seg
        self.forwarding[spec.name] = set(spec.publish)
        # Dependency DAG: boundary parents resolve to their owning segments.
        # Merges only add segments *downstream* of existing ones (launch
        # order is topological), so deploying never changes the deps of
        # already-deployed segments — the edge set grows incrementally.
        in_segment = set(spec.task_ids)
        deps = {
            self._owner_of[p]
            for tid in spec.task_ids
            for p in spec.parents.get(tid, ())
            if p not in in_segment and p in self._owner_of
        }
        for tid in spec.task_ids:
            self._owner_of[tid] = spec.name
            self.task_defs[tid] = dataflow.tasks[tid]
        deps.discard(spec.name)
        self.seg_deps[spec.name] = deps
        self._waves_cache = None
        return seg

    def kill(self, segment_name: str) -> None:
        seg = self.segments.pop(segment_name)
        self.forwarding.pop(segment_name, None)
        self.ewma_ms.pop(segment_name, None)
        self.seg_deps.pop(segment_name, None)
        for deps in self.seg_deps.values():
            deps.discard(segment_name)
        self._waves_cache = None
        self._drop_streams(seg)
        for tid in seg.spec.task_ids:
            self.paused.discard(tid)
            if self._owner_of.get(tid) == segment_name:
                del self._owner_of[tid]
                self.task_defs.pop(tid, None)

    # -- control signals (paper §4.3 control topic) -----------------------------
    def forward(self, task_id: str) -> None:
        """Ask the segment owning ``task_id`` to forward its output stream."""
        owner = self._owner_of.get(task_id)
        if owner is None:
            raise KeyError(f"task {task_id!r} not deployed")
        self.forwarding[owner].add(task_id)

    def pause(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.pause(task_ids)
        self.paused |= {t for t in task_ids if t in self._owner_of}

    def resume(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.resume(task_ids)
        self.paused -= set(task_ids)

    def _owner(self, task_id: str) -> Optional[str]:
        return self._owner_of.get(task_id)

    # -- stepping pipeline --------------------------------------------------------
    def segment_waves(self) -> List[List[str]]:
        """Topological levels of the segment dependency DAG (cached; segments
        in one wave are independent and step concurrently)."""
        if self._waves_cache is None:
            order = {n: s.spec.created_at for n, s in self.segments.items()}
            self._waves_cache = compute_waves(self.seg_deps, order)
        return self._waves_cache

    def _step_named(self, name: str) -> float:
        if self.tracer.enabled:
            with self.tracer.span(name, "segment", step=self.step_count):
                ms = self._step_timed(name)
        else:
            ms = self._step_timed(name)
        self._m_seg_ms.observe(ms)
        return ms

    def _step_timed(self, name: str) -> float:
        seg = self.segments[name]
        s0 = time.perf_counter()
        simulated = self._step_one(seg)
        return simulated if simulated is not None else (time.perf_counter() - s0) * 1e3

    def _step_segments(self) -> Dict[str, float]:
        """The sync sweep: every segment once, in launch order (topological)."""
        ordered = sorted(self.segments, key=lambda n: self.segments[n].spec.created_at)
        return {name: self._step_named(name) for name in ordered}

    def _step_segments_concurrent(self) -> Dict[str, float]:
        """Dependency-aware concurrent dispatch (ready-queue over a thread
        pool); falls back to the caller's thread when the backend models
        time instead of spending it (``concurrent_dispatch = False``)."""
        if not self.concurrent_dispatch:
            return self._step_segments()
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="repro-step"
            )
        self._begin_concurrent_step()
        try:
            order = {n: s.spec.created_at for n, s in self.segments.items()}
            with self.tracer.span(
                "wave_dispatch", "step", step=self.step_count,
                segments=len(self.segments),
            ):
                return run_ready_queue(
                    self.seg_deps, self._step_named, self.max_workers, order,
                    pool=self._pool, recover=self._step_recover,
                )
        finally:
            self._end_concurrent_step()

    def _reset_pool(self) -> None:
        """Drop the dispatch pool only (recreated lazily at the next
        concurrent step) — the pool-resize half of :meth:`close`, safe to
        call on a live backend."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # -- cluster-plane hooks (overridden by the multiproc backend) --------------
    def _step_recover(self, name: str, exc: BaseException) -> bool:
        """Attempt to recover from a failed segment step so the dispatch
        loop can re-queue the item instead of erroring the step. Backends
        without a self-healing worker pool decline."""
        return False

    def worker_health(self) -> Optional[Dict[str, Any]]:
        """Worker-pool health snapshot; ``None`` for in-process backends."""
        return None

    def _emit_worker_event(self, kind: str, worker: Optional[int] = None,
                           detail: str = "", ms: float = 0.0) -> None:
        """Record a cluster-plane event and forward it to the user hook.

        A failing user hook must never break recovery, so hook exceptions
        are swallowed after the event is recorded."""
        from repro.cluster.events import WorkerEvent

        event = WorkerEvent(kind=kind, worker=worker, step=self.step_count,
                            detail=detail, ms=ms)
        self.worker_events.append(event)
        if len(self.worker_events) > 256:
            del self.worker_events[:-256]
        if self.on_worker_event is not None:
            try:
                self.on_worker_event(event)
            except Exception:  # pragma: no cover - user-hook safety
                pass

    def close(self) -> None:
        """Release stepping resources (the persistent dispatch pool).

        Idempotent; stepping after close() lazily recreates the pool."""
        self._reset_pool()

    def step(self) -> StepReport:
        if self.tracer.enabled:
            with self.tracer.span("step", "step", step=self.step_count + 1):
                return self._step_impl()
        return self._step_impl()

    def _step_impl(self) -> StepReport:
        t0 = time.perf_counter()
        if self.step_mode == "concurrent":
            seg_ms = self._step_segments_concurrent()
        else:
            seg_ms = self._step_segments()
        waves = self.segment_waves()
        concurrent = self.step_mode == "concurrent"
        wave_ms = [
            (max if concurrent else sum)([seg_ms[n] for n in wave if n in seg_ms] or [0.0])
            for wave in waves
        ]
        live, paused_n, cost = self.account()
        stragglers = self._update_stragglers(seg_ms)
        self.step_count += 1
        if self.on_wave is not None:
            for i, wave in enumerate(waves):
                self.on_wave(
                    WaveEvent(
                        step=self.step_count,
                        index=i,
                        segments=tuple(wave),
                        wave_ms=wave_ms[i],
                    )
                )
        report = StepReport(
            step=self.step_count,
            live_tasks=live,
            paused_tasks=paused_n,
            cost=cost,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            segment_ms=seg_ms,
            stragglers=stragglers,
            makespan_ms=sum(wave_ms),
        )
        self._m_steps.inc()
        self._m_step_wall.observe(report.wall_ms)
        self._m_live.set(live)
        self._m_paused.set(paused_n)
        self._m_cost.set(cost)
        self.reports.append(report)
        if self.history_limit is not None and len(self.reports) > self.history_limit:
            del self.reports[: len(self.reports) - self.history_limit]
        return report

    def run(self, steps: int) -> List[StepReport]:
        return [self.step() for _ in range(steps)]

    # -- accounting ----------------------------------------------------------------
    def account(self) -> Tuple[int, int, float]:
        """(live tasks, paused tasks, core-equivalents) — the Fig. 2/3 counters."""
        live = 0
        paused_n = 0
        cost = 0.0
        for seg in self.segments.values():
            for tid in seg.spec.task_ids:
                w = seg.cost_of[tid] * seg.spec.batch_of[tid]
                if bool(seg.active[tid]):
                    live += 1
                    cost += w
                else:
                    paused_n += 1
                    cost += PAUSE_EPSILON * w
        return live, paused_n, cost / CORE_CALIBRATION

    @property
    def live_task_count(self) -> int:
        return sum(len(s.live_task_ids()) for s in self.segments.values())

    @property
    def deployed_task_count(self) -> int:
        return sum(len(s.spec.task_ids) for s in self.segments.values())

    def sink_state(self, task_id: str) -> Any:
        owner = self._owner_of.get(task_id)
        if owner is None:
            raise KeyError(f"sink task {task_id!r} not deployed")
        return self.segments[owner].states[task_id]

    def snapshot(self) -> BackendSnapshot:
        live, paused_n, cost = self.account()
        return BackendSnapshot(
            backend=self.name or type(self).__name__,
            step_count=self.step_count,
            segments={n: list(s.spec.task_ids) for n, s in self.segments.items()},
            paused=set(self.paused),
            live_tasks=live,
            paused_tasks=paused_n,
            cost=cost,
            device_of=dict(getattr(self, "device_of", {})),
        )

    def spawn_config(self) -> Dict[str, Any]:
        """Constructor kwargs that reproduce this backend's topology.

        Checkpoints persist this next to the backend name so a restore can
        re-create the same data plane — transport kind, worker count,
        placement policy — without the caller re-specifying it. Keys must
        be JSON-safe and accepted by the backend's constructor."""
        return {}

    # -- durability (checkpoint/restore verbs) ------------------------------------
    def dump_state(self, state_encoder: Optional[Callable[[Any], Any]] = None) -> Dict[str, Any]:
        """Serialize everything a restore needs to resume stepping exactly.

        The payload is backend-portable: segment specs carry each task's
        ⟨type, config⟩ so a restoring backend can rebuild operators (or cost
        entries) without the original running DAGs — deployed-but-paused
        tasks may no longer exist in any running DAG. Backend-specific
        extras (broker buffers, device maps) ride in ``extra`` via
        :meth:`_dump_extra` and are ignored by backends that don't know
        them, which is what makes inprocess ↔ dryrun cross-restores work.

        ``state_encoder`` overrides how state leaves are serialized — the
        background checkpointer passes a deferring marker so the cheap
        snapshot happens on the stepping thread and the base64 encoding on
        the writer thread (states are replaced wholesale each step, never
        mutated in place, so captured references stay consistent).
        """
        self._state_encoder = (
            encode_pytree if state_encoder is None else state_encoder
        )
        try:
            return self._dump_state_inner()
        finally:
            self._state_encoder = encode_pytree

    def _dump_state_inner(self) -> Dict[str, Any]:
        enc = self._state_encoder
        segments: List[Dict[str, Any]] = []
        for name, seg in sorted(
            self.segments.items(), key=lambda kv: kv[1].spec.created_at
        ):
            spec = seg.spec
            segments.append(
                {
                    "name": name,
                    "dag_name": spec.dag_name,
                    "task_ids": list(spec.task_ids),
                    "parents": {t: list(ps) for t, ps in spec.parents.items()},
                    # the *current* forwarding set, so runtime forward()
                    # signals survive the restore as the new publish set
                    "publish": sorted(self.forwarding.get(name, set())),
                    "batch_of": {t: int(b) for t, b in spec.batch_of.items()},
                    "created_at": int(spec.created_at),
                    "fused": bool(spec.fused),
                    "tasks": {
                        t: {"type": self.task_defs[t].type, "config": self.task_defs[t].config}
                        for t in spec.task_ids
                    },
                    "states": {
                        t: enc(seg.states[t]) for t in spec.task_ids
                    },
                    "steps_run": int(getattr(seg, "steps_run", 0)),
                }
            )
        state = {
            "step_count": int(self.step_count),
            "launch_seq": int(self._launch_seq),
            "paused": sorted(self.paused),
            "ewma_ms": {k: float(v) for k, v in self.ewma_ms.items()},
            "redispatches": [[int(s), n] for s, n in self.redispatches],
            "segments": segments,
            "extra": self._dump_extra(),
        }
        if self.history_limit is not None:
            # opt-in monitoring history: the StepReport ring buffer survives
            # restarts (dashboards resume with the pre-crash trajectory)
            state["history_limit"] = int(self.history_limit)
            state["reports"] = [_encode_report(r) for r in self.reports]
        return state

    def restore_state(self, state: Dict[str, Any]) -> None:
        """Redeploy every checkpointed segment and resume the counters.

        Must be called on a *fresh* backend. Segments re-deploy in their
        original launch order (so the launch-order-is-topological invariant
        survives), with task states decoded through the backend-specific
        :meth:`_decode_init_states` hook — that hook is where cross-backend
        restores coerce states (jit ⇄ dry-run). Sharded backends re-place
        segments through their PlacementPolicy as a side effect of
        ``deploy``; device pinning is *not* restored verbatim.
        """
        if self.segments:
            raise ValueError("restore_state() needs a fresh backend (segments deployed)")
        # Extras first: they carry transport buffers/counters and the
        # checkpoint-time placement map — restore-time placement policies
        # (sticky) consult the latter while the segments redeploy below.
        self._restore_extra(state.get("extra", {}))
        for rec in sorted(state["segments"], key=lambda r: r["created_at"]):
            spec = SegmentSpec(
                name=rec["name"],
                dag_name=rec["dag_name"],
                task_ids=list(rec["task_ids"]),
                parents={t: list(ps) for t, ps in rec["parents"].items()},
                publish=set(rec["publish"]),
                batch_of={t: int(b) for t, b in rec["batch_of"].items()},
                fused=bool(rec.get("fused", False)),
            )
            # Synthetic task-definition container: deploy only reads
            # dataflow.tasks[tid] (operator/cost construction), so the
            # checkpointed ⟨type, config⟩ records are sufficient.
            df = Dataflow(rec["dag_name"])
            for tid in spec.task_ids:
                t = rec["tasks"][tid]
                df.add_task(Task.make(tid, t["type"], t["config"]))
            init_states = self._decode_init_states(spec, df, rec["states"])
            self._launch_seq = int(rec["created_at"])
            seg = self.deploy(spec, df, init_states=init_states)
            seg.steps_run = int(rec.get("steps_run", 0))
        self._launch_seq = int(state["launch_seq"])
        paused = set(state.get("paused", ()))
        if paused:
            self.pause(paused)
        self.step_count = int(state["step_count"])
        self.ewma_ms = {k: float(v) for k, v in state.get("ewma_ms", {}).items()}
        self.redispatches = [(int(s), n) for s, n in state.get("redispatches", ())]
        if state.get("history_limit") is not None:
            self.history_limit = int(state["history_limit"])
            self.reports = [_decode_report(r) for r in state.get("reports", ())]

    def _decode_init_states(
        self, spec: SegmentSpec, dataflow: Dataflow, states_enc: Dict[str, Any]
    ) -> Dict[str, PyTree]:
        """Decode checkpointed states into this backend's native form."""
        return {tid: decode_pytree(enc) for tid, enc in states_enc.items()}

    def _dump_extra(self) -> Dict[str, Any]:
        """Backend-specific durable extras (broker buffers, device maps)."""
        return {}

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        """Consume :meth:`_dump_extra` output; unknown keys must be ignored."""

    # -- compiled-segment reuse cache ---------------------------------------------
    def compile_cache_stats(self) -> Dict[str, int]:
        """Hit/miss/evict counters of the compiled-segment reuse cache.

        Backends that compile in-process expose their coordinator cache
        (``self.compile_cache``); the multiproc backend overrides this to
        aggregate its workers' process-local caches. Backends that never
        compile (dryrun) report zeros.
        """
        cache = getattr(self, "compile_cache", None)
        if cache is None:
            return {"hits": 0, "misses": 0, "evictions": 0, "entries": 0}
        return cache.stats()

    # -- dry-run latency calibration feed ----------------------------------------
    def latency_samples(self) -> List[Tuple[Dict[str, float], float]]:
        """⟨per-task-type work units, measured segment ms⟩ calibration pairs.

        Joins every recorded ``StepReport.segment_ms`` entry with the
        deployed segment's per-task ``cost_weight × batch`` work units,
        grouped by task type — the observations
        :func:`repro.ops.costs.fit_latency_model` fits so the dry-run
        backend can report realistic ``segment_ms`` instead of ~0.
        """
        samples: List[Tuple[Dict[str, float], float]] = []
        for report in self.reports:
            for name, ms in report.segment_ms.items():
                seg = self.segments.get(name)
                if seg is None:  # segment killed since — spec no longer known
                    continue
                units: Dict[str, float] = {}
                for tid in seg.spec.task_ids:
                    ttype = self.task_defs[tid].type
                    work = seg.cost_of[tid] * seg.spec.batch_of[tid]
                    units[ttype] = units.get(ttype, 0.0) + work
                samples.append((units, float(ms)))
        return samples

    def segment_latency_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-segment latency digest from the SAME ``StepReport.segment_ms``
        history that feeds :meth:`latency_samples` (killed segments skipped
        identically), so the dry-run calibrator and any monitoring reader
        agree by construction. This — not ``ewma_ms``, which is a smoothed
        straggler-detection signal that resets on redispatch — is the
        canonical per-segment latency surface; use
        ``StreamSystem.segment_latency_ms()`` from the API layer.

        Returns ``{segment: {"mean_ms", "last_ms", "max_ms", "samples"}}``.
        """
        agg: Dict[str, Dict[str, float]] = {}
        for report in self.reports:
            for name, ms in report.segment_ms.items():
                if name not in self.segments:  # killed since — same skip as above
                    continue
                cell = agg.get(name)
                if cell is None:
                    cell = agg[name] = {
                        "mean_ms": 0.0, "last_ms": 0.0, "max_ms": 0.0,
                        "samples": 0, "_sum": 0.0,
                    }
                ms = float(ms)
                cell["_sum"] += ms
                cell["samples"] += 1
                cell["last_ms"] = ms
                cell["max_ms"] = max(cell["max_ms"], ms)
        for cell in agg.values():
            cell["mean_ms"] = cell.pop("_sum") / cell["samples"]
        return agg

    # -- straggler mitigation -----------------------------------------------------
    def _update_stragglers(self, seg_ms: Dict[str, float]) -> List[str]:
        flagged: List[str] = []
        for name, ms in seg_ms.items():
            prev = self.ewma_ms.get(name)
            self.ewma_ms[name] = ms if prev is None else (
                self.ewma_alpha * ms + (1 - self.ewma_alpha) * prev
            )
        # prune EWMAs of killed segments
        for name in list(self.ewma_ms):
            if name not in self.segments:
                del self.ewma_ms[name]
        if len(self.ewma_ms) >= 2:
            vals = sorted(self.ewma_ms.values())
            median = vals[len(vals) // 2]
            for name, ew in list(self.ewma_ms.items()):
                if median > STRAGGLER_MIN_MEDIAN_MS and ew > self.straggler_factor * median:
                    flagged.append(name)
                    self.redispatch(name)
        return flagged

    def redispatch(self, segment_name: str) -> None:
        """Re-dispatch a straggling segment (hardware: move to spare host).

        The compiled executable and task states are retained; the EWMA is
        reset so the relocated segment is judged afresh.
        """
        self.redispatches.append((self.step_count, segment_name))
        self.ewma_ms.pop(segment_name, None)

    # -- defragmentation (enactment; planning in repro.core.defrag) -----------------
    def defragment(
        self,
        dag_name: str,
        fused_spec: SegmentSpec,
        dataflow: Dataflow,
    ) -> Any:
        """Replace all segments of ``dag_name`` by one fused segment.

        Task states carry over (state-preserving defrag — beyond the paper,
        which would relaunch cold). Paused tasks are dropped entirely,
        reclaiming their ε overhead.
        """
        carried: Dict[str, PyTree] = {}
        for name, seg in list(self.segments.items()):
            if seg.spec.dag_name != dag_name:
                continue
            for tid in fused_spec.task_ids:
                if tid in seg.spec.task_ids:
                    carried[tid] = seg.states[tid]
            self.kill(name)
        return self.deploy(fused_spec, dataflow, init_states=carried)

    def fuse_segments(
        self,
        fused_spec: SegmentSpec,
        dataflow: Dataflow,
        members: List[str],
    ) -> Any:
        """Replace ``members`` (a linear same-DAG segment chain) by ONE
        fusion-compiled segment, carrying task states over.

        The enactment twin of :func:`repro.core.defrag.plan_fusion` — like
        :meth:`defragment` but member-scoped (other segments of the DAG
        stay deployed untouched), and the replacement deploys with
        ``fused_spec.fused`` set so the jit planes compile its whole task
        chain into a single donated-buffer step: the chain's intermediate
        streams become XLA temporaries that never materialize on a topic.
        """
        carried: Dict[str, PyTree] = {}
        # kill() forgets member pause flags and deploy() starts all-active,
        # so paused tasks inside the chain must be re-paused afterwards.
        repause = {t for t in fused_spec.task_ids if t in self.paused}
        for name in members:
            seg = self.segments[name]
            for tid in fused_spec.task_ids:
                if tid in seg.spec.task_ids:
                    carried[tid] = seg.states[tid]
            self.kill(name)
        seg = self.deploy(fused_spec, dataflow, init_states=carried)
        if repause:
            self.pause(repause)
        return seg


# -- backend registry ----------------------------------------------------------

_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}
# Built-ins resolve lazily so that naming "dryrun" never imports JAX and
# naming "inprocess" only pays the JAX import when actually used.
_LAZY_BUILTINS: Dict[str, Tuple[str, str]] = {
    "inprocess": ("repro.runtime.executor", "InProcessJitBackend"),
    "sharded": ("repro.runtime.sharded", "ShardedBackend"),
    "dryrun": ("repro.runtime.dryrun", "DryRunBackend"),
    "multiproc": ("repro.runtime.worker", "MultiprocBackend"),
}


def register_backend(cls: Type[ExecutionBackend]) -> Type[ExecutionBackend]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"backend class {cls.__name__} has no name")
    if cls.name in _BACKENDS or cls.name in _LAZY_BUILTINS:
        raise ValueError(f"execution backend {cls.name!r} already registered")
    _BACKENDS[cls.name] = cls
    return cls


def available_backends() -> List[str]:
    return sorted(set(_BACKENDS) | set(_LAZY_BUILTINS))


def resolve_backend(
    backend: Union[str, ExecutionBackend, Type[ExecutionBackend]],
    **kwargs: Any,
) -> ExecutionBackend:
    """Name / instance / class → backend instance (names hit the registry)."""
    if isinstance(backend, ExecutionBackend):
        return backend
    if isinstance(backend, type) and issubclass(backend, ExecutionBackend):
        return backend(**kwargs)
    if isinstance(backend, str):
        cls = _BACKENDS.get(backend)
        if cls is None and backend in _LAZY_BUILTINS:
            module, attr = _LAZY_BUILTINS[backend]
            cls = getattr(importlib.import_module(module), attr)
        if cls is None:
            raise ValueError(
                f"unknown backend {backend!r} (registered: {', '.join(available_backends())})"
            )
        return cls(**kwargs)
    raise TypeError(
        f"backend must be a name or ExecutionBackend, got {type(backend).__name__}"
    )
