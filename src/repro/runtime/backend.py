"""ExecutionBackend — the pluggable data-plane contract behind StreamSystem.

The paper's Manager (§4.3) binds the merge/unmerge control plane to one
concrete runtime (Storm). This module makes that binding an API instead:
:class:`StreamSystem` is a thin policy layer that drives any
:class:`ExecutionBackend` through a fixed verb set —

  ``deploy / kill / forward / pause / resume / step / snapshot /
  sink_state / account / dump_state / restore_state``

— and backends plug in by name through a registry that mirrors the
``MergeStrategy`` registry in :mod:`repro.core.strategies`. Three ship
built-in:

  * ``"inprocess"`` — :class:`repro.runtime.executor.InProcessJitBackend`,
    today's jit data plane (segments compiled to one XLA step each, broker
    topics between them);
  * ``"sharded"`` — :class:`repro.runtime.sharded.ShardedBackend`, the same
    jit plane with segments placed across ``jax.devices()`` via a pluggable
    :class:`~repro.runtime.scheduler.PlacementPolicy`;
  * ``"dryrun"`` — :class:`repro.runtime.dryrun.DryRunBackend`, no JAX at
    all: pure cost-model stepping over ``cost_weight × batch`` accounting,
    fast enough to sweep full OPMW/RIoT arrival-departure traces in
    milliseconds. Its ``live_tasks``/``paused_tasks``/``cost`` trajectories
    are contract-identical to the jit backends (checksums are jit-only).

This module is deliberately **JAX-free**: it holds the shared contract
(:class:`SegmentSpec`, :class:`StepReport`, the accounting constants, the
O(1) task→segment reverse index, straggler bookkeeping) so that a
``backend="dryrun"`` session never imports JAX.
"""
from __future__ import annotations

import importlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple, Type, Union

from repro.core.graph import Dataflow, Task

from .checkpoint import decode_pytree, encode_pytree

# Fraction of a task's cost still consumed while paused (deployed-but-idle
# Storm bolt). Calibrated so the paper's drain-phase crossover reproduces.
PAUSE_EPSILON = 0.03
# events·cost_weight per core: 1 core ≡ one weight-1.0 task at 10 ev/s ×
# 32-event batches — matches the paper's constant 10 ev/s input rate setup.
CORE_CALIBRATION = 320.0
# Straggler detection floor: below this median step-time the k·median test
# would flag pure perf_counter jitter (the dry-run backend steps in
# microseconds), so segments are only judged once steps cost real time.
STRAGGLER_MIN_MEDIAN_MS = 0.05

PyTree = Any


@dataclass
class SegmentSpec:
    """Static description of a segment before compilation/instantiation."""

    name: str
    dag_name: str  # running DAG this segment belongs to
    task_ids: List[str]  # topological order within the segment
    # task id -> parent ids in canonical (signature-sorted) order; parents may
    # live outside the segment (boundary inputs fetched from the broker).
    parents: Dict[str, List[str]]
    # tasks initially forwarding their output to the broker (boundary streams
    # known at deploy time). The backend can extend this set at runtime —
    # the paper's control-topic "forward" signal — without recompiling,
    # because the compiled step returns every task's output.
    publish: Set[str]
    batch_of: Dict[str, int]  # per-task output batch size
    created_at: int = 0  # launch sequence number (segments step in this order)


@dataclass
class StepReport:
    step: int
    live_tasks: int
    paused_tasks: int
    cost: float  # core-equivalents this step
    wall_ms: float
    segment_ms: Dict[str, float] = field(default_factory=dict)
    stragglers: List[str] = field(default_factory=list)


@dataclass
class BackendSnapshot:
    """Point-in-time backend state — the ``snapshot`` verb of the protocol."""

    backend: str
    step_count: int
    segments: Dict[str, List[str]]  # segment name -> deployed task ids
    paused: Set[str]
    live_tasks: int
    paused_tasks: int
    cost: float
    device_of: Dict[str, Any] = field(default_factory=dict)  # sharded only


def compute_batches(
    order: List[str],
    parents: Dict[str, List[str]],
    known: Dict[str, int],
    base_batch: int,
) -> Dict[str, int]:
    """Static per-task batch sizes: sources B₀, else Σ parent batches."""
    out = dict(known)
    for tid in order:
        if tid in out:
            continue
        ps = parents[tid]
        out[tid] = base_batch if not ps else sum(out[p] for p in ps)
    return out


class ExecutionBackend:
    """Data-plane protocol + the runtime-agnostic bookkeeping.

    Concrete backends implement two hooks:

      * :meth:`_build` — turn a :class:`SegmentSpec` into a segment object
        exposing ``spec``, ``states``, ``active``, ``cost_of``,
        ``pause``/``resume`` and ``live_task_ids``;
      * :meth:`_step_segments` — advance every segment one step, returning
        per-segment wall-times in ms.

    Everything else — the O(1) task→segment reverse index (replacing the
    old linear scans in ``forward``/``_owner``), pause/resume flags, the
    cost accounting that reproduces the paper's Fig. 2/3 counters,
    straggler EWMAs and state-preserving defragmentation — is shared here,
    so every backend reports identical control-plane trajectories by
    construction.
    """

    name: str = ""

    def __init__(self, straggler_factor: float = 3.0, ewma_alpha: float = 0.3):
        self.segments: Dict[str, Any] = {}
        self.forwarding: Dict[str, Set[str]] = {}  # segment -> task ids forwarded
        self.paused: Set[str] = set()  # running task ids paused (global view)
        self.step_count = 0
        self._launch_seq = 0
        # O(1) reverse index: task id -> owning segment name, maintained
        # across deploy/kill/defragment (was an O(segments·tasks) scan).
        self._owner_of: Dict[str, str] = {}
        # task id -> ⟨type, config⟩ definition, kept so checkpoints can
        # redeploy paused tasks whose running DAGs are long gone.
        self.task_defs: Dict[str, Task] = {}
        # straggler tracking
        self.straggler_factor = straggler_factor
        self.ewma_alpha = ewma_alpha
        self.ewma_ms: Dict[str, float] = {}
        self.redispatches: List[Tuple[int, str]] = []
        self.reports: List[StepReport] = []

    # -- hooks for concrete backends ------------------------------------------
    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]],
    ) -> Any:
        raise NotImplementedError

    def _step_segments(self) -> Dict[str, float]:
        raise NotImplementedError

    def _drop_streams(self, seg: Any) -> None:
        """Release any transport resources of a killed segment (broker topics)."""

    # -- deployment -----------------------------------------------------------
    def deploy(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]] = None,
    ) -> Any:
        spec.created_at = self._launch_seq
        self._launch_seq += 1
        seg = self._build(spec, dataflow, init_states)
        self.segments[spec.name] = seg
        self.forwarding[spec.name] = set(spec.publish)
        for tid in spec.task_ids:
            self._owner_of[tid] = spec.name
            self.task_defs[tid] = dataflow.tasks[tid]
        return seg

    def kill(self, segment_name: str) -> None:
        seg = self.segments.pop(segment_name)
        self.forwarding.pop(segment_name, None)
        self.ewma_ms.pop(segment_name, None)
        self._drop_streams(seg)
        for tid in seg.spec.task_ids:
            self.paused.discard(tid)
            if self._owner_of.get(tid) == segment_name:
                del self._owner_of[tid]
                self.task_defs.pop(tid, None)

    # -- control signals (paper §4.3 control topic) -----------------------------
    def forward(self, task_id: str) -> None:
        """Ask the segment owning ``task_id`` to forward its output stream."""
        owner = self._owner_of.get(task_id)
        if owner is None:
            raise KeyError(f"task {task_id!r} not deployed")
        self.forwarding[owner].add(task_id)

    def pause(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.pause(task_ids)
        self.paused |= {t for t in task_ids if t in self._owner_of}

    def resume(self, task_ids: Set[str]) -> None:
        for seg in self.segments.values():
            seg.resume(task_ids)
        self.paused -= set(task_ids)

    def _owner(self, task_id: str) -> Optional[str]:
        return self._owner_of.get(task_id)

    # -- stepping ----------------------------------------------------------------
    def step(self) -> StepReport:
        t0 = time.perf_counter()
        seg_ms = self._step_segments()
        live, paused_n, cost = self.account()
        stragglers = self._update_stragglers(seg_ms)
        self.step_count += 1
        report = StepReport(
            step=self.step_count,
            live_tasks=live,
            paused_tasks=paused_n,
            cost=cost,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            segment_ms=seg_ms,
            stragglers=stragglers,
        )
        self.reports.append(report)
        return report

    def run(self, steps: int) -> List[StepReport]:
        return [self.step() for _ in range(steps)]

    # -- accounting ----------------------------------------------------------------
    def account(self) -> Tuple[int, int, float]:
        """(live tasks, paused tasks, core-equivalents) — the Fig. 2/3 counters."""
        live = 0
        paused_n = 0
        cost = 0.0
        for seg in self.segments.values():
            for tid in seg.spec.task_ids:
                w = seg.cost_of[tid] * seg.spec.batch_of[tid]
                if bool(seg.active[tid]):
                    live += 1
                    cost += w
                else:
                    paused_n += 1
                    cost += PAUSE_EPSILON * w
        return live, paused_n, cost / CORE_CALIBRATION

    @property
    def live_task_count(self) -> int:
        return sum(len(s.live_task_ids()) for s in self.segments.values())

    @property
    def deployed_task_count(self) -> int:
        return sum(len(s.spec.task_ids) for s in self.segments.values())

    def sink_state(self, task_id: str) -> Any:
        owner = self._owner_of.get(task_id)
        if owner is None:
            raise KeyError(f"sink task {task_id!r} not deployed")
        return self.segments[owner].states[task_id]

    def snapshot(self) -> BackendSnapshot:
        live, paused_n, cost = self.account()
        return BackendSnapshot(
            backend=self.name or type(self).__name__,
            step_count=self.step_count,
            segments={n: list(s.spec.task_ids) for n, s in self.segments.items()},
            paused=set(self.paused),
            live_tasks=live,
            paused_tasks=paused_n,
            cost=cost,
            device_of=dict(getattr(self, "device_of", {})),
        )

    # -- durability (checkpoint/restore verbs) ------------------------------------
    def dump_state(self) -> Dict[str, Any]:
        """Serialize everything a restore needs to resume stepping exactly.

        The payload is backend-portable: segment specs carry each task's
        ⟨type, config⟩ so a restoring backend can rebuild operators (or cost
        entries) without the original running DAGs — deployed-but-paused
        tasks may no longer exist in any running DAG. Backend-specific
        extras (broker buffers, device maps) ride in ``extra`` via
        :meth:`_dump_extra` and are ignored by backends that don't know
        them, which is what makes inprocess ↔ dryrun cross-restores work.
        """
        segments: List[Dict[str, Any]] = []
        for name, seg in sorted(
            self.segments.items(), key=lambda kv: kv[1].spec.created_at
        ):
            spec = seg.spec
            segments.append(
                {
                    "name": name,
                    "dag_name": spec.dag_name,
                    "task_ids": list(spec.task_ids),
                    "parents": {t: list(ps) for t, ps in spec.parents.items()},
                    # the *current* forwarding set, so runtime forward()
                    # signals survive the restore as the new publish set
                    "publish": sorted(self.forwarding.get(name, set())),
                    "batch_of": {t: int(b) for t, b in spec.batch_of.items()},
                    "created_at": int(spec.created_at),
                    "tasks": {
                        t: {"type": self.task_defs[t].type, "config": self.task_defs[t].config}
                        for t in spec.task_ids
                    },
                    "states": {
                        t: encode_pytree(seg.states[t]) for t in spec.task_ids
                    },
                    "steps_run": int(getattr(seg, "steps_run", 0)),
                }
            )
        return {
            "step_count": int(self.step_count),
            "launch_seq": int(self._launch_seq),
            "paused": sorted(self.paused),
            "ewma_ms": {k: float(v) for k, v in self.ewma_ms.items()},
            "redispatches": [[int(s), n] for s, n in self.redispatches],
            "segments": segments,
            "extra": self._dump_extra(),
        }

    def restore_state(self, state: Dict[str, Any]) -> None:
        """Redeploy every checkpointed segment and resume the counters.

        Must be called on a *fresh* backend. Segments re-deploy in their
        original launch order (so the launch-order-is-topological invariant
        survives), with task states decoded through the backend-specific
        :meth:`_decode_init_states` hook — that hook is where cross-backend
        restores coerce states (jit ⇄ dry-run). Sharded backends re-place
        segments through their PlacementPolicy as a side effect of
        ``deploy``; device pinning is *not* restored verbatim.
        """
        if self.segments:
            raise ValueError("restore_state() needs a fresh backend (segments deployed)")
        for rec in sorted(state["segments"], key=lambda r: r["created_at"]):
            spec = SegmentSpec(
                name=rec["name"],
                dag_name=rec["dag_name"],
                task_ids=list(rec["task_ids"]),
                parents={t: list(ps) for t, ps in rec["parents"].items()},
                publish=set(rec["publish"]),
                batch_of={t: int(b) for t, b in rec["batch_of"].items()},
            )
            # Synthetic task-definition container: deploy only reads
            # dataflow.tasks[tid] (operator/cost construction), so the
            # checkpointed ⟨type, config⟩ records are sufficient.
            df = Dataflow(rec["dag_name"])
            for tid in spec.task_ids:
                t = rec["tasks"][tid]
                df.add_task(Task.make(tid, t["type"], t["config"]))
            init_states = self._decode_init_states(spec, df, rec["states"])
            self._launch_seq = int(rec["created_at"])
            seg = self.deploy(spec, df, init_states=init_states)
            seg.steps_run = int(rec.get("steps_run", 0))
        self._launch_seq = int(state["launch_seq"])
        paused = set(state.get("paused", ()))
        if paused:
            self.pause(paused)
        self.step_count = int(state["step_count"])
        self.ewma_ms = {k: float(v) for k, v in state.get("ewma_ms", {}).items()}
        self.redispatches = [(int(s), n) for s, n in state.get("redispatches", ())]
        self._restore_extra(state.get("extra", {}))

    def _decode_init_states(
        self, spec: SegmentSpec, dataflow: Dataflow, states_enc: Dict[str, Any]
    ) -> Dict[str, PyTree]:
        """Decode checkpointed states into this backend's native form."""
        return {tid: decode_pytree(enc) for tid, enc in states_enc.items()}

    def _dump_extra(self) -> Dict[str, Any]:
        """Backend-specific durable extras (broker buffers, device maps)."""
        return {}

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        """Consume :meth:`_dump_extra` output; unknown keys must be ignored."""

    # -- straggler mitigation -----------------------------------------------------
    def _update_stragglers(self, seg_ms: Dict[str, float]) -> List[str]:
        flagged: List[str] = []
        for name, ms in seg_ms.items():
            prev = self.ewma_ms.get(name)
            self.ewma_ms[name] = ms if prev is None else (
                self.ewma_alpha * ms + (1 - self.ewma_alpha) * prev
            )
        # prune EWMAs of killed segments
        for name in list(self.ewma_ms):
            if name not in self.segments:
                del self.ewma_ms[name]
        if len(self.ewma_ms) >= 2:
            vals = sorted(self.ewma_ms.values())
            median = vals[len(vals) // 2]
            for name, ew in list(self.ewma_ms.items()):
                if median > STRAGGLER_MIN_MEDIAN_MS and ew > self.straggler_factor * median:
                    flagged.append(name)
                    self.redispatch(name)
        return flagged

    def redispatch(self, segment_name: str) -> None:
        """Re-dispatch a straggling segment (hardware: move to spare host).

        The compiled executable and task states are retained; the EWMA is
        reset so the relocated segment is judged afresh.
        """
        self.redispatches.append((self.step_count, segment_name))
        self.ewma_ms.pop(segment_name, None)

    # -- defragmentation (enactment; planning in repro.core.defrag) -----------------
    def defragment(
        self,
        dag_name: str,
        fused_spec: SegmentSpec,
        dataflow: Dataflow,
    ) -> Any:
        """Replace all segments of ``dag_name`` by one fused segment.

        Task states carry over (state-preserving defrag — beyond the paper,
        which would relaunch cold). Paused tasks are dropped entirely,
        reclaiming their ε overhead.
        """
        carried: Dict[str, PyTree] = {}
        for name, seg in list(self.segments.items()):
            if seg.spec.dag_name != dag_name:
                continue
            for tid in fused_spec.task_ids:
                if tid in seg.spec.task_ids:
                    carried[tid] = seg.states[tid]
            self.kill(name)
        return self.deploy(fused_spec, dataflow, init_states=carried)


# -- backend registry ----------------------------------------------------------

_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}
# Built-ins resolve lazily so that naming "dryrun" never imports JAX and
# naming "inprocess" only pays the JAX import when actually used.
_LAZY_BUILTINS: Dict[str, Tuple[str, str]] = {
    "inprocess": ("repro.runtime.executor", "InProcessJitBackend"),
    "sharded": ("repro.runtime.sharded", "ShardedBackend"),
    "dryrun": ("repro.runtime.dryrun", "DryRunBackend"),
}


def register_backend(cls: Type[ExecutionBackend]) -> Type[ExecutionBackend]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"backend class {cls.__name__} has no name")
    if cls.name in _BACKENDS or cls.name in _LAZY_BUILTINS:
        raise ValueError(f"execution backend {cls.name!r} already registered")
    _BACKENDS[cls.name] = cls
    return cls


def available_backends() -> List[str]:
    return sorted(set(_BACKENDS) | set(_LAZY_BUILTINS))


def resolve_backend(
    backend: Union[str, ExecutionBackend, Type[ExecutionBackend]],
    **kwargs: Any,
) -> ExecutionBackend:
    """Name / instance / class → backend instance (names hit the registry)."""
    if isinstance(backend, ExecutionBackend):
        return backend
    if isinstance(backend, type) and issubclass(backend, ExecutionBackend):
        return backend(**kwargs)
    if isinstance(backend, str):
        cls = _BACKENDS.get(backend)
        if cls is None and backend in _LAZY_BUILTINS:
            module, attr = _LAZY_BUILTINS[backend]
            cls = getattr(importlib.import_module(module), attr)
        if cls is None:
            raise ValueError(
                f"unknown backend {backend!r} (registered: {', '.join(available_backends())})"
            )
        return cls(**kwargs)
    raise TypeError(
        f"backend must be a name or ExecutionBackend, got {type(backend).__name__}"
    )
