"""Multiprocess data plane — persistent worker processes + the ``multiproc``
ExecutionBackend.

PR 4's concurrent stepping pipeline overlaps segments on a thread pool, but
per-segment Python dispatch holds the GIL: measured overlap capped at ×1.28
on a 2-core host while the dry-run makespan model predicts ~×8. This module
lifts the cap the way the paper's DSPS does — worker *processes*:

  * :func:`_worker_main` — the worker loop. Each worker owns a set of
    deployed segments (compiled in-process with the same
    :func:`~repro.runtime.segment.build_segment` the jit backends use),
    attaches to the shared stream transport from a picklable spec, and
    executes commands from a duplex pipe: ``deploy / kill / step / pause /
    resume / states / ping / shutdown``. Boundary inputs are fetched from
    the transport and outputs published back — ``_fetch_inputs`` /
    ``_drop_streams`` semantics ride the transport untouched.
  * :class:`MultiprocBackend` — the coordinator. It is **JAX-free**: the
    parent process keeps :class:`RemoteSegment` proxies (spec, cost
    weights, active flags) and drives workers through blocking pipe RPCs.
    The existing wave/ready-queue scheduler dispatches those RPCs from its
    thread pool — ``conn.recv`` releases the GIL, so independent segments
    on different workers genuinely overlap. Segments are placed onto
    workers by the same pluggable
    :class:`~repro.runtime.scheduler.PlacementPolicy` machinery the
    sharded backend uses for devices (straggler migration moves a
    segment's states to another worker over the pipe).

Workers spawn with the ``spawn`` start method (fork is unsafe under JAX),
import JAX lazily inside the child, and append structured log lines to
``<log_dir>/worker-<i>.log`` (default: ``$REPRO_WORKER_LOG_DIR`` or a
temp dir) — CI uploads these on failure.

Checkpoint/restore: the coordinator drains workers (steps are synchronous
RPCs, so between steps every worker is idle), pulls encoded task states
per segment, and dumps through the shared
:meth:`~repro.runtime.backend.ExecutionBackend.dump_state`; restore
re-spawns fresh workers and re-places every segment through the placement
policy (``worker_of_at_checkpoint`` hints feed the ``sticky`` policy).
"""
from __future__ import annotations

import multiprocessing as mp
import os
import tempfile
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Union

from repro.core.graph import Dataflow, Task
from repro.ops.costs import cost_weight_for_task

from .backend import ExecutionBackend, PyTree, SegmentSpec
from .broker import topic_for
from .checkpoint import decode_pytree, encode_pytree
from .scheduler import PlacedBackendMixin, PlacementPolicy
from .transport import Transport, TransportError, connect_transport, resolve_transport

WORKER_PLANES = ("jit", "dry")


# -- the worker process ----------------------------------------------------------


class _WorkerLog:
    def __init__(self, path: str, worker_id: int):
        self.path = path
        self.worker_id = worker_id
        self._f = open(path, "a", buffering=1)

    def write(self, event: str, **fields: Any) -> None:
        stamp = time.strftime("%H:%M:%S")
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        self._f.write(f"[{stamp}] w{self.worker_id} {event} {kv}\n")

    def close(self) -> None:
        self._f.close()


class _DrySegmentLite:
    """Transport-riding stand-in for a compiled segment (``worker_plane=
    "dry"``): fetches boundary inputs, advances sink counters, publishes
    zero batches — the full distributed machinery without jit compiles.
    Useful for scheduler/transport studies and fast CI sweeps."""

    def __init__(self, spec: SegmentSpec, dataflow: Dataflow):
        import numpy as np

        self.spec = spec
        self.np = np
        self.sink_ids = [t for t in spec.task_ids if dataflow.tasks[t].is_sink]
        self.active = {t: True for t in spec.task_ids}
        self.states: Dict[str, Any] = {
            t: ({"count": 0, "checksum": 0.0} if t in self.sink_ids else ())
            for t in spec.task_ids
        }
        in_segment = set(spec.task_ids)
        self.boundary_topics = []
        for tid in spec.task_ids:
            for p in spec.parents[tid]:
                topic = topic_for(p)
                if p not in in_segment and topic not in self.boundary_topics:
                    self.boundary_topics.append(topic)

    def load_states(self, states: Dict[str, Any]) -> None:
        for tid, value in states.items():
            if tid in self.sink_ids and isinstance(value, dict):
                self.states[tid] = {"count": int(value.get("count", 0)), "checksum": 0.0}

    def pause(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = False

    def resume(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = True

    def step(self, transport: Transport, forward: List[str], targets: Optional[Dict[str, int]]) -> None:
        for topic in self.boundary_topics:
            if targets and topic in targets:
                transport.fetch_synced(topic, targets[topic])
            else:
                try:
                    transport.fetch(topic)
                except KeyError:
                    pass  # producer not restored yet — dry plane tolerates
        for tid in self.sink_ids:
            if self.active[tid]:
                st = self.states[tid]
                self.states[tid] = {"count": st["count"] + 1, "checksum": 0.0}
        np = self.np
        for tid in forward:
            if tid in self.active and tid not in self.sink_ids:
                transport.publish(
                    topic_for(tid),
                    np.zeros((self.spec.batch_of[tid], 8), np.float32),
                )


class _JitSegmentRunner:
    """Owns one compiled segment inside a worker process."""

    def __init__(self, spec: SegmentSpec, dataflow: Dataflow,
                 init_states: Optional[Dict[str, Any]]):
        from repro.ops import operator_for_task

        from .executor import _conform_state  # imports JAX (worker-side only)
        from .segment import build_segment

        if init_states:
            # conform restored/migrated states onto the operator templates —
            # same cross-backend coercion the in-process jit plane applies
            # (dry checkpoints seed sink counts, mismatched leaves re-init)
            init_states = {
                tid: _conform_state(
                    value,
                    operator_for_task(
                        dataflow.tasks[tid], batch=spec.batch_of[tid]
                    ).init_state(spec.batch_of[tid]),
                )
                for tid, value in init_states.items()
            }
        self.seg = build_segment(spec, dataflow, init_states=init_states)
        self.spec = spec

    @property
    def boundary_topics(self) -> List[str]:
        return self.seg.boundary_topics

    def pause(self, task_ids: Set[str]) -> None:
        self.seg.pause(task_ids)

    def resume(self, task_ids: Set[str]) -> None:
        self.seg.resume(task_ids)

    @property
    def states(self) -> Dict[str, Any]:
        return self.seg.states

    def step(self, transport: Transport, forward: List[str], targets: Optional[Dict[str, int]]) -> None:
        import jax
        import numpy as np

        seg = self.seg
        inputs = {}
        for topic in seg.boundary_topics:
            if targets and topic in targets:
                inputs[topic] = transport.fetch_synced(topic, targets[topic])
            else:
                inputs[topic] = transport.fetch(topic)
        new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
        seg.states = new_states
        for tid in forward:
            if tid in outputs:
                # host transfer is the publish cost of crossing a process
                # boundary; np.asarray also blocks on the value
                transport.publish(topic_for(tid), np.asarray(outputs[tid]))
        # block on the whole segment so the measured ms is compute, not
        # async dispatch (same rationale as the in-process jit backend)
        jax.block_until_ready(new_states)
        seg.steps_run += 1


def _decode_spec(rec: Dict[str, Any]) -> SegmentSpec:
    return SegmentSpec(
        name=rec["name"],
        dag_name=rec["dag_name"],
        task_ids=list(rec["task_ids"]),
        parents={t: list(ps) for t, ps in rec["parents"].items()},
        publish=set(rec["publish"]),
        batch_of={t: int(b) for t, b in rec["batch_of"].items()},
        created_at=int(rec.get("created_at", 0)),
    )


def _dataflow_from_tasks(dag_name: str, tasks: Dict[str, Dict[str, Any]]) -> Dataflow:
    df = Dataflow(dag_name)
    for tid, t in tasks.items():
        df.add_task(Task.make(tid, t["type"], t["config"]))
    return df


def _worker_main(conn, worker_id: int, transport_spec: Dict[str, Any],
                 plane: str, log_path: str) -> None:
    """The worker loop: blocking command RPCs against owned segments."""
    log = _WorkerLog(log_path, worker_id)
    log.write("start", pid=os.getpid(), plane=plane,
              transport=transport_spec.get("kind"))
    transport = connect_transport(transport_spec)
    segments: Dict[str, Any] = {}
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            log.write("coordinator-gone")
            break
        op = msg.get("op")
        try:
            reply: Dict[str, Any] = {"ok": True}
            if op == "deploy":
                spec = _decode_spec(msg["spec"])
                df = _dataflow_from_tasks(spec.dag_name, msg["tasks"])
                init = (
                    {t: decode_pytree(enc) for t, enc in msg["states"].items()}
                    if msg.get("states")
                    else None
                )
                if plane == "jit":
                    segments[spec.name] = _JitSegmentRunner(spec, df, init)
                else:
                    runner = _DrySegmentLite(spec, df)
                    if init:
                        runner.load_states(init)
                    segments[spec.name] = runner
                log.write("deploy", segment=spec.name, tasks=len(spec.task_ids))
            elif op == "kill":
                runner = segments.pop(msg["segment"])
                for tid in runner.spec.task_ids:
                    transport.drop(topic_for(tid))
                log.write("kill", segment=msg["segment"])
            elif op == "step":
                runner = segments[msg["segment"]]
                t0 = time.perf_counter()
                runner.step(transport, msg["forward"], msg.get("targets"))
                reply["ms"] = (time.perf_counter() - t0) * 1e3
            elif op == "step_many":
                # wave-batched dispatch: step every named segment (they are
                # mutually independent members of one wave, in launch
                # order) under a single command round-trip — per-segment
                # Python dispatch runs inside this process, so coordinator
                # RPC overhead amortizes to one round-trip per worker per
                # wave instead of one per segment
                ms: Dict[str, float] = {}
                for entry in msg["segments"]:
                    runner = segments[entry["segment"]]
                    t0 = time.perf_counter()
                    runner.step(transport, entry["forward"], entry.get("targets"))
                    ms[entry["segment"]] = (time.perf_counter() - t0) * 1e3
                reply["ms"] = ms
            elif op == "pause":
                segments[msg["segment"]].pause(set(msg["tasks"]))
            elif op == "resume":
                segments[msg["segment"]].resume(set(msg["tasks"]))
            elif op == "states":
                runner = segments[msg["segment"]]
                reply["states"] = {
                    tid: encode_pytree(runner.states[tid])
                    for tid in runner.spec.task_ids
                }
            elif op == "ping":
                reply["pid"] = os.getpid()
            elif op == "shutdown":
                log.write("shutdown")
            else:
                raise ValueError(f"unknown worker op {op!r}")
        except BaseException as e:  # noqa: BLE001 - reported to coordinator
            log.write("error", op=op, error=repr(e))
            log._f.write(traceback.format_exc())
            reply = {"error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()}
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            break
        if op == "shutdown":
            break
    try:
        transport.close()
    except Exception:  # pragma: no cover - shutdown best-effort
        pass
    log.close()


# -- the coordinator backend ------------------------------------------------------


class WorkerError(RuntimeError):
    """A worker process reported a failure (its log has the traceback)."""


@dataclass
class RemoteSegment:
    """Parent-side proxy of a segment deployed inside a worker process.

    Carries everything the shared accounting needs (spec, per-task cost
    weights, active flags as plain bools); task states are fetched from
    the worker on demand (checkpoint dumps, defrag carry-over) and cached
    per step."""

    spec: SegmentSpec
    backend: "MultiprocBackend"
    cost_of: Dict[str, float]
    active: Dict[str, bool]
    steps_run: int = 0
    _states_cache: Optional[Dict[str, Any]] = field(default=None, repr=False)
    _states_step: int = -1

    @property
    def name(self) -> str:
        return self.spec.name

    def live_task_ids(self) -> List[str]:
        return [t for t in self.spec.task_ids if self.active[t]]

    def pause(self, task_ids: Set[str]) -> None:
        hit = [t for t in task_ids if t in self.active]
        if not hit:
            return
        for tid in hit:
            self.active[tid] = False
        self.backend._segment_call(self, {"op": "pause", "tasks": hit})
        self._states_cache = None

    def resume(self, task_ids: Set[str]) -> None:
        hit = [t for t in task_ids if t in self.active]
        if not hit:
            return
        for tid in hit:
            self.active[tid] = True
        self.backend._segment_call(self, {"op": "resume", "tasks": hit})
        self._states_cache = None

    @property
    def states(self) -> Dict[str, Any]:
        """Decoded task states, pulled from the worker (cached per step)."""
        step = self.backend.step_count
        if self._states_cache is None or self._states_step != step:
            reply = self.backend._segment_call(self, {"op": "states"})
            self._states_cache = {
                tid: decode_pytree(enc) for tid, enc in reply["states"].items()
            }
            self._states_step = step
        return self._states_cache


class MultiprocBackend(PlacedBackendMixin, ExecutionBackend):
    """Worker-process data plane behind the ExecutionBackend protocol.

    The coordinator (this class) is JAX-free; each of ``workers`` spawned
    processes compiles and steps its segments with the same jit machinery
    as the in-process backend (``worker_plane="jit"``) or a lightweight
    transport-riding cost plane (``"dry"``). Boundary streams cross
    processes on a :class:`~repro.runtime.transport.Transport` that must
    support multi-process attachment — ``"shm"`` (default) or ``"tcp"``;
    the in-process broker is rejected with a clear error.

    Stepping composes with both pipeline modes: ``sync`` issues one
    blocking RPC per segment in launch order; ``concurrent`` lets the
    wave/ready-queue scheduler issue RPCs from its thread pool, where
    ``conn.recv`` releases the GIL — independent segments on different
    workers execute simultaneously, which is what lifts the threaded
    dispatch's GIL cap.
    """

    name = "multiproc"

    def __init__(
        self,
        workers: int = 2,
        transport: Any = "shm",
        transport_options: Optional[Dict[str, Any]] = None,
        placement: Union[str, PlacementPolicy] = "round_robin",
        worker_plane: str = "jit",
        log_dir: Optional[str] = None,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        ewma_decay: float = 0.6,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
    ):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if worker_plane not in WORKER_PLANES:
            raise ValueError(
                f"worker_plane must be one of {WORKER_PLANES}, got {worker_plane!r}"
            )
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            # the dispatch pool must cover every worker or RPC overlap dies
            max_workers=max_workers if max_workers is not None else max(workers, 2),
        )
        self.n_workers = workers
        self.worker_plane = worker_plane
        self.transport: Transport = resolve_transport(
            transport, **(transport_options or {})
        )
        # fail fast: the transport must be attachable from worker processes
        self._transport_spec = self.transport.connect_info()
        self.log_dir = (
            log_dir
            or os.environ.get("REPRO_WORKER_LOG_DIR")
            or tempfile.mkdtemp(prefix="repro-workers-")
        )
        os.makedirs(self.log_dir, exist_ok=True)
        self._init_placement(placement, ewma_decay=ewma_decay)
        self._ctx = mp.get_context("spawn")
        self._procs: List[Any] = []
        self._conns: List[Any] = []
        self._conn_locks: List[threading.Lock] = []
        self._topic_target: Optional[Dict[str, int]] = None
        self._spawned = False

    # -- worker pool ------------------------------------------------------------
    def _ensure_workers(self) -> None:
        if self._spawned:
            return
        self._spawned = True
        for i in range(self.n_workers):
            parent_conn, child_conn = self._ctx.Pipe()
            log_path = os.path.join(self.log_dir, f"worker-{i}.log")
            proc = self._ctx.Process(
                target=_worker_main,
                args=(child_conn, i, self._transport_spec, self.worker_plane,
                      log_path),
                name=f"repro-worker-{i}",
                daemon=True,
            )
            proc.start()
            child_conn.close()
            self._procs.append(proc)
            self._conns.append(parent_conn)
            self._conn_locks.append(threading.Lock())

    def _call(self, worker: int, msg: Dict[str, Any]) -> Dict[str, Any]:
        """One blocking RPC to a worker; serialized per worker, overlapping
        across workers (recv releases the GIL)."""
        self._ensure_workers()
        with self._conn_locks[worker]:
            try:
                self._conns[worker].send(msg)
                reply = self._conns[worker].recv()
            except (EOFError, BrokenPipeError, OSError) as e:
                raise WorkerError(
                    f"worker {worker} died during {msg.get('op')!r} "
                    f"(log: {os.path.join(self.log_dir, f'worker-{worker}.log')})"
                ) from e
        if "error" in reply:
            raise WorkerError(
                f"worker {worker} failed {msg.get('op')!r}: {reply['error']}\n"
                f"{reply.get('traceback', '')}"
            )
        return reply

    def _segment_call(self, seg: RemoteSegment, msg: Dict[str, Any]) -> Dict[str, Any]:
        msg = dict(msg)
        msg["segment"] = seg.spec.name
        return self._call(self.device_of[seg.spec.name], msg)

    # -- placement hooks (PlacedBackendMixin) -----------------------------------
    def _n_slots(self) -> int:
        return self.n_workers

    def _move_segment(self, seg: RemoteSegment, old: int, new: int) -> None:
        """Migrate a straggling segment to another worker: pull its encoded
        states, kill it on the old worker, redeploy on the new one."""
        reply = self._call(old, {"op": "states", "segment": seg.spec.name})
        self._call(old, {"op": "kill", "segment": seg.spec.name})
        self.device_of[seg.spec.name] = new  # before deploy RPC below
        self._deploy_rpc(new, seg.spec, states=reply["states"])
        seg._states_cache = None

    # -- ExecutionBackend hooks -------------------------------------------------
    def _encode_spec(self, spec: SegmentSpec) -> Dict[str, Any]:
        return {
            "name": spec.name,
            "dag_name": spec.dag_name,
            "task_ids": list(spec.task_ids),
            "parents": {t: list(ps) for t, ps in spec.parents.items()},
            "publish": sorted(spec.publish),
            "batch_of": {t: int(b) for t, b in spec.batch_of.items()},
            "created_at": int(spec.created_at),
        }

    def _deploy_rpc(self, worker: int, spec: SegmentSpec,
                    states: Optional[Dict[str, Any]] = None) -> None:
        self._call(
            worker,
            {
                "op": "deploy",
                "spec": self._encode_spec(spec),
                "tasks": {
                    tid: {"type": self.task_defs[tid].type,
                          "config": self.task_defs[tid].config}
                    for tid in spec.task_ids
                },
                "states": states,
            },
        )

    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]],
    ) -> RemoteSegment:
        seg = RemoteSegment(
            spec=spec,
            backend=self,
            cost_of={
                tid: cost_weight_for_task(dataflow.tasks[tid])
                for tid in spec.task_ids
            },
            active={tid: True for tid in spec.task_ids},
        )
        # deploy() records task_defs after _build returns; the RPC needs
        # them now, so register this segment's defs up front
        for tid in spec.task_ids:
            self.task_defs[tid] = dataflow.tasks[tid]
        worker = self._assign_slot(spec)
        self._deploy_rpc(
            worker,
            spec,
            states=(
                {tid: encode_pytree(v) for tid, v in init_states.items()}
                if init_states
                else None
            ),
        )
        return seg

    def _drop_streams(self, seg: RemoteSegment) -> None:
        """Kill the remote segment — the worker drops its topics on the
        shared transport (waking any in-flight synced fetches)."""
        worker = self.device_of.get(seg.spec.name)
        if worker is not None:
            self._call(worker, {"op": "kill", "segment": seg.spec.name})

    def _begin_concurrent_step(self) -> None:
        # same per-topic sequencing scheme as the in-process jit backend:
        # each forwarding task publishes exactly once per step, so this
        # step's boundary reads must observe seq+1 on their producer.
        # One sequences() snapshot instead of a seq() call per topic —
        # on the tcp transport each seq() is a socket round-trip.
        seqs = self.transport.sequences()
        self._topic_target = {
            topic_for(tid): seqs.get(topic_for(tid), 0) + 1
            for name, tids in self.forwarding.items()
            if name in self.segments
            for tid in tids
        }

    def _end_concurrent_step(self) -> None:
        self._topic_target = None

    def _step_entry(self, seg: RemoteSegment) -> Dict[str, Any]:
        targets = None
        if self._topic_target is not None:
            targets = {
                t: s for t, s in self._topic_target.items()
                if t in self._boundary_topics(seg)
            }
        return {
            "segment": seg.spec.name,
            "forward": sorted(self.forwarding[seg.spec.name]),
            "targets": targets,
        }

    def _step_one(self, seg: RemoteSegment) -> Optional[float]:
        reply = self._call(
            self.device_of[seg.spec.name], {"op": "step", **self._step_entry(seg)}
        )
        seg.steps_run += 1
        seg._states_cache = None
        return float(reply["ms"])  # worker-measured compute, not RPC wait

    def _step_wave_on_worker(self, worker: int, names: List[str]) -> Dict[str, float]:
        entries = [self._step_entry(self.segments[n]) for n in names]
        reply = self._call(worker, {"op": "step_many", "segments": entries})
        for n in names:
            seg = self.segments[n]
            seg.steps_run += 1
            seg._states_cache = None
        return {n: float(ms) for n, ms in reply["ms"].items()}

    def _step_segments_concurrent(self) -> Dict[str, float]:
        """Wave-batched concurrent dispatch.

        The generic ready-queue issues one RPC per segment; across a pipe
        that round-trip is the dominant cost for small segments. Here each
        dependency wave becomes ONE ``step_many`` command per worker
        (segments within a wave are mutually independent, so the worker
        may step its share back-to-back), dispatched to all workers
        concurrently from the thread pool — workers overlap, coordinator
        overhead is waves × workers round-trips per step instead of one
        per segment. Cross-worker boundary reads stay guarded by the
        per-topic sequence targets exactly as in per-segment dispatch.
        """
        if not self.segments:
            return {}
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor

            self._pool = ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="repro-step"
            )
        self._begin_concurrent_step()
        try:
            seg_ms: Dict[str, float] = {}
            for wave in self.segment_waves():
                by_worker: Dict[int, List[str]] = {}
                for name in wave:
                    by_worker.setdefault(self.device_of[name], []).append(name)
                futures = [
                    self._pool.submit(self._step_wave_on_worker, w, names)
                    for w, names in sorted(by_worker.items())
                ]
                for fut in futures:
                    seg_ms.update(fut.result())
            return seg_ms
        finally:
            self._end_concurrent_step()

    @staticmethod
    def _boundary_topics(seg: RemoteSegment) -> Set[str]:
        in_segment = set(seg.spec.task_ids)
        return {
            topic_for(p)
            for tid in seg.spec.task_ids
            for p in seg.spec.parents.get(tid, ())
            if p not in in_segment
        }

    # -- durability hooks ---------------------------------------------------------
    def _dump_extra(self) -> Dict[str, Any]:
        counters = self.transport.counters()
        return {
            "worker_of": {name: int(i) for name, i in self.device_of.items()},
            "n_workers": self.n_workers,
            "broker_bytes_published": int(counters["bytes_published"]),
            "broker_publishes": int(counters["publishes"]),
        }

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        self.device_of_at_checkpoint = {
            name: int(i) for name, i in extra.get("worker_of", {}).items()
        }
        if extra.get("n_workers") is not None:
            self._n_slots_at_checkpoint = int(extra["n_workers"])
        self.transport.restore_counters(
            int(extra.get("broker_bytes_published", 0)),
            int(extra.get("broker_publishes", 0)),
        )

    def spawn_config(self) -> Dict[str, Any]:
        cfg: Dict[str, Any] = {
            "workers": self.n_workers,
            "transport": self.transport.name,
            "worker_plane": self.worker_plane,
        }
        if getattr(self.policy, "name", ""):
            cfg["placement"] = self.policy.name
        return cfg

    # -- lifecycle ---------------------------------------------------------------
    def close(self) -> None:
        """Shut down the dispatch pool, the worker pool and the transport.

        Unlike the single-process backends this releases the deployed
        segments' host processes — a closed multiproc backend is done
        stepping (restore from a checkpoint to resume)."""
        super().close()
        if self._spawned:
            for i, conn in enumerate(self._conns):
                try:
                    with self._conn_locks[i]:
                        conn.send({"op": "shutdown"})
                        conn.recv()
                except (EOFError, BrokenPipeError, OSError):
                    pass
                conn.close()
            for proc in self._procs:
                proc.join(timeout=10)
                if proc.is_alive():  # pragma: no cover - stuck worker
                    proc.terminate()
                    proc.join(timeout=5)
            self._procs.clear()
            self._conns.clear()
            self._conn_locks.clear()
            self._spawned = False
        self.transport.close()

    def __del__(self):  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass
