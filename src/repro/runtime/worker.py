"""Multiprocess data plane — persistent worker processes + the ``multiproc``
ExecutionBackend.

PR 4's concurrent stepping pipeline overlaps segments on a thread pool, but
per-segment Python dispatch holds the GIL: measured overlap capped at ×1.28
on a 2-core host while the dry-run makespan model predicts ~×8. This module
lifts the cap the way the paper's DSPS does — worker *processes*:

  * :func:`_worker_main` — the worker loop. Each worker owns a set of
    deployed segments (compiled in-process with the same
    :func:`~repro.runtime.segment.build_segment` the jit backends use),
    attaches to the shared stream transport from a picklable spec, and
    executes commands from a duplex pipe: ``deploy / kill / step / pause /
    resume / states / ping / shutdown``. Boundary inputs are fetched from
    the transport and outputs published back — ``_fetch_inputs`` /
    ``_drop_streams`` semantics ride the transport untouched.
  * :class:`MultiprocBackend` — the coordinator. It is **JAX-free**: the
    parent process keeps :class:`RemoteSegment` proxies (spec, cost
    weights, active flags) and drives workers through blocking pipe RPCs.
    The existing wave/ready-queue scheduler dispatches those RPCs from its
    thread pool — ``conn.recv`` releases the GIL, so independent segments
    on different workers genuinely overlap. Segments are placed onto
    workers by the same pluggable
    :class:`~repro.runtime.scheduler.PlacementPolicy` machinery the
    sharded backend uses for devices (straggler migration moves a
    segment's states to another worker over the pipe).

Workers spawn with the ``spawn`` start method (fork is unsafe under JAX),
import JAX lazily inside the child, and append structured log lines to
``<log_dir>/worker-<i>.log`` (default: ``$REPRO_WORKER_LOG_DIR`` or a
temp dir) — CI uploads these on failure.

Checkpoint/restore: the coordinator drains workers (steps are synchronous
RPCs, so between steps every worker is idle), pulls encoded task states
per segment, and dumps through the shared
:meth:`~repro.runtime.backend.ExecutionBackend.dump_state`; restore
re-spawns fresh workers and re-places every segment through the placement
policy (``worker_of_at_checkpoint`` hints feed the ``sticky`` policy).
"""
from __future__ import annotations

import os
import pickle
import shutil
import tempfile
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple, Union

from repro.cluster.events import (
    POOL_GROWN,
    POOL_SHRUNK,
    SEGMENT_REDEPLOYED,
    WORKER_DEAD,
    WORKER_RESPAWNED,
)
from repro.core.graph import Dataflow, Task
from repro.obs import merge_snapshots, process_metrics, process_tracer
from repro.ops.costs import cost_weight_for_task

from .backend import ExecutionBackend, PyTree, SegmentSpec
from .broker import topic_for
from .checkpoint import decode_pytree, encode_pytree
from .scheduler import PlacedBackendMixin, PlacementPolicy
from .transport import Transport, TransportError, connect_transport, resolve_transport

WORKER_PLANES = ("jit", "dry")


# -- the worker process ----------------------------------------------------------


class _WorkerLog:
    def __init__(self, path: str, worker_id: int):
        self.path = path
        self.worker_id = worker_id
        self._f = open(path, "a", buffering=1)

    def write(self, event: str, **fields: Any) -> None:
        stamp = time.strftime("%H:%M:%S")
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        self._f.write(f"[{stamp}] w{self.worker_id} {event} {kv}\n")

    def close(self) -> None:
        self._f.close()


class _DrySegmentLite:
    """Transport-riding stand-in for a compiled segment (``worker_plane=
    "dry"``): fetches boundary inputs, advances sink counters, publishes
    zero batches — the full distributed machinery without jit compiles.
    Useful for scheduler/transport studies and fast CI sweeps."""

    def __init__(self, spec: SegmentSpec, dataflow: Dataflow):
        import numpy as np

        self.spec = spec
        self.np = np
        self.sink_ids = [t for t in spec.task_ids if dataflow.tasks[t].is_sink]
        self.active = {t: True for t in spec.task_ids}
        self.states: Dict[str, Any] = {
            t: ({"count": 0, "checksum": 0.0} if t in self.sink_ids else ())
            for t in spec.task_ids
        }
        in_segment = set(spec.task_ids)
        self.boundary_topics = []
        for tid in spec.task_ids:
            for p in spec.parents[tid]:
                topic = topic_for(p)
                if p not in in_segment and topic not in self.boundary_topics:
                    self.boundary_topics.append(topic)

    def load_states(self, states: Dict[str, Any]) -> None:
        for tid, value in states.items():
            if tid in self.sink_ids and isinstance(value, dict):
                self.states[tid] = {"count": int(value.get("count", 0)), "checksum": 0.0}

    def pause(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = False

    def resume(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = True

    def step(self, transport: Transport, forward: List[str],
             targets: Optional[Dict[str, int]],
             local: Optional[Dict[str, Any]] = None) -> None:
        for topic in self.boundary_topics:
            if local is not None and topic in local:
                continue  # produced earlier in this worker's chain
            if targets and topic in targets:
                transport.fetch_synced(topic, targets[topic])
            else:
                try:
                    transport.fetch(topic)
                except KeyError:
                    pass  # producer not restored yet — dry plane tolerates
        for tid in self.sink_ids:
            if self.active[tid]:
                st = self.states[tid]
                self.states[tid] = {"count": st["count"] + 1, "checksum": 0.0}
        np = self.np
        for tid in forward:
            if tid in self.active and tid not in self.sink_ids:
                batch = np.zeros((self.spec.batch_of[tid], 8), np.float32)
                if local is not None:
                    local[topic_for(tid)] = batch
                transport.publish(topic_for(tid), batch)


class _JitSegmentRunner:
    """Owns one compiled segment inside a worker process."""

    def __init__(self, spec: SegmentSpec, dataflow: Dataflow,
                 init_states: Optional[Dict[str, Any]]):
        from repro.ops import operator_for_task

        from .compile_cache import process_compile_cache
        from .executor import _conform_state  # imports JAX (worker-side only)
        from .segment import build_segment

        if init_states:
            # conform restored/migrated states onto the operator templates —
            # same cross-backend coercion the in-process jit plane applies
            # (dry checkpoints seed sink counts, mismatched leaves re-init)
            init_states = {
                tid: _conform_state(
                    value,
                    operator_for_task(
                        dataflow.tasks[tid], batch=spec.batch_of[tid]
                    ).init_state(spec.batch_of[tid]),
                )
                for tid, value in init_states.items()
            }
        # process-local compiled-segment reuse: structurally identical
        # segments deployed to this worker share one jitted executable
        self.seg = build_segment(
            spec, dataflow, init_states=init_states, cache=process_compile_cache()
        )
        self.spec = spec

    @property
    def boundary_topics(self) -> List[str]:
        return self.seg.boundary_topics

    def pause(self, task_ids: Set[str]) -> None:
        self.seg.pause(task_ids)

    def resume(self, task_ids: Set[str]) -> None:
        self.seg.resume(task_ids)

    @property
    def states(self) -> Dict[str, Any]:
        return self.seg.states

    def step(self, transport: Transport, forward: List[str],
             targets: Optional[Dict[str, int]],
             local: Optional[Dict[str, Any]] = None) -> None:
        import jax
        import numpy as np

        seg = self.seg
        inputs: Dict[str, Any] = {}
        tokens: Dict[str, int] = {}
        # zero-copy hot path: a view-capable transport (shm) hands back
        # read-only views into its ring plus a sequence token per topic.
        # Fused segments donate their pre-step states, so the stale-view
        # recompute below is unavailable to them — they take private
        # copies up front instead.
        fused = bool(getattr(seg.spec, "fused", False))
        views = None if fused else getattr(transport, "fetch_view", None)
        for topic in seg.boundary_topics:
            if local is not None and topic in local:
                # produced earlier in this worker's chain — resolved
                # locally, no transport round-trip
                inputs[topic] = local[topic]
            elif views is not None:
                target = targets.get(topic) if targets else None
                inputs[topic], tokens[topic] = views(topic, min_seq=target)
            elif targets and topic in targets:
                inputs[topic] = transport.fetch_synced(
                    topic, targets[topic], copy=fused
                )
            else:
                inputs[topic] = transport.fetch(topic, copy=fused)
        new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
        if tokens:
            # Stale-view revalidation: on CPU, jax may alias the host views
            # instead of copying them onto a device, so a producer lapping
            # the ring *during* the step could have torn an input. Block
            # until the step has fully consumed its inputs, then check each
            # view's lap token; on staleness recompute from the pre-step
            # states with private copies. Publishes and the state commit
            # happen only after validation — exactly-once either way.
            jax.block_until_ready((new_states, outputs))
            if not all(transport.view_valid(t, s) for t, s in tokens.items()):
                for t in tokens:
                    inputs[t] = transport.fetch(t, copy=True)
                new_states, outputs = seg.step_fn(seg.states, seg.active, inputs)
        seg.states = new_states
        for tid in forward:
            if tid in outputs:
                # host transfer is the publish cost of crossing a process
                # boundary; np.asarray also blocks on the value
                batch = np.asarray(outputs[tid])
                if local is not None:
                    local[topic_for(tid)] = batch
                transport.publish(topic_for(tid), batch)
        # block on the whole segment so the measured ms is compute, not
        # async dispatch (same rationale as the in-process jit backend)
        jax.block_until_ready(new_states)
        seg.steps_run += 1


def _decode_spec(rec: Dict[str, Any]) -> SegmentSpec:
    return SegmentSpec(
        name=rec["name"],
        dag_name=rec["dag_name"],
        task_ids=list(rec["task_ids"]),
        parents={t: list(ps) for t, ps in rec["parents"].items()},
        publish=set(rec["publish"]),
        batch_of={t: int(b) for t, b in rec["batch_of"].items()},
        created_at=int(rec.get("created_at", 0)),
        fused=bool(rec.get("fused", False)),
    )


def _dataflow_from_tasks(dag_name: str, tasks: Dict[str, Dict[str, Any]]) -> Dataflow:
    df = Dataflow(dag_name)
    for tid, t in tasks.items():
        df.add_task(Task.make(tid, t["type"], t["config"]))
    return df


def _encode_states(runner: Any) -> Dict[str, Any]:
    """Encode a runner's post-step task states for the reply wire.

    These are the coordinator's *shadow snapshots*: committed atomically
    with the step reply, so a worker that dies mid-step leaves the shadow
    at the pre-step states and a deterministic re-step after respawn
    reproduces the uninterrupted trajectory exactly once."""
    return {tid: encode_pytree(runner.states[tid]) for tid in runner.spec.task_ids}


def _host_tree(x: Any) -> Any:
    """Device arrays -> host numpy, containers preserved — the cheap
    (no base64, no JSON tagging) state capture for spill snapshots."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        return {k: _host_tree(v) for k, v in x.items()}
    if isinstance(x, tuple):
        return tuple(_host_tree(v) for v in x)
    if isinstance(x, list):
        return [_host_tree(v) for v in x]
    import numpy as np

    return np.asarray(x)


def _spill_slots(path: str) -> Tuple[str, str]:
    """The two alternating slot files behind one logical spill path."""
    return f"{path}.a", f"{path}.b"


def _capture_states(runner: Any, ephemeral: Dict[str, tuple]) -> Dict[str, Any]:
    """Host-side copy of a segment's post-step states, minus ephemeral
    leaves (``repro.ops.costs.ephemeral_state_keys``: keys every step
    overwrites wholesale, like a sink's retained batch — dropping them
    keeps the per-step spill tiny and recovery re-inits them from the
    operator template)."""
    out: Dict[str, Any] = {}
    for tid in runner.spec.task_ids:
        state = runner.states[tid]
        drop = ephemeral.get(tid)
        if drop and isinstance(state, dict):
            state = {k: v for k, v in state.items() if k not in drop}
        out[tid] = _host_tree(state)
    return out


class _SpillWriter:
    """Double-buffered combined spill writer: persists the post-step
    states of EVERY spill-armed segment a worker owns to one worker-local
    file, written once per step batch BEFORE the step reply is sent.

    Each entry carries a completed-step counter — what makes recovery
    exactly-once without per-step wire snapshots: a worker that dies
    *before* the write leaves the freshest entry one step behind the
    in-flight step (re-step it), one that dies *after* the write but
    before the reply leaves it one step ahead of what the coordinator
    confirmed (skip the re-step — the outputs were already published).
    One write per wave batch instead of one per segment matters because
    the cost is dominated by fixed per-write work, not payload bytes
    (ephemeral-filtered states are a few hundred bytes per segment).

    Two slot files are held open for the writer's lifetime and written
    alternately (seek/truncate/dump/flush), so the steady state pays no
    open/rename syscalls. A crash can tear at most the slot being
    written; the other slot is intact one write behind, and a torn pickle
    stream never loads (the STOP opcode is its last byte), so the
    coordinator-side reader merges both slots taking each segment's
    highest-step entry."""

    def __init__(self, path: str):
        self._writes = 0
        self._files = []
        for p in _spill_slots(path):
            # r+b, not wb: a respawned worker must not blank the slots the
            # coordinator may still need for a subsequent recovery
            self._files.append(open(p, "r+b" if os.path.exists(p) else "w+b"))

    def write(self, entries: Dict[str, Dict[str, Any]]) -> None:
        f = self._files[self._writes % 2]
        self._writes += 1
        f.seek(0)
        f.truncate()
        pickle.dump({"segments": entries}, f,
                    protocol=pickle.HIGHEST_PROTOCOL)
        f.flush()

    def close(self) -> None:
        for f in self._files:
            try:
                f.close()
            except OSError:  # pragma: no cover - close is best-effort
                pass


def _worker_main(conn, worker_id: int, transport_spec: Dict[str, Any],
                 plane: str, log_path: str) -> None:
    """The worker loop: blocking command RPCs against owned segments."""
    log = _WorkerLog(log_path, worker_id)
    log.write("start", pid=os.getpid(), plane=plane,
              transport=transport_spec.get("kind"))
    transport = connect_transport(transport_spec)
    # telemetry plane: the per-process registry/tracer the coordinator
    # pulls over the "metrics" op (tracer stays disabled until an "obs"
    # op arms it — spans are worker-side monotonic, so they line up with
    # coordinator spans in one merged Chrome trace)
    tracer = process_tracer()
    wm = process_metrics()
    w_seg_ms = wm.histogram(
        "repro_worker_segment_step_ms",
        "worker-measured per-segment step time (ms)",
    )
    w_steps = wm.counter(
        "repro_worker_segment_steps_total",
        "segment steps executed inside worker processes",
    )

    def _timed_step(name: str, runner: Any, forward: List[str],
                    targets: Optional[Dict[str, int]],
                    local: Optional[Dict[str, Any]] = None) -> float:
        t0 = time.perf_counter()
        if tracer.enabled:
            with tracer.span(name, "segment", worker=worker_id):
                runner.step(transport, forward, targets, local=local)
        else:
            runner.step(transport, forward, targets, local=local)
        ms = (time.perf_counter() - t0) * 1e3
        w_seg_ms.observe(ms)
        w_steps.inc()
        return ms

    segments: Dict[str, Any] = {}
    spill_writer: Optional[_SpillWriter] = None  # one combined file per worker
    spill_entries: Dict[str, Dict[str, Any]] = {}  # segment -> {step, states}
    spill_step: Dict[str, int] = {}  # segment -> completed-step counter
    spill_ephem: Dict[str, Dict[str, tuple]] = {}  # segment -> tid -> keys
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            log.write("coordinator-gone")
            break
        op = msg.get("op")
        try:
            reply: Dict[str, Any] = {"ok": True}
            if op == "deploy":
                spec = _decode_spec(msg["spec"])
                df = _dataflow_from_tasks(spec.dag_name, msg["tasks"])
                init = (
                    {t: decode_pytree(enc) for t, enc in msg["states"].items()}
                    if msg.get("states")
                    else None
                )
                if plane == "jit":
                    segments[spec.name] = _JitSegmentRunner(spec, df, init)
                else:
                    runner = _DrySegmentLite(spec, df)
                    if init:
                        runner.load_states(init)
                    segments[spec.name] = runner
                spill_entries.pop(spec.name, None)  # redeploy resets history
                if msg.get("spill"):
                    from repro.ops.costs import ephemeral_state_keys

                    spill_ephem[spec.name] = {
                        tid: keys
                        for tid in spec.task_ids
                        if (keys := ephemeral_state_keys(df.tasks[tid]))
                    }
                    spill_step[spec.name] = int(msg.get("step0", 0))
                    if spill_writer is None:
                        spill_writer = _SpillWriter(msg["spill"])
                else:
                    spill_step.pop(spec.name, None)
                    spill_ephem.pop(spec.name, None)
                log.write("deploy", segment=spec.name, tasks=len(spec.task_ids))
            elif op == "kill":
                runner = segments.pop(msg["segment"])
                for tid in runner.spec.task_ids:
                    transport.drop(topic_for(tid))
                spill_entries.pop(msg["segment"], None)
                spill_step.pop(msg["segment"], None)
                spill_ephem.pop(msg["segment"], None)
                log.write("kill", segment=msg["segment"])
            elif op == "step":
                name = msg["segment"]
                runner = segments[name]
                reply["ms"] = _timed_step(
                    name, runner, msg["forward"], msg.get("targets")
                )
                if name in spill_step:
                    spill_step[name] += 1
                    t1 = time.perf_counter()
                    spill_entries[name] = {
                        "step": spill_step[name],
                        "states": _capture_states(runner, spill_ephem[name]),
                    }
                    spill_writer.write(spill_entries)
                    reply["spill_ms"] = (time.perf_counter() - t1) * 1e3
                if msg.get("snap"):
                    reply["states"] = {name: _encode_states(runner)}
            elif op in ("step_many", "step_chain"):
                # wave-batched dispatch: step every named segment (for
                # "step_many", mutually independent members of one wave, in
                # launch order) under a single command round-trip —
                # per-segment Python dispatch runs inside this process, so
                # coordinator RPC overhead amortizes to one round-trip per
                # worker per wave instead of one per segment.
                #
                # "step_chain" goes further: the entries span *consecutive
                # waves* of one step, in global wave order, so a deep
                # same-worker chain costs one round-trip per worker per
                # STEP. Intra-chain boundary streams are resolved through
                # the ``local`` dict (publisher stores, consumer reads) —
                # no transport hop at all — while cross-worker reads still
                # ride the per-topic sequence targets (a blocked
                # fetch_synced waits on a producer in an earlier wave,
                # which its worker reaches by the same global order, so
                # chains never deadlock).
                local = {} if op == "step_chain" else None
                ms: Dict[str, float] = {}
                snaps: Dict[str, Dict[str, Any]] = {}
                spill_ms = 0.0
                spilled = False
                for entry in msg["segments"]:
                    name = entry["segment"]
                    runner = segments[name]
                    ms[name] = _timed_step(
                        name, runner, entry["forward"],
                        entry.get("targets"), local=local,
                    )
                    if name in spill_step:
                        spill_step[name] += 1
                        t1 = time.perf_counter()
                        spill_entries[name] = {
                            "step": spill_step[name],
                            "states": _capture_states(
                                runner, spill_ephem[name]
                            ),
                        }
                        spill_ms += (time.perf_counter() - t1) * 1e3
                        spilled = True
                    if msg.get("snap"):
                        snaps[name] = _encode_states(runner)
                if spilled:
                    # one combined durable write per batch: fixed per-write
                    # cost amortizes across every segment in the wave
                    t1 = time.perf_counter()
                    spill_writer.write(spill_entries)
                    spill_ms += (time.perf_counter() - t1) * 1e3
                reply["ms"] = ms
                if spill_ms:
                    reply["spill_ms"] = spill_ms
                if msg.get("snap"):
                    reply["states"] = snaps
            elif op == "pause":
                segments[msg["segment"]].pause(set(msg["tasks"]))
            elif op == "resume":
                segments[msg["segment"]].resume(set(msg["tasks"]))
            elif op == "states":
                runner = segments[msg["segment"]]
                reply["states"] = {
                    tid: encode_pytree(runner.states[tid])
                    for tid in runner.spec.task_ids
                }
            elif op == "ping":
                reply["pid"] = os.getpid()
            elif op == "cache_stats":
                if plane == "jit":
                    from .compile_cache import process_compile_cache

                    reply["stats"] = process_compile_cache().stats()
                else:  # dry plane never compiles
                    reply["stats"] = {
                        "hits": 0, "misses": 0, "evictions": 0, "entries": 0,
                    }
            elif op == "metrics":
                # telemetry pull (same aggregation pattern as cache_stats):
                # the registry snapshot is cumulative and idempotent, the
                # span buffer drains destructively — the coordinator
                # buffers drained spans until its own drain_spans()
                reply["metrics"] = wm.snapshot()
                reply["spans"] = tracer.drain()
            elif op == "obs":
                tracer.configure(
                    enabled=msg.get("trace"),
                    sample_stride=msg.get("sample_stride"),
                    capacity=msg.get("capacity"),
                )
            elif op == "shutdown":
                log.write("shutdown")
            else:
                raise ValueError(f"unknown worker op {op!r}")
        except BaseException as e:  # noqa: BLE001 - reported to coordinator
            log.write("error", op=op, error=repr(e))
            log._f.write(traceback.format_exc())
            reply = {"error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()}
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            break
        if op == "shutdown":
            break
    try:
        transport.close()
    except Exception:  # pragma: no cover - shutdown best-effort
        pass
    log.close()


# -- the coordinator backend ------------------------------------------------------


class WorkerError(RuntimeError):
    """A worker failed. ``worker``/``gen`` identify the process incarnation
    when the failure was fatal to it (pipe EOF, hang timeout) — the cluster
    plane's recovery hook uses them to respawn exactly that incarnation.
    Application-level errors reported by a *live* worker leave them ``None``
    (respawning would not fix a logic error)."""

    def __init__(self, message: str, worker: Optional[int] = None,
                 gen: Optional[int] = None):
        super().__init__(message)
        self.worker = worker
        self.gen = gen


@dataclass
class RemoteSegment:
    """Parent-side proxy of a segment deployed inside a worker process.

    Carries everything the shared accounting needs (spec, per-task cost
    weights, active flags as plain bools); task states are fetched from
    the worker on demand (checkpoint dumps, defrag carry-over) and cached
    per step."""

    spec: SegmentSpec
    backend: "MultiprocBackend"
    cost_of: Dict[str, float]
    active: Dict[str, bool]
    steps_run: int = 0
    # recovery found the segment's spill one step AHEAD of what the
    # coordinator confirmed (worker died after publish+spill but before
    # the reply): that many re-dispatches are no-ops, not re-steps
    _skip_steps: int = 0
    _states_cache: Optional[Dict[str, Any]] = field(default=None, repr=False)
    _states_step: int = -1

    @property
    def name(self) -> str:
        return self.spec.name

    def live_task_ids(self) -> List[str]:
        return [t for t in self.spec.task_ids if self.active[t]]

    def pause(self, task_ids: Set[str]) -> None:
        hit = [t for t in task_ids if t in self.active]
        if not hit:
            return
        for tid in hit:
            self.active[tid] = False
        self.backend._segment_call(self, {"op": "pause", "tasks": hit})
        self._states_cache = None

    def resume(self, task_ids: Set[str]) -> None:
        hit = [t for t in task_ids if t in self.active]
        if not hit:
            return
        for tid in hit:
            self.active[tid] = True
        self.backend._segment_call(self, {"op": "resume", "tasks": hit})
        self._states_cache = None

    @property
    def states(self) -> Dict[str, Any]:
        """Decoded task states, pulled from the worker (cached per step)."""
        step = self.backend.step_count
        if self._states_cache is None or self._states_step != step:
            reply = self.backend._segment_call(self, {"op": "states"})
            self._states_cache = {
                tid: decode_pytree(enc) for tid, enc in reply["states"].items()
            }
            self._states_step = step
        return self._states_cache


class MultiprocBackend(PlacedBackendMixin, ExecutionBackend):
    """Worker-process data plane behind the ExecutionBackend protocol.

    The coordinator (this class) is JAX-free; each of ``workers`` spawned
    processes compiles and steps its segments with the same jit machinery
    as the in-process backend (``worker_plane="jit"``) or a lightweight
    transport-riding cost plane (``"dry"``). Boundary streams cross
    processes on a :class:`~repro.runtime.transport.Transport` that must
    support multi-process attachment — ``"shm"`` (default) or ``"tcp"``;
    the in-process broker is rejected with a clear error.

    Stepping composes with both pipeline modes: ``sync`` issues one
    blocking RPC per segment in launch order; ``concurrent`` lets the
    wave/ready-queue scheduler issue RPCs from its thread pool, where
    ``conn.recv`` releases the GIL — independent segments on different
    workers execute simultaneously, which is what lifts the threaded
    dispatch's GIL cap.
    """

    name = "multiproc"

    def __init__(
        self,
        workers: int = 2,
        transport: Any = "shm",
        transport_options: Optional[Dict[str, Any]] = None,
        placement: Union[str, PlacementPolicy] = "round_robin",
        worker_plane: str = "jit",
        log_dir: Optional[str] = None,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        ewma_decay: float = 0.6,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
        launcher: Any = "local",
        rpc_timeout: Optional[float] = None,
        chain_batching: bool = True,
    ):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if worker_plane not in WORKER_PLANES:
            raise ValueError(
                f"worker_plane must be one of {WORKER_PLANES}, got {worker_plane!r}"
            )
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            # the dispatch pool must cover every worker or RPC overlap dies
            max_workers=max_workers if max_workers is not None else max(workers, 2),
        )
        from repro.cluster.launcher import resolve_launcher

        self.n_workers = workers
        self.worker_plane = worker_plane
        self.transport: Transport = resolve_transport(
            transport, **(transport_options or {})
        )
        # fail fast: the transport must be attachable from worker processes
        self._transport_spec = self.transport.connect_info()
        self.log_dir = (
            log_dir
            or os.environ.get("REPRO_WORKER_LOG_DIR")
            or tempfile.mkdtemp(prefix="repro-workers-")
        )
        os.makedirs(self.log_dir, exist_ok=True)
        self._init_placement(placement, ewma_decay=ewma_decay)
        self.launcher = resolve_launcher(launcher)
        self._procs: List[Any] = []  # WorkerHandles, indexed by worker slot
        # RLock, not Lock: recovery respawns a worker while holding its
        # conn lock and then redeploys through _call on the same thread
        self._conn_locks: List[threading.RLock] = []
        self._gen: List[int] = []  # incarnation counter per slot
        self._topic_target: Optional[Dict[str, int]] = None
        # Worker-local dependency batching (concurrent mode): flatten each
        # step's waves into one per-worker chain shipped as a single
        # "step_chain" RPC — one round-trip per worker per step, not per
        # wave, with intra-chain boundary streams resolved inside the
        # worker. Disabled automatically while rpc_timeout is armed: the
        # hang bound is calibrated for per-wave replies, and a chain reply
        # legitimately takes a whole step.
        self.chain_batching = bool(chain_batching)
        self._spawned = False
        # -- cluster plane state (driven by repro.cluster) --------------------
        self.rpc_timeout = rpc_timeout  # hang bound on RPC replies (None = wait)
        self.self_heal = False  # supervisor attach flips this on
        self.shadow_states = False  # piggyback post-step states on replies
        self.snapshot_every = 1  # shadow refresh cadence (steps)
        # "spill": workers persist post-step states to worker-local files
        # (cheap: pickle, no wire traffic); "wire": states ride step replies
        # (works for launchers whose workers share no filesystem)
        self.snapshot_mode = "wire"
        self._spill_ewma: Optional[float] = None  # worker-reported spill ms/step
        self._spill_dir: Optional[str] = None
        self._shadow: Dict[str, Dict[str, Any]] = {}  # segment -> encoded states
        self._recover_lock = threading.Lock()
        self.respawns: List[Dict[str, Any]] = []
        # -- telemetry plane (repro.obs) --------------------------------------
        self._worker_spans: List[Dict[str, Any]] = []  # harvested, undrained
        self._obs_msg: Optional[Dict[str, Any]] = None  # replayed to (re)spawns
        self._last_ok: Dict[int, float] = {}  # worker -> monotonic of last good RPC
        # worker_health(): a worker whose last good RPC is older than this
        # is marked stale (supervision surfaces it through serving status)
        self.stale_after_ms = 5000.0

    def _mint_instruments(self) -> None:
        super()._mint_instruments()
        self._m_rpcs = self.metrics.counter(
            "repro_worker_rpcs_total",
            "coordinator-to-worker command RPCs completed, by op",
        )
        self._m_respawns = self.metrics.counter(
            "repro_worker_respawns_total",
            "worker processes respawned by crash recovery",
        )

    # -- worker pool ------------------------------------------------------------
    def _spawn_worker(self, worker: int) -> Any:
        log_path = os.path.join(self.log_dir, f"worker-{worker}.log")
        return self.launcher.launch(
            worker, self._transport_spec, self.worker_plane, log_path
        )

    def _ensure_workers(self) -> None:
        if self._spawned:
            return
        self._spawned = True
        for i in range(self.n_workers):
            self._procs.append(self._spawn_worker(i))
            self._conn_locks.append(threading.RLock())
            self._gen.append(0)
        for i in range(self.n_workers):
            self._push_obs(i)

    def _push_obs(self, worker: int) -> None:
        """Replay the armed trace configuration to a (re)spawned worker."""
        if self._obs_msg is None:
            return
        try:
            self._call(worker, self._obs_msg)
        except WorkerError:
            pass  # tracing is best-effort; liveness checks catch real deaths

    def _roundtrip(self, conn: Any, msg: Dict[str, Any], worker: int,
                   gen: int) -> Dict[str, Any]:
        conn.send(msg)
        if self.rpc_timeout is not None and not conn.poll(self.rpc_timeout):
            # hang bound exceeded: the pipe is now out of sync, so
            # this incarnation is unusable — recovery is mandatory
            raise WorkerError(
                f"worker {worker} hung on {msg.get('op')!r} "
                f"(> {self.rpc_timeout}s)", worker=worker, gen=gen,
            )
        return conn.recv()

    def _call(self, worker: int, msg: Dict[str, Any]) -> Dict[str, Any]:
        """One blocking RPC to a worker; serialized per worker, overlapping
        across workers (recv releases the GIL)."""
        self._ensure_workers()
        gen = self._gen[worker]
        op = msg.get("op")
        with self._conn_locks[worker]:
            conn = self._procs[worker].conn
            try:
                if self.tracer.enabled:
                    with self.tracer.span(f"rpc:{op}", "rpc", worker=worker):
                        reply = self._roundtrip(conn, msg, worker, gen)
                else:
                    reply = self._roundtrip(conn, msg, worker, gen)
            except (EOFError, BrokenPipeError, OSError) as e:
                raise WorkerError(
                    f"worker {worker} died during {msg.get('op')!r} "
                    f"(log: {os.path.join(self.log_dir, f'worker-{worker}.log')})",
                    worker=worker, gen=gen,
                ) from e
        # a reply arrived — even an application error means the worker is
        # alive, so the health staleness clock resets here
        self._m_rpcs.inc(op=str(op))
        self._last_ok[worker] = time.monotonic()
        if "error" in reply:
            raise WorkerError(
                f"worker {worker} failed {msg.get('op')!r}: {reply['error']}\n"
                f"{reply.get('traceback', '')}"
            )
        return reply

    def worker_alive(self, worker: int) -> bool:
        """Cheap liveness: the launched process still exists (no pipe I/O)."""
        if not self._spawned or worker >= len(self._procs):
            return False
        return self._procs[worker].is_alive()

    def ping_worker(self, worker: int, timeout: float = 5.0) -> bool:
        """Active liveness probe: a ``ping`` RPC bounded by ``timeout``.

        A ``False`` from a timeout poisons the command pipe (a late reply
        would desync framing), so callers must treat it as fatal and
        recover the worker — the supervisor does."""
        self._ensure_workers()
        with self._conn_locks[worker]:
            conn = self._procs[worker].conn
            try:
                conn.send({"op": "ping"})
                if not conn.poll(timeout):
                    return False
                reply = conn.recv()
            except (EOFError, BrokenPipeError, OSError):
                return False
        return "pid" in reply

    def _segment_call(self, seg: RemoteSegment, msg: Dict[str, Any]) -> Dict[str, Any]:
        msg = dict(msg)
        msg["segment"] = seg.spec.name
        return self._call(self.device_of[seg.spec.name], msg)

    # -- placement hooks (PlacedBackendMixin) -----------------------------------
    def _n_slots(self) -> int:
        return self.n_workers

    def _move_segment(self, seg: RemoteSegment, old: int, new: int) -> None:
        """Migrate a straggling segment to another worker: pull its encoded
        states, kill it on the old worker, redeploy on the new one."""
        reply = self._call(old, {"op": "states", "segment": seg.spec.name})
        self._call(old, {"op": "kill", "segment": seg.spec.name})
        self.device_of[seg.spec.name] = new  # before deploy RPC below
        self._deploy_rpc(new, seg.spec, states=reply["states"],
                         step0=seg.steps_run)
        self._reapply_pauses(new, seg)
        seg._states_cache = None

    # -- cluster plane: recovery and elasticity -----------------------------------
    def _spill_file(self, worker: int) -> str:
        if self._spill_dir is None:
            # prefer tmpfs: spill writes sit on every step's critical path,
            # and /tmp is often disk-backed (~7x slower per write)
            base = "/dev/shm" if os.path.isdir("/dev/shm") else None
            self._spill_dir = tempfile.mkdtemp(prefix="repro-spill-", dir=base)
        return os.path.join(self._spill_dir, f"worker-{worker}.pkl")

    def _read_spill(self, worker: int) -> Dict[str, Dict[str, Any]]:
        """Per-segment spill entries of one worker's combined file.

        Both alternating slots are read (a crash tears at most the slot
        being written) and merged per segment, highest step wins. Entries
        can be stale — a segment that migrated here and died before its
        first step leaves an old incarnation's entry — so callers must
        check the step counter against the coordinator's count."""
        merged: Dict[str, Dict[str, Any]] = {}
        if self._spill_dir is None:
            return merged
        for slot in _spill_slots(self._spill_file(worker)):
            try:
                with open(slot, "rb") as f:
                    payload = pickle.load(f)
            except (OSError, EOFError, pickle.UnpicklingError):
                continue  # slot never written, or torn by the crash
            for name, entry in payload.get("segments", {}).items():
                cur = merged.get(name)
                if cur is None or int(entry["step"]) > int(cur["step"]):
                    merged[name] = entry
        return merged

    def _recovery_states(self, seg: RemoteSegment,
                         spilled: Dict[str, Dict[str, Any]]):
        """Freshest redeploy states for a dead worker's segment.

        Returns ``(encoded_states, step0, skip)``. In spill mode the
        worker-local entry carries a completed-step counter: equal to the
        coordinator's count means the state is current (any in-flight step
        simply re-runs); one ahead means the lost step actually completed
        (outputs published, spill written, reply lost) — redeploy the
        advanced state and *skip* the re-dispatch. A counter outside that
        range is a stale entry from before a migration: fall back to the
        shadow snapshot, which the deploy RPC keeps at deploy-time states
        (always pre-step at death)."""
        entry = spilled.get(seg.spec.name)
        if entry is not None:
            k = int(entry["step"])
            if k in (seg.steps_run, seg.steps_run + 1):
                states = {
                    tid: encode_pytree(v)
                    for tid, v in entry["states"].items()
                }
                return states, k, k == seg.steps_run + 1
        return self._shadow.get(seg.spec.name), seg.steps_run, False

    def _reapply_pauses(self, worker: int, seg: RemoteSegment) -> None:
        paused = [t for t in seg.spec.task_ids if not seg.active[t]]
        if paused:
            self._call(worker, {"op": "pause", "segment": seg.spec.name,
                                "tasks": paused})

    def recover_worker(self, worker: int, expect_gen: Optional[int] = None) -> Dict[str, Any]:
        """Respawn a dead/hung worker in place and redeploy its segments.

        States come from the freshest source available — the worker-local
        spill file (``snapshot_mode="spill"``) or the shadow snapshot
        committed with the segment's last step reply (``"wire"``), falling
        back to deploy-time states; all encoded, so no JAX is touched in
        the coordinator (see :meth:`_recovery_states` for the exactly-once
        step accounting). ``expect_gen`` makes recovery idempotent under
        races: a heartbeat thread and a stepping thread that both observe
        the same death recover it exactly once (the second caller sees the
        bumped generation and returns without respawning)."""
        with self._recover_lock:
            if expect_gen is not None and self._gen[worker] != expect_gen:
                return {"worker": worker, "segments": [], "ms": 0.0,
                        "already_recovered": True}
            t0 = time.perf_counter()
            self._emit_worker_event(WORKER_DEAD, worker=worker,
                                    detail=f"gen={self._gen[worker]}")
            with self._conn_locks[worker]:
                old = self._procs[worker]
                try:
                    old.terminate()
                except Exception:
                    pass
                old.join(timeout=5)
                old.close()
                self._procs[worker] = self._spawn_worker(worker)
                self._gen[worker] += 1
                self._m_respawns.inc()
                self._emit_worker_event(WORKER_RESPAWNED, worker=worker,
                                        detail=f"gen={self._gen[worker]}")
                self._push_obs(worker)
                redeployed: List[str] = []
                spilled = (
                    self._read_spill(worker)
                    if self.snapshot_mode == "spill" else {}
                )
                for name in sorted(
                    n for n, w in self.device_of.items() if w == worker
                ):
                    seg = self.segments.get(name)
                    if seg is None:
                        continue
                    states, step0, skip = self._recovery_states(seg, spilled)
                    self._deploy_rpc(worker, seg.spec, states=states,
                                     step0=step0)
                    if skip:
                        seg._skip_steps += 1
                    self._reapply_pauses(worker, seg)
                    seg._states_cache = None
                    redeployed.append(name)
            ms = (time.perf_counter() - t0) * 1e3
            self._emit_worker_event(
                SEGMENT_REDEPLOYED, worker=worker, ms=ms,
                detail=f"{len(redeployed)} segment(s): {', '.join(redeployed)}",
            )
            record = {"worker": worker, "segments": redeployed, "ms": ms,
                      "step": self.step_count}
            self.respawns.append(record)
            return record

    def _step_recover(self, name: str, exc: BaseException) -> bool:
        """Self-healing hook for the stepping paths: recover the dead
        worker so the failed item can be re-dispatched instead of erroring
        the whole step. Only fatal worker failures qualify, and only once
        the supervisor has armed ``self_heal``."""
        if not self.self_heal or not isinstance(exc, WorkerError):
            return False
        if exc.worker is None or exc.worker >= self.n_workers:
            return False
        self.recover_worker(exc.worker, expect_gen=exc.gen)
        return True

    def resize_pool(self, n: int) -> None:
        """Grow or shrink the worker pool without stopping the system.

        Growing spawns fresh workers (new segments land there via the
        placement policy; straggler migration rebalances existing ones).
        Shrinking migrates every segment off the retiring workers to the
        least-pressured survivors, then shuts the retirees down."""
        if n < 1:
            raise ValueError(f"worker pool size must be >= 1, got {n}")
        self._ensure_workers()
        if n == self.n_workers:
            return
        t0 = time.perf_counter()
        if n > self.n_workers:
            for i in range(self.n_workers, n):
                self._procs.append(self._spawn_worker(i))
                self._conn_locks.append(threading.RLock())
                self._gen.append(0)
                self._push_obs(i)
            grown = n - self.n_workers
            self.n_workers = n
            self._emit_worker_event(
                POOL_GROWN, ms=(time.perf_counter() - t0) * 1e3,
                detail=f"+{grown} -> {n} workers",
            )
        else:
            ewma = self.device_ewma()
            load: Dict[int, int] = {i: 0 for i in range(n)}
            for name, w in self.device_of.items():
                if w < n:
                    load[w] += len(self.segments[name].spec.task_ids)
            moved = 0
            for name, w in sorted(self.device_of.items()):
                if w < n:
                    continue
                target = min(range(n),
                             key=lambda i: (ewma.get(i, 0.0), load[i], i))
                seg = self.segments[name]
                self._move_segment(seg, w, target)
                load[target] += len(seg.spec.task_ids)
                moved += 1
            for i in reversed(range(n, self.n_workers)):
                handle = self._procs.pop(i)
                try:
                    with self._conn_locks[i]:
                        handle.conn.send({"op": "shutdown"})
                        handle.conn.recv()
                except (EOFError, BrokenPipeError, OSError):
                    pass
                handle.close()
                handle.join(timeout=5)
                if handle.is_alive():  # pragma: no cover - stuck worker
                    handle.terminate()
                self._conn_locks.pop(i)
                self._gen.pop(i)
                self._ewma_residual.pop(i, None)
            shrunk = self.n_workers - n
            self.n_workers = n
            self._emit_worker_event(
                POOL_SHRUNK, ms=(time.perf_counter() - t0) * 1e3,
                detail=f"-{shrunk} -> {n} workers ({moved} segments migrated)",
            )
        # the dispatch pool must keep covering every worker
        self._reset_pool()
        self.max_workers = max(self.n_workers, 2)

    def worker_health(self) -> Dict[str, Any]:
        """Cluster-plane health snapshot (serving surfaces this verbatim).

        ``last_ok_monotonic`` records each worker's most recent good RPC
        reply on the coordinator's monotonic clock (``now_monotonic`` is
        the same clock at snapshot time, so readers compute ages without
        wall-clock skew); ``stale`` marks workers whose last reply is
        older than ``stale_after_ms`` — ``None`` for a worker never yet
        called (no RPC issued, nothing to age)."""
        per_worker: Dict[int, int] = {i: 0 for i in range(self.n_workers)}
        for name, w in self.device_of.items():
            if name in self.segments and w in per_worker:
                per_worker[w] += 1
        now = time.monotonic()
        stale: Dict[str, Optional[bool]] = {}
        for i in range(self.n_workers):
            t = self._last_ok.get(i)
            stale[str(i)] = (
                None if t is None else (now - t) * 1e3 > self.stale_after_ms
            )
        return {
            "now_monotonic": now,
            "last_ok_monotonic": {
                str(i): self._last_ok.get(i) for i in range(self.n_workers)
            },
            "stale_after_ms": self.stale_after_ms,
            "stale": stale,
            "backend": self.name,
            "workers": self.n_workers,
            "alive": [h.is_alive() for h in self._procs],
            "generations": list(self._gen),
            "respawns": len(self.respawns),
            "segments_per_worker": {str(i): c for i, c in per_worker.items()},
            "supervised": self.self_heal,
            "snapshot_mode": self.snapshot_mode if (
                self.shadow_states or self._spill_dir is not None
            ) else None,
            "spill_ms_per_step": (
                round(self._spill_ewma, 4) if self._spill_ewma is not None else None
            ),
            "events": [e.to_dict() for e in self.worker_events[-20:]],
        }

    # -- ExecutionBackend hooks -------------------------------------------------
    def _encode_spec(self, spec: SegmentSpec) -> Dict[str, Any]:
        return {
            "name": spec.name,
            "dag_name": spec.dag_name,
            "task_ids": list(spec.task_ids),
            "parents": {t: list(ps) for t, ps in spec.parents.items()},
            "publish": sorted(spec.publish),
            "batch_of": {t: int(b) for t, b in spec.batch_of.items()},
            "created_at": int(spec.created_at),
            "fused": bool(spec.fused),
        }

    def _deploy_rpc(self, worker: int, spec: SegmentSpec,
                    states: Optional[Dict[str, Any]] = None,
                    step0: int = 0) -> None:
        msg = {
            "op": "deploy",
            "spec": self._encode_spec(spec),
            "tasks": {
                tid: {"type": self.task_defs[tid].type,
                      "config": self.task_defs[tid].config}
                for tid in spec.task_ids
            },
            "states": states,
        }
        if self.snapshot_mode == "spill":
            msg["spill"] = self._spill_file(worker)
            msg["step0"] = int(step0)
        self._call(worker, msg)
        if states is not None:
            self._shadow[spec.name] = states

    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, PyTree]],
    ) -> RemoteSegment:
        seg = RemoteSegment(
            spec=spec,
            backend=self,
            cost_of={
                tid: cost_weight_for_task(dataflow.tasks[tid])
                for tid in spec.task_ids
            },
            active={tid: True for tid in spec.task_ids},
        )
        # deploy() records task_defs after _build returns; the RPC needs
        # them now, so register this segment's defs up front
        for tid in spec.task_ids:
            self.task_defs[tid] = dataflow.tasks[tid]
        worker = self._assign_slot(spec)
        self._deploy_rpc(
            worker,
            spec,
            states=(
                {tid: encode_pytree(v) for tid, v in init_states.items()}
                if init_states
                else None
            ),
        )
        return seg

    def _drop_streams(self, seg: RemoteSegment) -> None:
        """Kill the remote segment — the worker drops its topics on the
        shared transport (waking any in-flight synced fetches)."""
        worker = self.device_of.get(seg.spec.name)
        if worker is not None:
            self._call(worker, {"op": "kill", "segment": seg.spec.name})
        self._shadow.pop(seg.spec.name, None)
        # no spill cleanup: the worker prunes the segment's entry from its
        # combined file on the next write, and a lingering entry is inert
        # (recovery only consults segments still assigned to the worker)

    def _begin_concurrent_step(self) -> None:
        # same per-topic sequencing scheme as the in-process jit backend:
        # each forwarding task publishes exactly once per step, so this
        # step's boundary reads must observe seq+1 on their producer.
        # One sequences() snapshot instead of a seq() call per topic —
        # on the tcp transport each seq() is a socket round-trip.
        seqs = self.transport.sequences()
        self._topic_target = {
            topic_for(tid): seqs.get(topic_for(tid), 0) + 1
            for name, tids in self.forwarding.items()
            if name in self.segments
            for tid in tids
        }

    def _end_concurrent_step(self) -> None:
        self._topic_target = None

    def _step_entry(self, seg: RemoteSegment) -> Dict[str, Any]:
        targets = None
        if self._topic_target is not None:
            targets = {
                t: s for t, s in self._topic_target.items()
                if t in self._boundary_topics(seg)
            }
        return {
            "segment": seg.spec.name,
            "forward": sorted(self.forwarding[seg.spec.name]),
            "targets": targets,
        }

    def _snap_now(self) -> bool:
        return self.shadow_states and self.step_count % max(self.snapshot_every, 1) == 0

    def _harvest_snaps(self, reply: Dict[str, Any]) -> None:
        for name, states in (reply.get("states") or {}).items():
            self._shadow[name] = states
        if "spill_ms" in reply:
            # worker-measured durability cost of this batch's spill writes —
            # EWMA'd so worker_health can report supervision overhead live
            prev = self._spill_ewma
            val = float(reply["spill_ms"])
            self._spill_ewma = val if prev is None else 0.8 * prev + 0.2 * val

    def _consume_skip(self, seg: RemoteSegment) -> bool:
        """Recovery determined this step already completed inside the dead
        worker (outputs published, spill written): count it done."""
        if seg._skip_steps <= 0:
            return False
        seg._skip_steps -= 1
        seg.steps_run += 1
        seg._states_cache = None
        return True

    def _step_one(self, seg: RemoteSegment) -> Optional[float]:
        if self._consume_skip(seg):
            return 0.0
        # bounded retry: a fatal worker failure mid-step triggers in-place
        # recovery (redeploy from spill/shadow snapshots) and ONE
        # re-dispatch per attempt — deterministic re-steps keep sink
        # counts exact
        for attempt in range(3):
            try:
                reply = self._call(
                    self.device_of[seg.spec.name],
                    {"op": "step", "snap": self._snap_now(),
                     **self._step_entry(seg)},
                )
                break
            except WorkerError as e:
                if attempt == 2 or not self._step_recover(seg.spec.name, e):
                    raise
        self._harvest_snaps(reply)
        seg.steps_run += 1
        seg._states_cache = None
        return float(reply["ms"])  # worker-measured compute, not RPC wait

    def _step_wave_on_worker(
        self, worker: int, names: List[str], op: str = "step_many"
    ) -> Dict[str, float]:
        seg_ms: Dict[str, float] = {}
        todo: List[str] = []
        for n in names:
            if self._consume_skip(self.segments[n]):
                seg_ms[n] = 0.0
            else:
                todo.append(n)
        if not todo:
            return seg_ms
        entries = [self._step_entry(self.segments[n]) for n in todo]
        reply = self._call(
            worker,
            {"op": op, "segments": entries, "snap": self._snap_now()},
        )
        self._harvest_snaps(reply)
        for n in todo:
            seg = self.segments[n]
            seg.steps_run += 1
            seg._states_cache = None
        seg_ms.update({n: float(ms) for n, ms in reply["ms"].items()})
        return seg_ms

    def _use_chains(self) -> bool:
        # step_chain replies arrive once a worker's WHOLE chain is done, so
        # a per-wave-calibrated hang bound would misfire — fall back to
        # per-wave step_many while the supervisor's rpc_timeout is armed.
        return self.chain_batching and self.rpc_timeout is None

    def _worker_chains(self) -> Dict[int, List[str]]:
        """Each step's waves flattened into one per-worker chain, in global
        wave order (see :func:`~repro.runtime.scheduler.compute_chains`)."""
        from .scheduler import compute_chains

        order = {n: s.spec.created_at for n, s in self.segments.items()}
        chains, _ = compute_chains(self.seg_deps, dict(self.device_of), order=order)
        return chains

    def _dispatch_chunks(
        self, by_worker: Dict[int, List[str]], op: str
    ) -> Dict[str, float]:
        """Dispatch one command per worker concurrently, with in-place
        recovery: a dead worker fails its whole chunk at once; with
        self-healing on, recover it and re-dispatch that chunk — the rest
        of the step keeps running meanwhile (deterministic re-steps and
        the spill skip counters keep sink counts exactly-once)."""
        from concurrent.futures import FIRST_COMPLETED, wait

        seg_ms: Dict[str, float] = {}
        futures = {
            self._pool.submit(self._step_wave_on_worker, w, names, op):
            (w, names, 0)
            for w, names in sorted(by_worker.items())
        }
        while futures:
            done, _ = wait(futures, return_when=FIRST_COMPLETED)
            for fut in done:
                w, names, tries = futures.pop(fut)
                try:
                    seg_ms.update(fut.result())
                except WorkerError as e:
                    if tries >= 2 or not self._step_recover(names[0], e):
                        raise
                    futures[self._pool.submit(
                        self._step_wave_on_worker, w, names, op
                    )] = (w, names, tries + 1)
        return seg_ms

    def _step_segments(self) -> Dict[str, float]:
        """Sync-mode stepping, chain-batched when enabled.

        PR 8 left ``step_chain`` concurrent-only; sync mode paid one
        blocking RPC per segment. With ``chain_batching`` on (and no
        ``rpc_timeout`` armed) sync mode now dispatches the same
        one-``step_chain``-per-worker commands, guarded by the same
        per-topic sequence targets — so sink digests are identical to the
        per-segment launch-order sweep. The per-worker chunks must be
        dispatched concurrently even in sync mode: an early entry of one
        worker's chain may wait on another worker's publish, so a serial
        worker-by-worker dispatch could deadlock on the sequence targets.
        Sync semantics are unchanged — the caller still sums (not maxes)
        the per-wave times, and this returns worker-measured compute ms
        per segment exactly like the base sweep.
        """
        if not self._use_chains() or not self.segments:
            return super()._step_segments()
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor

            self._pool = ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="repro-step"
            )
        self._begin_concurrent_step()
        try:
            return self._dispatch_chunks(self._worker_chains(), "step_chain")
        finally:
            self._end_concurrent_step()

    def compile_cache_stats(self) -> Dict[str, int]:
        """Aggregate the workers' process-local compiled-segment caches."""
        total = {"hits": 0, "misses": 0, "evictions": 0, "entries": 0}
        if not self._spawned:
            return total
        for w in range(self.n_workers):
            if not self.worker_alive(w):
                continue
            stats = self._call(w, {"op": "cache_stats"}).get("stats", {})
            for k in total:
                total[k] += int(stats.get(k, 0))
        return total

    # -- telemetry plane ----------------------------------------------------------
    def configure_obs(
        self,
        metrics: Optional[bool] = None,
        trace: Optional[bool] = None,
        sample_stride: Optional[int] = None,
        trace_capacity: Optional[int] = None,
    ) -> "MultiprocBackend":
        super().configure_obs(metrics=metrics, trace=trace,
                              sample_stride=sample_stride,
                              trace_capacity=trace_capacity)
        if trace is not None or sample_stride is not None or trace_capacity is not None:
            # remember the config so every future (re)spawn replays it,
            # then push it to the workers already running
            self._obs_msg = {"op": "obs", "trace": trace,
                             "sample_stride": sample_stride,
                             "capacity": trace_capacity}
            if self._spawned:
                for w in range(self.n_workers):
                    if self.worker_alive(w):
                        self._push_obs(w)
        return self

    def _harvest_worker_obs(self) -> List[Dict[str, Any]]:
        """Pull every live worker's registry snapshot over the ``metrics``
        RPC (same aggregation pattern as :meth:`compile_cache_stats`).
        Worker spans ride the same reply; since the worker-side drain is
        destructive they are buffered here until :meth:`drain_spans`."""
        snaps: List[Dict[str, Any]] = []
        if not self._spawned:
            return snaps
        for w in range(self.n_workers):
            if not self.worker_alive(w):
                continue
            try:
                reply = self._call(w, {"op": "metrics"})
            except WorkerError:
                continue  # a dying worker must never fail a scrape
            if reply.get("metrics"):
                snaps.append(reply["metrics"])
            self._worker_spans.extend(reply.get("spans") or ())
        return snaps

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Coordinator registry merged with the workers' process-local
        registries (counters/histograms add; worker families are
        ``repro_worker_segment_*`` so nothing double-counts)."""
        return merge_snapshots(
            [self.metrics.snapshot(), *self._harvest_worker_obs()]
        )

    def drain_spans(self) -> List[Dict[str, Any]]:
        self._harvest_worker_obs()
        out, self._worker_spans = self._worker_spans, []
        out.extend(self.tracer.drain())
        out.sort(key=lambda s: s.get("ts", 0))
        return out

    def _step_segments_concurrent(self) -> Dict[str, float]:
        """Wave- or chain-batched concurrent dispatch.

        The generic ready-queue issues one RPC per segment; across a pipe
        that round-trip is the dominant cost for small segments. Each
        dependency wave becomes ONE ``step_many`` command per worker
        (segments within a wave are mutually independent, so the worker
        may step its share back-to-back), dispatched to all workers
        concurrently from the thread pool — workers overlap, coordinator
        overhead is waves × workers round-trips per step instead of one
        per segment. Cross-worker boundary reads stay guarded by the
        per-topic sequence targets exactly as in per-segment dispatch.

        With ``chain_batching`` on (and no rpc_timeout armed) the waves
        are flattened further into one ``step_chain`` command per worker
        per STEP: the worker steps its segments in global wave order and
        resolves intra-chain boundary streams locally, so a deep
        same-worker chain pays one round-trip total and zero transport
        hops between its own segments.
        """
        if not self.segments:
            return {}
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor

            self._pool = ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="repro-step"
            )
        self._begin_concurrent_step()
        try:
            if self._use_chains():
                return self._dispatch_chunks(self._worker_chains(), "step_chain")
            seg_ms: Dict[str, float] = {}
            for wave in self.segment_waves():
                by_worker: Dict[int, List[str]] = {}
                for name in wave:
                    by_worker.setdefault(self.device_of[name], []).append(name)
                seg_ms.update(self._dispatch_chunks(by_worker, "step_many"))
            return seg_ms
        finally:
            self._end_concurrent_step()

    @staticmethod
    def _boundary_topics(seg: RemoteSegment) -> Set[str]:
        in_segment = set(seg.spec.task_ids)
        return {
            topic_for(p)
            for tid in seg.spec.task_ids
            for p in seg.spec.parents.get(tid, ())
            if p not in in_segment
        }

    # -- durability hooks ---------------------------------------------------------
    def _dump_extra(self) -> Dict[str, Any]:
        counters = self.transport.counters()
        return {
            "worker_of": {name: int(i) for name, i in self.device_of.items()},
            "n_workers": self.n_workers,
            "broker_bytes_published": int(counters["bytes_published"]),
            "broker_publishes": int(counters["publishes"]),
        }

    def _restore_extra(self, extra: Dict[str, Any]) -> None:
        self.device_of_at_checkpoint = {
            name: int(i) for name, i in extra.get("worker_of", {}).items()
        }
        if extra.get("n_workers") is not None:
            self._n_slots_at_checkpoint = int(extra["n_workers"])
        self.transport.restore_counters(
            int(extra.get("broker_bytes_published", 0)),
            int(extra.get("broker_publishes", 0)),
        )

    def spawn_config(self) -> Dict[str, Any]:
        cfg: Dict[str, Any] = {
            "workers": self.n_workers,
            "transport": self.transport.name,
            "worker_plane": self.worker_plane,
        }
        if getattr(self.policy, "name", ""):
            cfg["placement"] = self.policy.name
        if getattr(self.launcher, "name", "local") != "local":
            cfg["launcher"] = self.launcher.name
        return cfg

    # -- lifecycle ---------------------------------------------------------------
    def close(self) -> None:
        """Shut down the dispatch pool, the worker pool and the transport.

        Unlike the single-process backends this releases the deployed
        segments' host processes — a closed multiproc backend is done
        stepping (restore from a checkpoint to resume)."""
        super().close()
        if self._spawned:
            for i, handle in enumerate(self._procs):
                try:
                    with self._conn_locks[i]:
                        handle.conn.send({"op": "shutdown"})
                        handle.conn.recv()
                except (EOFError, BrokenPipeError, OSError):
                    pass
                handle.close()
            for handle in self._procs:
                handle.join(timeout=10)
                if handle.is_alive():  # pragma: no cover - stuck worker
                    handle.terminate()
                    handle.join(timeout=5)
            self._procs.clear()
            self._conn_locks.clear()
            self._gen.clear()
            self._spawned = False
        if self._spill_dir is not None:
            shutil.rmtree(self._spill_dir, ignore_errors=True)
            self._spill_dir = None
        self.transport.close()

    def __del__(self):  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass
