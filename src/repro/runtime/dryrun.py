"""DryRunBackend — pure cost-model stepping, no JAX anywhere.

The paper's Fig. 2/3 resource counters (running task count, core usage)
are *control-plane* observables: they depend only on which tasks are
deployed, which are paused, and each task's ``cost_weight × batch``. This
backend deploys the same :class:`~repro.runtime.backend.SegmentSpec`
segments the jit backends would, but instantiates no operators and moves
no event batches — a step just advances per-sink event counters and
re-evaluates the shared accounting. Full 35-dataflow OPMW
arrival/departure sweeps run in milliseconds, so control-plane experiments
(merge policies, defrag schedules, trace studies) no longer pay jit
compilation.

The contract with the jit backends: identical ``live_tasks`` /
``paused_tasks`` / ``cost`` trajectories for the same submissions (cost
weights come from the shared jax-free :mod:`repro.ops.costs` model) and
identical sink event *counts*; checksums are jit-only and read as 0.0
here.

Latency is *modelled*, not spent: with a calibrated
:class:`~repro.ops.costs.LatencyModel` (fit from recorded jit
``StepReport``s via :meth:`ExecutionBackend.latency_samples`) every
segment reports the wall-time a jit backend would have measured, and
``step_mode="concurrent"`` turns into a simulated-clock makespan study —
per-wave ``segment_ms = max`` (independent segments overlap), summed
across dependency waves — so straggler/defrag/placement scheduling
questions answer entirely in dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Set, Union

from repro.core.graph import Dataflow
from repro.ops.costs import LatencyModel, cost_weight_for_task

from .backend import ExecutionBackend, SegmentSpec
from .checkpoint import decode_pytree


@dataclass
class DrySegment:
    """Cost-model stand-in for a compiled segment (same observable surface)."""

    spec: SegmentSpec
    states: Dict[str, Any]  # sinks: {"count", "checksum"}; others: ()
    active: Dict[str, bool]
    cost_of: Dict[str, float]
    sink_ids: List[str]
    steps_run: int = 0

    @property
    def name(self) -> str:
        return self.spec.name

    def live_task_ids(self) -> List[str]:
        return [t for t in self.spec.task_ids if self.active[t]]

    def pause(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = False

    def resume(self, task_ids: Set[str]) -> None:
        for tid in task_ids:
            if tid in self.active:
                self.active[tid] = True


class DryRunBackend(ExecutionBackend):
    name = "dryrun"
    # Concurrency is simulated, not spent: stepping stays on the caller's
    # thread and the dependency-DAG makespan model (wave max) does the rest.
    concurrent_dispatch = False

    def __init__(
        self,
        straggler_factor: float = 3.0,
        ewma_alpha: float = 0.3,
        step_mode: str = "sync",
        max_workers: Optional[int] = None,
        latency_model: Optional[LatencyModel] = None,
    ):
        super().__init__(
            straggler_factor=straggler_factor,
            ewma_alpha=ewma_alpha,
            step_mode=step_mode,
            max_workers=max_workers,
        )
        self.latency_model = latency_model

    def calibrate(self, samples_or_model: Union[LatencyModel, list]) -> LatencyModel:
        """Install a latency model (or fit one from jit calibration samples —
        the output of :meth:`ExecutionBackend.latency_samples`)."""
        if isinstance(samples_or_model, LatencyModel):
            self.latency_model = samples_or_model
        else:
            from repro.ops.costs import fit_latency_model

            self.latency_model = fit_latency_model(samples_or_model)
        return self.latency_model

    # -- ExecutionBackend hooks -------------------------------------------------
    def _build(
        self,
        spec: SegmentSpec,
        dataflow: Dataflow,
        init_states: Optional[Dict[str, Any]],
    ) -> DrySegment:
        states: Dict[str, Any] = {}
        sink_ids: List[str] = []
        cost_of: Dict[str, float] = {}
        for tid in spec.task_ids:
            task = dataflow.tasks[tid]
            cost_of[tid] = cost_weight_for_task(task)
            if task.is_sink:
                sink_ids.append(tid)
                states[tid] = {"count": 0, "checksum": 0.0}
            else:
                states[tid] = ()
            if init_states and tid in init_states:
                states[tid] = init_states[tid]
        return DrySegment(
            spec=spec,
            states=states,
            active={tid: True for tid in spec.task_ids},
            cost_of=cost_of,
            sink_ids=sink_ids,
        )

    def _decode_init_states(
        self, spec: SegmentSpec, dataflow: Dataflow, states_enc: Dict[str, Any]
    ) -> Dict[str, Any]:
        """Coerce checkpointed states to the cost-model's native form.

        Only sink counters matter here: a jit checkpoint's sink state
        (device arrays for count/checksum/last) collapses to
        ``{"count": int, "checksum": 0.0}`` — checksums are jit-only and
        read as 0.0 on this backend — and every non-sink state collapses
        to ``()``. This is the inprocess → dryrun half of the cross-backend
        restore contract: sink counts and Fig. 2 trajectories continue
        exactly; jit-internal operator state is deliberately dropped.
        """
        out: Dict[str, Any] = {}
        for tid, enc in states_enc.items():
            if not dataflow.tasks[tid].is_sink:
                out[tid] = ()
                continue
            value = decode_pytree(enc)
            count = value.get("count", 0) if isinstance(value, dict) else 0
            out[tid] = {"count": int(count), "checksum": 0.0}
        return out

    def _step_one(self, seg: DrySegment) -> Optional[float]:
        for tid in seg.sink_ids:
            if seg.active[tid]:
                st = seg.states[tid]
                seg.states[tid] = {"count": st["count"] + 1, "checksum": 0.0}
        seg.steps_run += 1
        if self.latency_model is None:
            return None  # measured (~µs) — the uncalibrated legacy behavior
        units: Dict[str, float] = {}
        for tid in seg.spec.task_ids:
            if not seg.active[tid]:
                continue  # paused tasks are skipped by the jit lax.cond too
            ttype = self.task_defs[tid].type
            units[ttype] = units.get(ttype, 0.0) + seg.cost_of[tid] * seg.spec.batch_of[tid]
        return self.latency_model.segment_ms(units)
