"""repro.obs — unified telemetry plane (metrics, tracing, export).

One import point for the whole surface:

    from repro.obs import MetricsRegistry, Tracer, render_prometheus

Metric families (all ``repro_``-prefixed; full table in README
"Observability"):

  * step pipeline   — ``repro_steps_total``, ``repro_step_wall_ms``,
    ``repro_segment_step_ms``, ``repro_tasks_live``, ``repro_tasks_paused``,
    ``repro_cost_cores``
  * transport       — ``repro_transport_publishes``,
    ``repro_transport_bytes_published``, ``repro_transport_fetches``
  * workers         — ``repro_worker_rpcs_total{op=}``,
    ``repro_worker_respawns_total``
  * compile cache   — ``repro_compile_cache_{hits,misses,evictions,entries}``
  * checkpointing   — ``repro_checkpoints_total``, ``repro_checkpoint_save_ms``
  * reuse savings   — ``repro_reuse_tasks_saved``,
    ``repro_reuse_tasks_{submitted,reused}_total``,
    ``repro_reuse_core_steps_avoided_total``, ``repro_merge_events_total``,
    ``repro_unmerge_events_total``, ``repro_fusion_segments_saved_total``,
    ``repro_serve_slots_saved{tenant=}``

Everything here is stdlib-only and JAX-free — the dry-run coordinator and
the serving front end import it unconditionally.
"""
from .metrics import (
    DEFAULT_MS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
    merge_snapshots,
    parse_prometheus,
    process_metrics,
    render_prometheus,
)
from .tracing import Tracer, chrome_trace_json, process_tracer, write_chrome_trace

__all__ = [
    "Counter",
    "DEFAULT_MS_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NullRegistry",
    "Tracer",
    "chrome_trace_json",
    "merge_snapshots",
    "parse_prometheus",
    "process_metrics",
    "process_tracer",
    "render_prometheus",
    "write_chrome_trace",
]
