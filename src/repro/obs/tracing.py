"""Lightweight step-span tracing with a Chrome trace-event exporter.

A :class:`Tracer` records complete spans (``ph: "X"``) into a bounded
ring buffer. Design constraints, in order:

  1. **Cheap when off** — ``tracer.enabled`` is a plain attribute; hot
     paths guard with ``if tracer.enabled:`` so a disabled tracer costs
     one attribute load per site.
  2. **Monotonic clock** — timestamps are ``time.monotonic_ns()//1000``
     (µs). The monotonic clock is per-*boot*, not per-process, so spans
     recorded in multiproc worker processes line up with coordinator
     spans on the same host without any clock handshake — which is what
     makes the merged Chrome trace show real cross-worker overlap.
  3. **Bounded** — the ring buffer (``capacity`` spans) drops oldest;
     ``sample_stride=N`` records every Nth span per span name, the knob
     that keeps per-segment tracing affordable at high step rates.

Span dicts are already Chrome trace-event shaped (``name``/``cat``/
``ph``/``ts``/``dur``/``pid``/``tid``/``args``), so export is just
wrapping them in ``{"traceEvents": [...]}`` — load the file in
``chrome://tracing`` or https://ui.perfetto.dev. They are also plain
JSON, so workers ship them to the coordinator on the ``metrics`` RPC
unchanged. JAX-free, stdlib only.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "Tracer",
    "chrome_trace_json",
    "process_tracer",
    "write_chrome_trace",
]

# Span categories used across the runtime (the README table's source):
#   step        whole-step + wave structure        (backend.step)
#   segment     per-segment step execution         (_step_named / workers)
#   transport   input fetch / output publish       (executor)
#   rpc         coordinator→worker command RPCs    (multiproc _call)
#   compile     compile-cache miss trace+jit       (compile_cache)
#   control     submit / remove / preview / fuse   (manager, system)
#   checkpoint  encode / fsync / save              (checkpoint store)


class Tracer:
    def __init__(
        self,
        enabled: bool = False,
        capacity: int = 65536,
        sample_stride: int = 1,
    ):
        self.enabled = bool(enabled)
        self.sample_stride = max(int(sample_stride), 1)
        self._buf: deque = deque(maxlen=max(int(capacity), 1))
        self._seen: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._pid = os.getpid()

    # -- configuration ------------------------------------------------------------
    def configure(
        self,
        enabled: Optional[bool] = None,
        sample_stride: Optional[int] = None,
        capacity: Optional[int] = None,
    ) -> None:
        with self._lock:
            if enabled is not None:
                self.enabled = bool(enabled)
            if sample_stride is not None:
                self.sample_stride = max(int(sample_stride), 1)
            if capacity is not None and capacity != self._buf.maxlen:
                self._buf = deque(self._buf, maxlen=max(int(capacity), 1))

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def _admit(self, name: str) -> bool:
        """Per-name stride sampling: True for every Nth span of ``name``."""
        if self.sample_stride <= 1:
            return True
        with self._lock:
            n = self._seen.get(name, 0)
            self._seen[name] = n + 1
        return n % self.sample_stride == 0

    # -- recording ----------------------------------------------------------------
    @contextmanager
    def span(self, name: str, cat: str = "step", **args: Any) -> Iterator[None]:
        """Record one complete span around the with-block. A no-op (beyond
        one branch) when disabled or sampled out; exceptions propagate and
        the span is still recorded with an ``error`` arg."""
        if not self.enabled or not self._admit(name):
            yield
            return
        t0 = time.monotonic_ns()
        try:
            yield
        except BaseException as e:
            args = dict(args, error=type(e).__name__)
            raise
        finally:
            t1 = time.monotonic_ns()
            self._buf.append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": t0 // 1000,
                    "dur": max((t1 - t0) // 1000, 1),
                    "pid": self._pid,
                    "tid": threading.get_ident() & 0xFFFFFFFF,
                    "args": args,
                }
            )

    def instant(self, name: str, cat: str = "step", **args: Any) -> None:
        """Record a zero-duration instant event (``ph: "i"``)."""
        if not self.enabled:
            return
        self._buf.append(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",
                "ts": time.monotonic_ns() // 1000,
                "pid": self._pid,
                "tid": threading.get_ident() & 0xFFFFFFFF,
                "args": args,
            }
        )

    # -- export -------------------------------------------------------------------
    def drain(self) -> List[Dict[str, Any]]:
        """Pop and return all buffered spans (oldest first)."""
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
        return out

    def spans(self) -> List[Dict[str, Any]]:
        """Peek at buffered spans without draining."""
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)


def chrome_trace_json(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Wrap span dicts as a Chrome trace-event file payload. Adds one
    process-name metadata event per pid so Perfetto labels worker rows."""
    events: List[Dict[str, Any]] = []
    for pid in sorted({s["pid"] for s in spans}):
        events.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": f"repro pid {pid}"},
            }
        )
    events.extend(sorted(spans, key=lambda s: s.get("ts", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: List[Dict[str, Any]]) -> str:
    """Write spans as a Chrome/Perfetto-loadable trace file; returns path."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace_json(spans), f)
    return path


# -- per-process singleton --------------------------------------------------------

_process_tracer: Optional[Tracer] = None
_process_lock = threading.Lock()


def process_tracer() -> Tracer:
    """The per-process tracer multiproc *workers* record into (disabled
    until the coordinator's ``trace`` RPC enables it); its spans ride the
    ``metrics`` RPC reply back to the coordinator."""
    global _process_tracer
    with _process_lock:
        if _process_tracer is None:
            _process_tracer = Tracer(enabled=False)
        return _process_tracer
