"""Process-local metrics: counters, gauges, histograms — mergeable, no deps.

The telemetry model is deliberately small and Prometheus-shaped:

  * :class:`Counter` — monotonically increasing float (``inc``); resets
    only with the process.
  * :class:`Gauge` — last-written float (``set``/``inc``/``dec``).
  * :class:`Histogram` — fixed-bucket cumulative histogram (``observe``),
    the only shape that merges exactly across processes.

Every instrument supports labels (``counter.inc(1, op="step")``) with the
usual low-cardinality caveat. Instruments live in a
:class:`MetricsRegistry`; registries serialize to plain-JSON
:meth:`~MetricsRegistry.snapshot` dicts and merge with
:meth:`~MetricsRegistry.merge_snapshot` — which is how multiproc workers
ship their process-local registries to the coordinator over the
``metrics`` RPC (same pattern as ``cache_stats``) and the coordinator
aggregates them: counters and histograms add, gauges add too (worker
gauges are per-process quantities like queue depths, so the pool-wide
value is the sum).

``render_prometheus`` hand-rolls the text exposition format (no client
library), and ``parse_prometheus`` is the tiny inverse used by tests and
the CI scrape smoke. This module must stay free of JAX imports — the
dry-run coordinator and the serving front end are JAX-free.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "DEFAULT_MS_BUCKETS",
    "merge_snapshots",
    "parse_prometheus",
    "process_metrics",
    "render_prometheus",
]

# Wall-time buckets in milliseconds — spans µs-scale dispatch overhead up
# to multi-second checkpoint fsyncs.
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    """Shared label-keyed storage; subclasses define the write verbs."""

    kind = "untyped"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._values: Dict[_LabelKey, Any] = {}

    def labelsets(self) -> List[Dict[str, str]]:
        with self._lock:
            return [dict(k) for k in self._values]

    def value(self, **labels: Any) -> float:
        """Current scalar for one labelset (0.0 when never written)."""
        with self._lock:
            return float(self._values.get(_label_key(labels), 0.0))


class Counter(_Instrument):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {amount}")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + float(amount)

    def set_total(self, total: float, **labels: Any) -> None:
        """Mirror an externally-tracked monotonic total (e.g. transport
        ``counters()``); clamps to never decrease so restores/rebinds
        can't violate counter semantics."""
        key = _label_key(labels)
        with self._lock:
            self._values[key] = max(self._values.get(key, 0.0), float(total))


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + float(amount)

    def dec(self, amount: float = 1.0, **labels: Any) -> None:
        self.inc(-amount, **labels)


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        lock: threading.Lock,
        buckets: Optional[Sequence[float]] = None,
    ):
        super().__init__(name, help, lock)
        bounds = tuple(sorted(float(b) for b in (buckets or DEFAULT_MS_BUCKETS)))
        if not bounds:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.buckets = bounds  # upper bounds; +Inf bucket is implicit

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        value = float(value)
        with self._lock:
            cell = self._values.get(key)
            if cell is None:
                cell = self._values[key] = {
                    "counts": [0] * (len(self.buckets) + 1),
                    "sum": 0.0,
                    "count": 0,
                }
            i = len(self.buckets)
            for j, bound in enumerate(self.buckets):
                if value <= bound:
                    i = j
                    break
            cell["counts"][i] += 1
            cell["sum"] += value
            cell["count"] += 1

    def value(self, **labels: Any) -> float:
        """Observation count for one labelset (histograms have no scalar)."""
        with self._lock:
            cell = self._values.get(_label_key(labels))
            return float(cell["count"]) if cell else 0.0


class MetricsRegistry:
    """A named family of instruments with get-or-create semantics.

    ``counter``/``gauge``/``histogram`` are idempotent: the first call
    mints the instrument, later calls return it (kind mismatches raise).
    ``add_collector`` registers a callback run at every
    :meth:`snapshot` — the hook that mirrors externally-owned values
    (transport byte counters, compile-cache stats, tenant ledgers) into
    gauges right before export, so scrapes are always coherent without
    putting bookkeeping on the hot path.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}
        self._collectors: List[Callable[[], None]] = []

    # -- minting ------------------------------------------------------------------
    def _get(self, cls, name: str, help: str, **kwargs: Any) -> Any:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, help, self._lock, **kwargs)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}"
                )
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def add_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    # -- export -------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Plain-JSON dump of every instrument (collectors run first)."""
        for fn in list(self._collectors):
            try:
                fn()
            except Exception:  # a dying collector must never kill a scrape
                pass
        out: Dict[str, Any] = {}
        with self._lock:
            for name, inst in sorted(self._instruments.items()):
                entry: Dict[str, Any] = {
                    "kind": inst.kind,
                    "help": inst.help,
                    "values": [
                        [dict(k), v if inst.kind != "histogram" else dict(
                            counts=list(v["counts"]), sum=v["sum"], count=v["count"])]
                        for k, v in inst._values.items()
                    ],
                }
                if inst.kind == "histogram":
                    entry["buckets"] = list(inst.buckets)
                out[name] = entry
        return out

    def merge_snapshot(self, snap: Dict[str, Any]) -> None:
        """Fold a foreign snapshot into this registry (counters/gauges/
        histogram cells add; histogram bucket layouts must match)."""
        for name, entry in snap.items():
            kind = entry.get("kind")
            if kind == "counter":
                inst: Any = self.counter(name, entry.get("help", ""))
            elif kind == "gauge":
                inst = self.gauge(name, entry.get("help", ""))
            elif kind == "histogram":
                inst = self.histogram(
                    name, entry.get("help", ""), buckets=entry.get("buckets")
                )
            else:
                continue
            for labels, value in entry.get("values", []):
                key = _label_key(labels)
                with self._lock:
                    if kind == "histogram":
                        cell = inst._values.get(key)
                        if cell is None:
                            cell = inst._values[key] = {
                                "counts": [0] * (len(inst.buckets) + 1),
                                "sum": 0.0,
                                "count": 0,
                            }
                        counts = value.get("counts", [])
                        if len(counts) == len(cell["counts"]):
                            cell["counts"] = [
                                a + b for a, b in zip(cell["counts"], counts)
                            ]
                        cell["sum"] += float(value.get("sum", 0.0))
                        cell["count"] += int(value.get("count", 0))
                    else:
                        inst._values[key] = inst._values.get(key, 0.0) + float(value)


def merge_snapshots(snaps: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge snapshot dicts into one aggregated snapshot."""
    acc = MetricsRegistry()
    for snap in snaps:
        if snap:
            acc.merge_snapshot(snap)
    return acc.snapshot()


# -- no-op twin -------------------------------------------------------------------


class _NullInstrument:
    """Accepts every write verb and does nothing — the obs-off fast path."""

    def inc(self, *a: Any, **k: Any) -> None: ...
    def dec(self, *a: Any, **k: Any) -> None: ...
    def set(self, *a: Any, **k: Any) -> None: ...
    def set_total(self, *a: Any, **k: Any) -> None: ...
    def observe(self, *a: Any, **k: Any) -> None: ...
    def value(self, **labels: Any) -> float:
        return 0.0
    def labelsets(self) -> List[Dict[str, str]]:
        return []


class NullRegistry(MetricsRegistry):
    """Registry that mints no-op instruments; ``snapshot()`` is empty.

    Installed when a backend is built with ``obs=False`` so the overhead
    benchmark has an honest baseline."""

    _NULL = _NullInstrument()

    def counter(self, name: str, help: str = "") -> Any:  # type: ignore[override]
        return self._NULL

    def gauge(self, name: str, help: str = "") -> Any:  # type: ignore[override]
        return self._NULL

    def histogram(self, name: str, help: str = "", buckets: Any = None) -> Any:  # type: ignore[override]
        return self._NULL

    def add_collector(self, fn: Callable[[], None]) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {}


NULL_REGISTRY = NullRegistry()


# -- Prometheus text exposition ---------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = []
    for k, v in sorted(labels.items()):
        escaped = str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
        parts.append(f'{_prom_name(k)}="{escaped}"')
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _prom_num(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(snapshot: Dict[str, Any]) -> str:
    """Prometheus text exposition (format version 0.0.4) of a snapshot."""
    lines: List[str] = []
    for name in sorted(snapshot):
        entry = snapshot[name]
        kind, pname = entry.get("kind", "untyped"), _prom_name(name)
        help_text = str(entry.get("help", "")).replace("\\", r"\\").replace("\n", r"\n")
        if help_text:
            lines.append(f"# HELP {pname} {help_text}")
        lines.append(f"# TYPE {pname} {kind}")
        for labels, value in entry.get("values", []):
            if kind == "histogram":
                acc = 0
                for bound, n in zip(
                    list(entry["buckets"]) + [float("inf")], value["counts"]
                ):
                    acc += n
                    le = _prom_labels(labels, f'le="{_prom_num(bound)}"')
                    lines.append(f"{pname}_bucket{le} {acc}")
                lines.append(f"{pname}_sum{_prom_labels(labels)} {_prom_num(value['sum'])}")
                lines.append(f"{pname}_count{_prom_labels(labels)} {value['count']}")
            else:
                lines.append(f"{pname}{_prom_labels(labels)} {_prom_num(value)}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)(?:\s+\d+)?$"
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str], float]]]:
    """Tiny inverse of :func:`render_prometheus` for tests and CI smokes.

    Returns ``{sample_name: [(labels, value), ...]}`` (histogram series
    appear under their ``_bucket``/``_sum``/``_count`` sample names).
    Raises ``ValueError`` on any line that is neither a comment nor a
    well-formed sample — which is what makes it a format validator.
    """
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line {lineno}: {line!r}")
        name, labelstr, raw = m.groups()
        labels = {
            k: v.replace(r"\"", '"').replace(r"\n", "\n").replace(r"\\", "\\")
            for k, v in _LABEL_RE.findall(labelstr or "")
        }
        try:
            value = float(raw.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            raise ValueError(f"bad sample value on line {lineno}: {raw!r}") from None
        out.setdefault(name, []).append((labels, value))
    return out


# -- per-process singleton --------------------------------------------------------

_process_registry: Optional[MetricsRegistry] = None
_process_lock = threading.Lock()


def process_metrics() -> MetricsRegistry:
    """The per-process registry multiproc *workers* write into; the
    coordinator pulls it over the ``metrics`` RPC and merges. Coordinator-
    side components use their owner's registry instead, so tests running
    many systems in one process don't cross-contaminate."""
    global _process_registry
    with _process_lock:
        if _process_registry is None:
            _process_registry = MetricsRegistry()
        return _process_registry
