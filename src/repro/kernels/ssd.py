"""Pallas TPU Mamba2 SSD chunked scan.

The SSD recurrence is chunk-parallel: within a chunk the output is a
masked (decay-weighted) matmul — MXU work — and only the (N × P) state
crosses chunks. The kernel maps chunks onto the innermost *sequential*
grid dim with the state in VMEM scratch, so the state never round-trips
to HBM (the pure-jnp scan writes it back every chunk).

Grid: (batch, heads, chunks) — chunks innermost.
Per-chunk tiles: x (L, P), dt/la (L,), B/C (L, N); scratch h (N, P) f32.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _ssd_kernel(
    x_ref, dt_ref, la_ref, b_ref, c_ref,  # VMEM tiles
    y_ref, hout_ref,                      # outputs
    h_scr,                                # VMEM scratch state (N, P) f32
    *, chunk: int,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)   # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)    # (L,)
    la = la_ref[0, :, 0].astype(jnp.float32)    # (L,) = dt * a  (≤ 0)
    bm = b_ref[0].astype(jnp.float32)           # (L, N)
    cm = c_ref[0].astype(jnp.float32)           # (L, N)

    cum = jnp.cumsum(la)                        # (L,)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj
    T = jnp.where(causal, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    CB = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    W = T * CB * dt[None, :]
    y_intra = jax.lax.dot(W, x, preferred_element_type=jnp.float32)  # (L, P)
    h = h_scr[...]
    y_inter = jax.lax.dot(cm, h, preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    last = cum[-1]
    w_end = jnp.exp(last - cum) * dt            # (L,)
    h_add = jax.lax.dot_general(
        bm, x * w_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (N, P)
    h_new = jnp.exp(last) * h + h_add
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xh: jnp.ndarray,     # (B, S, nh, P)
    dt: jnp.ndarray,     # (B, S, nh) softplus'd
    a: jnp.ndarray,      # (nh,) negative decay
    B_ssm: jnp.ndarray,  # (B, S, N)
    C_ssm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,nh,P) f32, final state (B,nh,N,P) f32)."""
    Bb, S, nh, P = xh.shape
    N = B_ssm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    la = dt * a[None, None, :]  # (B, S, nh)

    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(Bb, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, nh, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nh, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xh, dt, la, B_ssm, C_ssm)
    return y, h
