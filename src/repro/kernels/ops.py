"""Backend-dispatching wrappers: Pallas kernels on TPU, interpret mode or
jnp reference elsewhere. Model code calls these entry points.

``set_backend("pallas"|"ref"|"interpret")`` overrides detection (tests
pin "interpret" to execute the real kernel bodies on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .fused import affine_rmsnorm as _affine_rmsnorm_pallas
from .fused import map_chain as _map_chain_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .rmsnorm import rmsnorm_residual as _rmsnorm_res_pallas
from .ssd import ssd_scan as _ssd_pallas

_BACKEND: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    global _BACKEND
    assert name in (None, "pallas", "ref", "interpret")
    _BACKEND = name


def backend() -> str:
    if _BACKEND:
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _gqa_repeat(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def flash_attention(q, k, v, *, causal=True, window=0, scale=None):
    """q (B,Sq,H,hd); k/v (B,Sk,KV,hd) — GQA repeat handled here."""
    be = backend()
    if be == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    groups = q.shape[2] // k.shape[2]
    k = _gqa_repeat(k, groups)
    v = _gqa_repeat(v, groups)
    return _flash_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        interpret=(be == "interpret"),
    )


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, scale=None):
    be = backend()
    if be == "ref":
        return ref.decode_attention_ref(
            q, k_cache, v_cache, cache_len, window=window, scale=scale
        )
    return _decode_pallas(
        q, k_cache, v_cache, cache_len, window=window, scale=scale,
        interpret=(be == "interpret"),
    )


def rmsnorm(x, scale, eps: float = 1e-6):
    be = backend()
    if be == "ref":
        return ref.rmsnorm_ref(x, scale, eps)
    return _rmsnorm_pallas(x, scale, eps=eps, interpret=(be == "interpret"))


def map_chain(x, *, stages):
    """Sequential per-channel affine stages — the fused senml_parse chain."""
    be = backend()
    if be == "ref":
        return ref.map_chain_ref(x, stages)
    return _map_chain_pallas(x, stages=tuple(stages), interpret=(be == "interpret"))


def affine_rmsnorm(x, scale, *, stages, eps: float = 1e-6):
    """Affine decode chain feeding an RMS-norm tail, one fused pass."""
    be = backend()
    if be == "ref":
        return ref.affine_rmsnorm_ref(x, scale, stages, eps)
    return _affine_rmsnorm_pallas(
        x, scale, stages=tuple(stages), eps=eps, interpret=(be == "interpret")
    )


def rmsnorm_residual(x, residual, scale, eps: float = 1e-6):
    be = backend()
    if be == "ref":
        return ref.rmsnorm_residual_ref(x, residual, scale, eps)
    return _rmsnorm_res_pallas(x, residual, scale, eps=eps, interpret=(be == "interpret"))


def ssd_scan(xh, dt, a, B_ssm, C_ssm, *, chunk: int = 128):
    be = backend()
    if be == "ref":
        return ref.ssd_scan_ref(xh, dt, a, B_ssm, C_ssm, chunk=chunk)
    return _ssd_pallas(xh, dt, a, B_ssm, C_ssm, chunk=chunk, interpret=(be == "interpret"))
