"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These delegate to the model-layer reference implementations so the
kernels are validated against exactly the math the models use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention, decode_attention as _decode_ref
from repro.models.common import rms_norm
from repro.models.ssm import ssd_chunked


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    return chunked_attention(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention_ref(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    return _decode_ref(q, k_cache, v_cache, cache_len, window=window, scale=scale)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return rms_norm(x, scale, eps=eps)


def _apply_stages_ref(x: jnp.ndarray, stages) -> jnp.ndarray:
    # sequential, never algebraically collapsed — bitwise identity with the
    # unfused op-by-op execution is the contract (see kernels/fused.py)
    for scale, offset in stages:
        x = x * scale + offset
    return x


def map_chain_ref(x: jnp.ndarray, stages) -> jnp.ndarray:
    return _apply_stages_ref(x, stages)


def affine_rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, stages, eps: float = 1e-6):
    return rms_norm(_apply_stages_ref(x, stages), scale, eps=eps)


def rmsnorm_residual_ref(x, residual, scale, eps: float = 1e-6):
    added = x + residual
    return rms_norm(added, scale, eps=eps), added


def ssd_scan_ref(
    xh: jnp.ndarray,
    dt: jnp.ndarray,
    a: jnp.ndarray,
    B_ssm: jnp.ndarray,
    C_ssm: jnp.ndarray,
    *,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return ssd_chunked(xh, dt, a, B_ssm, C_ssm, chunk=chunk)
