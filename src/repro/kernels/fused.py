"""Pallas TPU multi-op fused kernels for planner-fused segment chains.

When the fusion planner collapses a straight-line run of kernel-backed
stream operators into one segment, the per-op XLA graph still streams
each intermediate through HBM. These kernels collapse the whole run into
one VMEM-resident pass:

  * :func:`map_chain` — a chain of per-channel affine decode stages
    (RIoTBench ``senml_parse``) applied back to back: one read, N
    multiply-adds in registers, one write.
  * :func:`affine_rmsnorm` — the same affine chain feeding an RMS-norm
    tail (``senml_parse* → rmsnorm``): the norm consumes the affine
    result straight out of VMEM.

The stages are applied **sequentially**, never algebraically collapsed
into one ⟨scale, offset⟩ pair — float rounding differs between
``(x·s₁+o₁)·s₂+o₂`` and ``x·(s₁s₂)+…``, and the digest-identity contract
(fused ≡ unfused, bitwise) requires replaying exactly the op sequence the
unfused segments execute. ``stages`` is a static tuple of ``(scale,
offset)`` pairs, so each distinct chain shape compiles once.

Grid: (rows / block_rows,) — embarrassingly parallel over row tiles,
mirroring :mod:`repro.kernels.rmsnorm`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Stages = Tuple[Tuple[float, float], ...]


def _apply_stages(x: jnp.ndarray, stages: Stages) -> jnp.ndarray:
    for scale, offset in stages:
        x = x * jnp.float32(scale) + jnp.float32(offset)
    return x


def _map_chain_kernel(x_ref, o_ref, *, stages: Stages):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = _apply_stages(x, stages).astype(o_ref.dtype)


def _affine_rmsnorm_kernel(x_ref, scale_ref, o_ref, *, stages: Stages, eps: float):
    x = _apply_stages(x_ref[...].astype(jnp.float32), stages)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _tile(x: jnp.ndarray, block_rows: int):
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    return xr, rows, br, d, pad


@functools.partial(jax.jit, static_argnames=("stages", "block_rows", "interpret"))
def map_chain(
    x: jnp.ndarray,  # (..., D)
    *,
    stages: Stages,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    xr, rows, br, d, pad = _tile(x, block_rows)
    out = pl.pallas_call(
        functools.partial(_map_chain_kernel, stages=stages),
        grid=(xr.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("stages", "eps", "block_rows", "interpret"))
def affine_rmsnorm(
    x: jnp.ndarray,  # (..., D)
    scale: jnp.ndarray,  # (D,)
    *,
    stages: Stages,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    xr, rows, br, d, pad = _tile(x, block_rows)
    out = pl.pallas_call(
        functools.partial(_affine_rmsnorm_kernel, stages=stages, eps=eps),
        grid=(xr.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
