"""Version-compatibility shims for Pallas-TPU across JAX releases.

Newer JAX exposes ``jax.experimental.pallas.tpu.CompilerParams`` and
``MemorySpace``; older releases (≤0.4.x) call the same objects
``TPUCompilerParams`` / ``TPUMemorySpace``. Kernels import the aliases from
here so they compile against either.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

__all__ = ["CompilerParams", "MemorySpace"]
