"""Pallas TPU fused RMSNorm (+ optional residual add).

One HBM read + one write per element: the row tile (rows × D) is normed
in VMEM at f32 and written back in the input dtype. Fusing the residual
add removes a third stream. D is the lane dim (multiple of 128 for every
assigned arch: 1024…18432).

Grid: (rows / block_rows,) — embarrassingly parallel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, res_ref, scale_ref, o_ref, add_ref, *, eps: float):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    add_ref[...] = h.astype(add_ref.dtype)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jnp.ndarray,  # (..., D)
    scale: jnp.ndarray,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xr.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_residual(
    x: jnp.ndarray,  # (..., D) block output
    residual: jnp.ndarray,  # (..., D) running stream
    scale: jnp.ndarray,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Returns (normed(x+residual), x+residual) with one fused pass."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    rr = residual.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        rr = jnp.pad(rr, ((0, pad), (0, 0)))
    normed, added = pl.pallas_call(
        functools.partial(_rmsnorm_res_kernel, eps=eps),
        grid=(xr.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xr.shape, x.dtype),
            jax.ShapeDtypeStruct(xr.shape, x.dtype),
        ],
        interpret=interpret,
    )(xr, rr, scale)
    if pad:
        normed, added = normed[:rows], added[:rows]
    return normed.reshape(orig_shape), added.reshape(orig_shape)
